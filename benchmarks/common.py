"""Shared helpers for the benchmark harness."""
import time
from contextlib import contextmanager

ROWS = []


def emit(name: str, us_per_call: float, derived: str) -> None:
    ROWS.append((name, us_per_call, derived))
    # %.6g keeps ratios (warm_ratio 0.917) and micro-latencies exact
    # enough for the CI regression gate without bloating big numbers.
    print(f"{name},{us_per_call:.6g},{derived}")


@contextmanager
def timed():
    box = {}
    t0 = time.perf_counter()
    yield box
    box["s"] = time.perf_counter() - t0
