"""Shared helpers for the benchmark harness."""
import time
from contextlib import contextmanager

ROWS = []


def emit(name: str, us_per_call: float, derived: str) -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


@contextmanager
def timed():
    box = {}
    t0 = time.perf_counter()
    yield box
    box["s"] = time.perf_counter() - t0
