"""Paper Figs 5, 6, 8: cold-start %, normalized accuracy, and robustness
versus prediction deviation, for all four policies + no-policy."""
import time

from benchmarks.common import emit
from repro.configs.paper_edge import DEFAULT_MEMORY_MB, paper_zoos
from repro.core import sweep_policies


def run() -> None:
    t0 = time.perf_counter()
    out = sweep_policies(
        paper_zoos(), deviations=(0.0, 0.3, 0.6, 0.9),
        policies=("none", "lfe", "bfe", "ws-bfe", "iws-bfe"),
        budget_mb=DEFAULT_MEMORY_MB, seeds=(0, 1, 2), requests_per_app=50)
    us = (time.perf_counter() - t0) * 1e6 / 20
    for fig, key in (("fig5_coldstart", "cold"), ("fig6_accuracy", "acc"),
                     ("fig8_robustness", "rob")):
        for policy, per_d in out.items():
            vals = " ".join(f"d{d:.1f}={m[key]:.3f}"
                            for d, m in sorted(per_d.items()))
            emit(f"{fig}/{policy}", us, vals)
    # headline paper-claim ratios at 30% deviation
    d = 0.3
    lfe, ws, iws = (out[p][d]["cold"] for p in ("lfe", "ws-bfe", "iws-bfe"))
    emit("fig5/claims", us,
         f"iws_vs_lfe={1 - iws / max(lfe, 1e-9):.0%}_fewer "
         f"iws_vs_ws={1 - iws / max(ws, 1e-9):.0%}_fewer "
         f"ws_vs_lfe={1 - ws / max(lfe, 1e-9):.0%}_fewer")


if __name__ == "__main__":
    run()
