"""Table II analogue for the 10 assigned LM tenants: per-precision size and
fidelity (top-1 agreement vs the full-precision reference) — the accuracy
axis of each tenant's real model zoo."""
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.configs import ARCH_NAMES, get_config
from repro.models import transformer as T
from repro.quant.quantize import fidelity, params_nbytes, quantize_params


def run() -> None:
    key = jax.random.key(0)
    def fwd(c, p, b):
        return T.forward(c, p, b)[..., 0, :]
    for arch in ARCH_NAMES:
        cfg = get_config(arch, reduced=True)
        params = T.init_params(cfg, key, jnp.float32)
        shape = ((2, 24) if cfg.num_codebooks == 1
                 else (2, 24, cfg.num_codebooks))
        batch = {"tokens": jax.random.randint(key, shape, 0,
                                              cfg.vocab_size)}
        if cfg.frontend == "vision_stub":
            batch["patch_embeds"] = jax.random.normal(
                key, (2, cfg.num_vision_tokens, cfg.d_model))
        base = params_nbytes(params)
        t0 = time.perf_counter()
        parts = []
        for bits in (8, 4):
            q = quantize_params(params, bits=bits, group=32)
            f = fidelity(cfg, params, q, batch, fwd)
            parts.append(
                f"int{bits}:size={params_nbytes(q) / base:.2f}x,"
                f"agree={f['top1_agreement']:.1f}%")
        us = (time.perf_counter() - t0) * 1e6 / 2
        emit(f"quant_fidelity/{arch}", us, " ".join(parts))


if __name__ == "__main__":
    run()
