"""Serving-engine benchmark: trace-driven multi-tenant throughput under
memory contention (the system-level Table I analogue, now end-to-end).

Drives the event-driven :class:`ServingEngine` through its asyncio entry
point with a Poisson per-tenant trace (the simulator's arrival process),
real prefill/decode on reduced configs, and KV caches charged against the
Edge-MultiAI budget.  The whole stack is constructed declaratively —
``EdgeServer.build(ServingConfig(...))`` — so this file states *what* is
being measured and owns none of the wiring.  XLA compiles are pre-warmed
outside the timed trace (fixed prompt length bounds the shape set), so
the virtual clock sees steady-state service times and the trace runs
*unsaturated* — which is what gives the prefetch pipeline actual idle
windows to hide loads in, exactly the regime the paper's proactive
loading targets.

Serving runs under **BFE** (the paper's unload-based eviction): every
cold procure may fully evict an idle tenant, so the warm-start ratio
isolates what prefetching itself contributes — iWS-BFE's reactive
downgrade-instead-of-unload machinery already warm-starts without any
prefetcher (that effect is measured by the fig5 simulator benchmark),
which would mask the pipeline under test here.  Three engines run over
the *same* trace:

* **prefetch** — the background loading pipeline: predicted-next tenants
  staged ahead of their requests, cold tenants' demand loads overlapped
  with other tenants' execution;
* **reactive** — demand-only loading: every load enacted synchronously
  inside the admit path, stalling the loop for the transfer.  (PR-1
  also fired synchronous proactive loads between batches, but those
  were *uncharged* in virtual time — an infinitely fast loader — so
  they are excluded from the baseline rather than reproduced.)
* **batch-aware** — the prefetch engine under the ``batch-bfe`` Policy
  plugin: demand loads planned against the full-batch cache bound
  instead of the head-batch snapshot (the A/B for queue-depth-aware
  procurement; compare its ``kv_downgrades`` against the head-batch
  run's).
* **sharded** — the prefetch engine staging through the mesh-aware
  :class:`ShardedLoaderChannel` on an 8-way logical mesh: weights shard
  per device, loads decompose into per-shard stage operations, and
  per-device budget ledgers bound every chip.  Same total transfer time
  through the shared host link, so the A/B isolates the per-shard
  accounting: ``serving/sharded/load_overlap_ms`` must come out >= the
  single-stream loader's on the same trace (landed shards of cancelled
  loads are credited honestly; the single-stream loader credits a
  cancelled load nothing).
* **paged** — continuous batching against the paged KV pool, A/B'd
  against the batch-scalar engine on a deliberately KV-contended sim
  trace (budget too small to fund a whole max_batch cache, arrivals
  dense enough that the batching window forms full batches).  The
  batch-scalar engine admits the whole batch's cache as one scalar and
  rejects it wholesale; page-granular admission keeps accepting single
  requests.  ``serving/paged/kv_rejections`` is emitted as the
  *reduction* (scalar − paged, higher is better) so the one-sided gate
  can hold "strictly fewer rejections";
  ``serving/paged/warm_ratio`` must stay at least the scalar run's.
* **migration** — the sharded engine on a *device-skewed* mesh (chip 0
  deliberately tight, neighbors roomy), with cross-device victim
  migration on vs off.  With migration off, the tight chip fails every
  speculative staged load whole (the PR-4 clean-failure path) and the
  engine degrades to demand-time loading; with migration on, the
  ``MigrateShard`` planner moves a resident victim's shards to the free
  chips and the same loads land.  ``serving/migration/warm_ratio`` is
  the A/B row — its detail carries the downgrade-only run's warm ratio,
  ``shards_migrated``, and both runs' prefetch-hit counts, showing
  migration admits loads the downgrade-only path shrank or failed.
* **quantized** — the sharded sim engine with quantize-on-the-wire
  staging (``LoaderSpec(compress="int8")``) vs full-width staging on
  the same trace.  Every load ships the int8 payload + per-group
  scales host→chip and dequantizes on land, so each transfer's
  virtual ``load_ms`` shrinks by the wire ratio (~0.56× for bf16)
  while claims and ledgers still charge the resident footprint.
  ``serving/quantized/load_ms`` is emitted as the *reduction* in
  total committed wire milliseconds (full − compressed, higher is
  better) so the one-sided gate holds "compressed staging strictly
  reduces load_ms"; ``serving/quantized/warm_ratio`` must hold
  against the full-width run's.  Sim executors make the pair
  bit-deterministic.
* **elastic** — the sharded sim engine under a mid-trace chip-loss/
  recovery schedule (``FaultSpec``), A/B'd against the same trace with
  no faults.  The dead chip is drained through one transactional
  ``ResidencyPlan`` (shard migrations toward survivors, downgrades
  where nothing fits, KV-page evictions + preemption for sequences
  homed there) while the other tenants keep decoding, and recovery
  rebalances shards back toward the canonical layout.
  ``serving/elastic/warm_ratio`` must hold against the undisturbed
  run's.

Reports requests/sec and per-tenant p50/p95/p99 for the prefetch engine,
plus the head-to-head ``serving/warm_ratio`` and the measured
``serving/load_overlap_ms`` (load time hidden behind other tenants).

    PYTHONPATH=src python -m benchmarks.run serving_throughput
"""
import asyncio
import time

import numpy as np

from benchmarks.common import emit
from repro.serving import poisson_trace
from repro.serving.api import (BatchingSpec, EdgeServer, FaultSpec,
                               LoaderSpec, ServingConfig, TenantSpec)

TENANTS = ["tinyllama-1.1b", "mamba2-780m", "gemma2-2b"]
PROMPT_LEN = 8
MAX_NEW = 4


def _warm_compile(srv: EdgeServer, batch_sizes=(1, 2, 3, 4)) -> None:
    """Trace every (tenant, precision, batch) prefill/decode shape the
    run can hit, so compile time stays out of the measured service
    (the jit cache is process-global: later engine runs hit it)."""
    for tr in srv.tenants.values():
        for bits in tr.host:
            tr.set_variant(tr.zoo.by_bits(bits))
            for bsz in batch_sizes:
                tr.generate(np.zeros((bsz, PROMPT_LEN), np.int32), MAX_NEW)
        tr.set_variant(None)  # leave residency to the manager


def _run_engine(prefetch: bool, policy: str = "bfe",
                sharded: bool = False, device_budget_mb=None,
                migrate: bool = True):
    """One full engine run over the default Poisson trace."""
    srv = EdgeServer.build(ServingConfig(
        tenants=tuple(TenantSpec(n) for n in TENANTS),
        policy=policy,
        delta_ms=750.0,
        batching=BatchingSpec(max_batch=4, window_ms=50.0),
        loader=LoaderSpec(prefetch=prefetch, sharded=sharded,
                          mesh_shape=(8,),
                          device_budget_mb=device_budget_mb,
                          migrate=migrate),
        # Contended: all-bf16 residency impossible, so BFE keeps
        # evicting; headroom sized to the largest admitted decode cache.
        kv_headroom_shape=(2, PROMPT_LEN + MAX_NEW)))
    _warm_compile(srv)

    cfgs = {t.name: t.cfg for t in srv.tenants.values()}
    trace, _ = poisson_trace(
        cfgs, requests_per_app=12, mean_iat_ms=1000.0, deviation=0.3,
        seed=0, prompt_len=(PROMPT_LEN, PROMPT_LEN + 1), max_new=MAX_NEW)
    t0 = time.monotonic()
    stats = asyncio.run(srv.engine.run_async(trace))
    wall_s = time.monotonic() - t0
    srv.engine.check_event_invariant()
    srv.close()
    # ServingStats.to_dict() is the benchmark-facing flattening: the
    # emit details below index the historical keys.
    return srv, stats.to_dict(), wall_s


def _skewed_budgets(srv: EdgeServer, n: int = 8, tight: float = 0.7,
                    roomy: float = 3.0):
    """Per-chip budgets for the migration A/B: chip 0 holds every
    tenant's int8 shard plus only ``tight`` of the headroom a full-bf16
    residency needs (so bf16 staged loads block there), the other chips
    stay roomy enough to absorb a migrated victim shard."""
    from repro.distributed import sharding as SH

    mesh = SH.serving_mesh((n,))
    shard8 = shard16 = 0.0
    for tr in srv.tenants.values():
        frac = SH.weight_shard_fraction(tr.cfg, mesh)
        shard8 += tr.zoo.by_bits(8).size_mb * frac
        shard16 += tr.zoo.by_bits(16).size_mb * frac
    tight_mb = shard8 + tight * (shard16 - shard8)
    return (tight_mb,) + (roomy * shard16,) * (n - 1)


PAGED_TENANTS = ["tinyllama-1.1b", "mamba2-780m"]

# The elastic A/B's deterministic schedule; the seed sweep reuses it
# with ``prob`` armed, so each injector seed decides which scheduled
# downs actually fire.
FAULT_SCHEDULE = FaultSpec(events=((3000.0, 3, "down"), (9000.0, 3, "up")))
FAULT_SWEEP_PROB = 0.7
FAULT_SWEEP_SEEDS = range(8)
# The seed sweep's harsher script: a cascading two-chip loss with late
# recovery.  A single-chip loss is fully absorbed by the drain planner
# (zero warm dip on every seed); losing a second chip mid-recovery is
# what actually costs warm starts, so the sweep's p95 captures the
# tail of *which* scheduled downs the injector seed lets fire.
FAULT_SWEEP_SCHEDULE = FaultSpec(
    events=((1500.0, 3, "down"), (4000.0, 2, "down"), (9000.0, 3, "up")),
    prob=FAULT_SWEEP_PROB)


def _run_paged(continuous: bool):
    """One sim-executor run of the KV-contention trace: the derived
    budget minus the serving tenant's smallest weights cannot fund a
    full batch's cache, so the batch-scalar engine must reject where
    page-granular admission keeps going.  Sim executors make the pair
    bit-deterministic — the A/B isolates the admission unit."""
    srv = EdgeServer.build(ServingConfig(
        tenants=tuple(TenantSpec(n) for n in PAGED_TENANTS),
        executor="sim",
        budget_mb=0.30,
        batching=BatchingSpec(max_batch=8, window_ms=50.0,
                              continuous=continuous)))
    cfgs = {t.name: t.cfg for t in srv.tenants.values()}
    trace, _ = poisson_trace(cfgs, requests_per_app=24, mean_iat_ms=1.0,
                             seed=11, max_new=120)
    stats = srv.engine.run_trace(trace)
    srv.engine.check_event_invariant()
    srv.close()
    return stats.to_dict()


def _run_quantized(compress):
    """One sim-executor run of the quantize-on-the-wire A/B: all three
    tenants on a 4-chip sharded sim mesh under the unload-heavy BFE
    policy (more committed loads → more wire traffic to compress),
    staged compressed (``compress="int8"``) or full-width (``None``).
    The returned dict carries ``wire_ms`` — the total committed wire
    milliseconds from the loader's history (LoadRecord.load_ms is the
    *wire* transfer time, so compression shows up here directly)."""
    srv = EdgeServer.build(ServingConfig(
        tenants=tuple(TenantSpec(n) for n in TENANTS),
        executor="sim",
        policy="bfe",
        delta_ms=750.0,
        batching=BatchingSpec(max_batch=4, window_ms=20.0),
        loader=LoaderSpec(sharded=True, mesh_shape=(4,),
                          compress=compress),
        kv_headroom_shape=(2, 12)))
    cfgs = {t.name: t.cfg for t in srv.tenants.values()}
    trace, _ = poisson_trace(cfgs, requests_per_app=30, mean_iat_ms=400.0,
                             seed=7)
    stats = srv.engine.run_trace(trace)
    srv.engine.check_event_invariant()
    d = stats.to_dict()
    d["wire_ms"] = sum(rec.load_ms for rec in srv.engine.loader.history)
    srv.close()
    return d


def _run_elastic(fault):
    """One sim-executor run of the elastic trace on a 4-chip ledgered
    mesh.  With ``fault`` set, chip 3 dies mid-trace (drained through one
    transactional ResidencyPlan while the other tenants keep decoding)
    and comes back later (shards rebalanced toward the canonical
    layout); with ``fault=None`` the same trace runs undisturbed.  Sim
    executors make the pair bit-deterministic, so the A/B isolates what
    the loss/recovery cycle costs."""
    srv = EdgeServer.build(ServingConfig(
        tenants=tuple(TenantSpec(n) for n in PAGED_TENANTS),
        executor="sim",
        policy="iws-bfe",
        delta_ms=750.0,
        batching=BatchingSpec(max_batch=4, window_ms=20.0),
        loader=LoaderSpec(sharded=True, mesh_shape=(4,)),
        kv_headroom_shape=(2, 12),
        fault=fault))
    cfgs = {t.name: t.cfg for t in srv.tenants.values()}
    trace, _ = poisson_trace(cfgs, requests_per_app=30, mean_iat_ms=400.0,
                             seed=7)
    stats = srv.engine.run_trace(trace)
    srv.engine.check_event_invariant()
    srv.close()
    return stats.to_dict()


def _run_cluster(router: str):
    """One 3-server cluster run over the flash-crowd trace: every box
    registers the same three tenants, tinyllama's flood arrives
    unpredicted mid-trace.  Warm-aware routing keeps each tenant's
    requests on the server already holding its weights; round-robin
    sprays them, so every server churns every zoo.  Sim executors + one
    global clock make the pair bit-deterministic — the A/B isolates the
    routing policy."""
    from repro.cluster import ClusterConfig, EdgeCluster, RouterSpec
    from repro.core.simulator import generate_flash_crowd
    from repro.serving import trace_from_workload

    base = ServingConfig(
        tenants=tuple(TenantSpec(n) for n in TENANTS),
        policy="bfe",
        executor="sim")
    cfg = ClusterConfig.uniform(
        3, base, RouterSpec(name=router, handoff_queue=4))
    cluster = EdgeCluster.build(cfg)
    wl = generate_flash_crowd(
        TENANTS, requests_per_app=36, base_iat_ms=8000.0,
        burst_app=TENANTS[0], burst_requests=40, burst_iat_ms=100.0,
        seed=7)
    cfgs = {t.name: t.cfg for t in cluster.servers[0].tenants.values()}
    trace = trace_from_workload(wl, cfgs, seed=3,
                                prompt_len=(PROMPT_LEN, PROMPT_LEN + 1),
                                max_new=MAX_NEW)
    stats = cluster.run_trace(trace)
    cluster.check_event_invariant()
    cluster.close()
    return stats.to_dict()


def run() -> None:
    srv, stats, wall_s = _run_engine(prefetch=True)
    _, reactive, _ = _run_engine(prefetch=False)
    _, batch_aware, _ = _run_engine(prefetch=True, policy="batch-bfe")
    sharded_srv, sharded, _ = _run_engine(prefetch=True, sharded=True)
    skew = _skewed_budgets(srv)
    mig_srv, mig, _ = _run_engine(prefetch=True, sharded=True,
                                  device_budget_mb=skew, migrate=True)
    _, mig_off, _ = _run_engine(prefetch=True, sharded=True,
                                device_budget_mb=skew, migrate=False)

    emit("serving/requests_per_sec", stats.get("requests_per_sec", 0.0),
         f"n={stats['requests']} wall={wall_s:.1f}s "
         f"kv_rejections={stats['kv_rejections']} "
         f"kv_downgrades={stats['kv_downgrades']}")
    emit("serving/warm_ratio", stats["warm_ratio"],
         f"reactive={reactive['warm_ratio']:.3f} "
         f"prefetch_hits={stats['prefetch_hits']} "
         f"prefetch_wasted={stats['prefetch_wasted']} "
         f"demand_loads={stats['demand_loads']}")
    emit("serving/load_overlap_ms", stats["load_overlap_ms"],
         f"loads_committed={stats['loads_committed']} "
         f"reactive_warm={reactive['warm_ratio']:.3f} "
         f"prefetch_warm={stats['warm_ratio']:.3f}")
    # The batch-aware A/B: same trace, same prefetch pipeline, demand
    # loads planned over the full-batch cache bound.  Fewer self-
    # downgrades (thrash) at equal-or-better warm ratio is the win.
    emit("serving/batch_aware/warm_ratio", batch_aware["warm_ratio"],
         f"head_warm={stats['warm_ratio']:.3f} "
         f"kv_downgrades={batch_aware['kv_downgrades']} "
         f"head_kv_downgrades={stats['kv_downgrades']} "
         f"demand_loads={batch_aware['demand_loads']} "
         f"prediction_hit_rate={batch_aware['prediction_hit_rate']:.3f}")
    # The sharded A/B: same trace, same policy, weights staged per shard
    # across an 8-way mesh under per-device budgets.  Equal-or-better
    # warm ratio at equal-or-better measured overlap is the win.
    led = sharded_srv.manager.state.devices
    emit("serving/sharded/warm_ratio", sharded["warm_ratio"],
         f"single_stream={stats['warm_ratio']:.3f} "
         f"mesh=8 shards_landed={sharded['shards_landed']} "
         f"prefetch_shrunk={sharded['prefetch_shrunk']} "
         f"demand_loads={sharded['demand_loads']} "
         f"device_budget={led.budgets_mb[0]:.2f}MB")
    emit("serving/sharded/load_overlap_ms", sharded["load_overlap_ms"],
         f"single_stream={stats['load_overlap_ms']:.6g} "
         f"loads_committed={sharded['loads_committed']} "
         f"prefetch_wasted={sharded['prefetch_wasted']} "
         f"per_shard_credit="
         f"{sharded['load_overlap_ms'] - stats['load_overlap_ms']:.6g}")
    # The migration A/B: same trace, same sharded channel, chip 0
    # deliberately tight.  Downgrade-only (migrate off) fails every
    # speculative load the tight chip blocks; MigrateShard funds them.
    # The win is the admitted loads: prefetch hits recovered, warm ratio
    # at least on par, victims' shards rebalanced instead of loads lost.
    mig_led = mig_srv.manager.state.devices
    emit("serving/migration/warm_ratio", mig["warm_ratio"],
         f"downgrade_only={mig_off['warm_ratio']:.3f} "
         f"shards_migrated={mig['shards_migrated']} "
         f"prefetch_hits={mig['prefetch_hits']} "
         f"off_prefetch_hits={mig_off['prefetch_hits']} "
         f"demand_loads={mig['demand_loads']} "
         f"off_demand_loads={mig_off['demand_loads']} "
         f"tight_chip={mig_led.budgets_mb[0]:.2f}MB")
    # The paged A/B: request-unit admission against the page pool vs
    # whole-batch scalar admission, same KV-contended sim trace.  The
    # rejection row is the *reduction* (scalar − paged) so "strictly
    # fewer rejections" gates one-sided; the warm row holds the paged
    # engine to at least the scalar engine's warm ratio.
    scalar = _run_paged(continuous=False)
    paged = _run_paged(continuous=True)
    emit("serving/paged/kv_rejections",
         scalar["kv_rejections"] - paged["kv_rejections"],
         f"scalar={scalar['kv_rejections']} "
         f"paged={paged['kv_rejections']} "
         f"paged_preemptions={paged['kv_preemptions']} "
         f"pages={paged['kv_pages_total']}@"
         f"{paged['kv_page_mb']:.4f}MB "
         f"overrelease={paged['kv_overrelease_mb']:.4f}MB")
    emit("serving/paged/warm_ratio", paged["warm_ratio"],
         f"scalar={scalar['warm_ratio']:.3f} "
         f"scalar_rejections={scalar['kv_rejections']} "
         f"paged_rejections={paged['kv_rejections']}")
    # The quantized A/B: same trace, same 4-chip sim mesh, staging
    # compressed vs full-width.  The load_ms row is the reduction in
    # total committed wire milliseconds (full − compressed, one-sided:
    # compression must strictly shorten the transfers); the warm row
    # holds the compressed engine to the full-width run's ratio — a
    # shorter transfer can only make prefetches readier.
    quant = _run_quantized("int8")
    fullw = _run_quantized(None)
    emit("serving/quantized/load_ms", fullw["wire_ms"] - quant["wire_ms"],
         f"full_ms={fullw['wire_ms']:.6g} "
         f"compressed_ms={quant['wire_ms']:.6g} "
         f"wire_mb={quant['wire_mb_staged']:.2f} "
         f"full_wire_mb={fullw['wire_mb_staged']:.2f} "
         f"loads_committed={quant['loads_committed']} "
         f"full_loads_committed={fullw['loads_committed']}")
    emit("serving/quantized/warm_ratio", quant["warm_ratio"],
         f"full_width={fullw['warm_ratio']:.3f} "
         f"prefetch_hits={quant['prefetch_hits']} "
         f"full_prefetch_hits={fullw['prefetch_hits']} "
         f"load_overlap_ms={quant['load_overlap_ms']:.6g} "
         f"inplace_downgrades={quant['inplace_downgrades']}")
    # The elastic A/B: same trace, same 4-chip sim mesh, fault schedule
    # on vs off.  Chip 3 is drained mid-trace and recovered later; the
    # warm ratio must hold against the undisturbed run (the drain plan
    # rehomes shards instead of cold-starting tenants) and the detail
    # carries the loss/recovery counters.
    faulted = _run_elastic(FAULT_SCHEDULE)
    clean = _run_elastic(None)
    emit("serving/elastic/warm_ratio", faulted["warm_ratio"],
         f"no_fault={clean['warm_ratio']:.3f} "
         f"chips_lost={faulted['chips_lost']} "
         f"chips_recovered={faulted['chips_recovered']} "
         f"drain_migrations={faulted['drain_migrations']} "
         f"drain_downgrades={faulted['drain_downgrades']} "
         f"kv_rejections={faulted['kv_rejections']}")
    # The seed sweep: the same schedule with stochastic downs
    # (prob=0.7) across 8 injector seeds — one deterministic point
    # estimate says little about fault cost, so the row is the p95 of
    # the warm-ratio dip (clean − faulted) over the sweep, each seed a
    # bit-reproducible run on its own counter-based (seed, step)
    # stream.  Ungated: the dip's tail is reported context, the
    # deterministic warm_ratio row above is what gates.
    dips, per_seed = [], []
    for s in FAULT_SWEEP_SEEDS:
        swept = _run_elastic(FAULT_SWEEP_SCHEDULE.with_seed(s))
        dips.append(clean["warm_ratio"] - swept["warm_ratio"])
        per_seed.append(f"s{s}={swept['warm_ratio']:.3f}")
    emit("serving/elastic/p95_warm_dip", float(np.percentile(dips, 95)),
         f"clean={clean['warm_ratio']:.3f} prob={FAULT_SWEEP_PROB} "
         f"seeds={len(dips)} " + " ".join(per_seed))
    # The cluster A/B: same flash-crowd trace over the same 3-server
    # fleet, warm-aware routing vs round-robin.  Warm-aware reads only
    # the typed ServerView surface (residency/staging accuracy, queue
    # depths) and must beat the state-blind baseline's fleet-wide warm
    # ratio; the detail carries the routing/spill/hand-off counters.
    warm = _run_cluster("warm-aware")
    rr = _run_cluster("round-robin")
    wc, rc = warm["cluster"], rr["cluster"]
    emit("serving/cluster/warm_ratio", warm["warm_ratio"],
         f"round_robin={rr['warm_ratio']:.3f} "
         f"servers={wc['servers']} routed={wc['routed']} "
         f"spilled={wc['spilled']} handoffs={wc['handoffs']} "
         f"rr_spilled={rc['spilled']} "
         f"per_server={'/'.join(str(n) for n in wc['per_server_requests'])}")
    for app, s in stats["per_tenant"].items():
        emit(f"serving/{app}/p50_ms", s["p50_ms"],
             f"p95={s['p95_ms']:.1f}ms p99={s['p99_ms']:.1f}ms "
             f"warm={s['warm_ratio']:.2f} fail={s['fail_ratio']:.2f} "
             f"rps={s['throughput_rps']:.2f} "
             f"mean_batch={s['mean_batch']:.1f}")
    st = srv.manager.state
    emit("serving/resident_mb", st.used_mb,
         f"weights={st.weights_mb:.2f}MB kv={st.kv_mb:.2f}MB "
         f"budget={st.budget_mb:.2f}MB")


if __name__ == "__main__":
    run()
