"""Serving-runtime microbenchmark: warm vs cold request latency through the
real multi-tenant server (the system-level Table I analogue)."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.models import transformer as T
from repro.serving import MultiTenantServer


def run() -> None:
    srv = MultiTenantServer(budget_mb=1.2, policy="iws-bfe",
                            delta_ms=500.0)
    names = ["tinyllama-1.1b", "mamba2-780m"]
    for n in names:
        cfg = get_config(n, reduced=True)
        srv.register(n, cfg, T.init_params(cfg, jax.random.key(2),
                                           jnp.float32))
    srv.start()
    rng = np.random.default_rng(0)
    now = 0.0
    # alternate tenants under a budget that fits ~one model: every other
    # request swaps models (cold); repeats on the same tenant are warm.
    lat = {"warm": [], "cold": []}
    for i in range(12):
        n = names[(i // 3) % 2]  # 3 requests per tenant, then swap
        cfg = get_config(n, reduced=True)
        prompts = rng.integers(0, cfg.vocab_size, (2, 6)).astype(np.int32)
        r = srv.serve(n, prompts, max_new=4, now_ms=now)
        if not r.failed:
            lat["warm" if r.warm else "cold"].append(r.latency_s)
        now += 2000.0
    s = srv.stats()
    for kind, xs in lat.items():
        if xs:
            emit(f"serving/{kind}_latency",
                 float(np.mean(xs)) * 1e6,
                 f"n={len(xs)} mean={np.mean(xs) * 1e3:.1f}ms")
    emit("serving/stats", 0.0,
         f"warm_ratio={s['warm_ratio']:.2f} fail={s['fail_ratio']:.2f} "
         f"resident={s['resident_mb']:.2f}MB")


if __name__ == "__main__":
    run()
