"""Serving-engine benchmark: trace-driven multi-tenant throughput under
memory contention (the system-level Table I analogue, now end-to-end).

Drives the event-driven :class:`ServingEngine` through its asyncio entry
point with a Poisson per-tenant trace (the simulator's arrival process),
real prefill/decode on reduced configs, and KV caches charged against the
Edge-MultiAI budget.  Reports requests/sec plus per-tenant p50/p95/p99.

    PYTHONPATH=src python -m benchmarks.run serving_throughput
"""
import asyncio
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.models import transformer as T
from repro.serving import MultiTenantServer, kv_cache_mb, poisson_trace


def run() -> None:
    srv = MultiTenantServer(budget_mb=1.2, policy="iws-bfe",
                            delta_ms=500.0, max_batch=4,
                            batch_window_ms=50.0)
    names = ["tinyllama-1.1b", "mamba2-780m"]
    cfgs = {}
    for n in names:
        cfg = get_config(n, reduced=True)
        cfgs[n] = cfg
        srv.register(n, cfg, T.init_params(cfg, jax.random.key(2),
                                           jnp.float32))
    # Contended budget with KV headroom for a max-size batch of the most
    # cache-hungry tenant.
    kv = max(kv_cache_mb(c, srv.max_batch, 12 + 4) for c in cfgs.values())
    srv.budget_mb = srv.contention_budget(kv)
    srv.start()

    trace, wl = poisson_trace(cfgs, requests_per_app=12,
                              mean_iat_ms=1500.0, deviation=0.3,
                              seed=0, max_new=4)
    t0 = time.monotonic()
    stats = asyncio.run(srv.engine.run_async(trace))
    wall_s = time.monotonic() - t0
    srv.engine.check_event_invariant()

    emit("serving/requests_per_sec", stats.get("requests_per_sec", 0.0),
         f"n={stats['requests']} wall={wall_s:.1f}s "
         f"kv_rejections={stats['kv_rejections']} "
         f"kv_downgrades={stats['kv_downgrades']}")
    for app, s in stats["per_tenant"].items():
        emit(f"serving/{app}/p50_ms", s["p50_ms"],
             f"p95={s['p95_ms']:.1f}ms p99={s['p99_ms']:.1f}ms "
             f"warm={s['warm_ratio']:.2f} fail={s['fail_ratio']:.2f} "
             f"rps={s['throughput_rps']:.2f} "
             f"mean_batch={s['mean_batch']:.1f}")
    st = srv.manager.state
    emit("serving/resident_mb", st.used_mb,
         f"weights={st.weights_mb:.2f}MB kv={st.kv_mb:.2f}MB "
         f"budget={st.budget_mb:.2f}MB")


if __name__ == "__main__":
    run()
