"""CI benchmark gate: compare a ``benchmarks.run`` CSV against a
committed baseline and fail on regressions beyond tolerance.

    PYTHONPATH=src python -m benchmarks.check_regression \
        bench.csv benchmarks/BENCH_serving_baseline.json

The baseline JSON maps row names to::

    {"value": <committed measurement>,
     "min_ratio": 0.5,          # fail if measured < value * min_ratio
     "min_delta": 0.1}          # fail if measured < value - min_delta

Either bound may be omitted; when both are present the *looser* floor
wins (ratios absorb machine-speed differences for wall-clock metrics,
deltas suit bounded ratios like warm_ratio).  Rows in the baseline but
missing from the CSV are hard failures — a silently dropped metric must
not read as a pass.  Improvements never fail: the gate is one-sided, and
the committed value should be refreshed deliberately, not ratcheted by
CI noise.

When ``GITHUB_STEP_SUMMARY`` is set (the bench-smoke job), a
baseline-vs-PR delta table is appended to the job summary — gated rows
with their floors and status, plus the ungated measured rows for
context.
"""
from __future__ import annotations

import json
import os
import sys
from typing import Dict, List, Tuple


def parse_csv(path: str) -> Dict[str, float]:
    """``name,value,detail`` rows (the benchmarks.common.emit schema);
    keeps the first occurrence of each name and skips the header plus
    any interleaved non-CSV output."""
    out: Dict[str, float] = {}
    with open(path) as fh:
        for line in fh:
            parts = line.rstrip("\n").split(",", 2)
            if len(parts) < 2 or parts[0] == "name":
                continue
            try:
                value = float(parts[1])
            except ValueError:
                continue
            out.setdefault(parts[0], value)
    return out


def floor_for(spec: dict) -> Tuple[float, str]:
    """The pass/fail floor for one baseline entry (looser bound wins)."""
    value = float(spec["value"])
    floors = []
    if "min_ratio" in spec:
        floors.append((value * float(spec["min_ratio"]),
                       f"{spec['min_ratio']}x of {value:g}"))
    if "min_delta" in spec:
        floors.append((value - float(spec["min_delta"]),
                       f"{value:g} - {spec['min_delta']}"))
    if not floors:
        return value, f"{value:g} (exact floor)"
    return min(floors, key=lambda f: f[0])


def write_step_summary(measured: Dict[str, float], baseline: dict,
                       rows: List[Tuple[str, str]], path: str) -> None:
    """Append a baseline-vs-PR delta table to the GitHub job summary."""
    lines = ["## Serving benchmark: baseline vs PR", "",
             "| metric | baseline | PR | delta | floor | status |",
             "|---|---:|---:|---:|---:|:---:|"]
    for name, status in rows:
        spec = baseline[name]
        base = float(spec["value"])
        floor, _ = floor_for(spec)
        if name in measured:
            got = measured[name]
            delta = got - base
            lines.append(
                f"| `{name}` | {base:g} | {got:g} | {delta:+g} "
                f"| {floor:g} | {status} |")
        else:
            lines.append(f"| `{name}` | {base:g} | _missing_ | — "
                         f"| {floor:g} | {status} |")
    ungated = sorted(set(measured) - set(baseline))
    if ungated:
        lines += ["", "ungated rows (context only):", "",
                  "| metric | PR |", "|---|---:|"]
        lines += [f"| `{n}` | {measured[n]:g} |" for n in ungated]
    with open(path, "a") as fh:
        fh.write("\n".join(lines) + "\n")


def main(csv_path: str, baseline_path: str) -> int:
    measured = parse_csv(csv_path)
    with open(baseline_path) as fh:
        baseline = json.load(fh)
    failures = []
    summary_rows: List[Tuple[str, str]] = []
    for name, spec in baseline.items():
        floor, how = floor_for(spec)
        if name not in measured:
            failures.append(f"{name}: missing from {csv_path}")
            summary_rows.append((name, "❌ missing"))
            continue
        got = measured[name]
        status = "OK  " if got >= floor else "FAIL"
        print(f"{status} {name}: measured={got:g} floor={floor:g} ({how})")
        summary_rows.append((name, "✅" if got >= floor else "❌"))
        if got < floor:
            failures.append(
                f"{name}: {got:g} < floor {floor:g} ({how})")
    summary_path = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary_path:
        write_step_summary(measured, baseline, summary_rows, summary_path)
    if failures:
        print("\nbenchmark regression gate FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print(f"\nbenchmark regression gate passed "
          f"({len(baseline)} metrics checked)")
    return 0


if __name__ == "__main__":
    if len(sys.argv) != 3:
        sys.exit("usage: python -m benchmarks.check_regression "
                 "<bench.csv> <baseline.json>")
    sys.exit(main(sys.argv[1], sys.argv[2]))
