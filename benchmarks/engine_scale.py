"""Engine-scale benchmark: a 10^5-request workload-zoo replay through
the fast-path serving engine.

The workload is the zoo generator's mixed stream — diurnal
(sinusoidal-rate) Poisson arrivals for four tenants plus a flash crowd
on the first — materialized by the vectorized trace builder
(:func:`repro.serving.fast_trace_from_workload`; the sim executor reads
only prompt *lengths*, so prompt arrays are pooled).  The engine runs
continuous batching on sim executors with ``audit="counters"`` and the
indexed scheduler: loader readiness answered from the lazy-deletion
heap, prediction triggers memoized per ``(history, fits, last_time)``
generation, load overlap folded online instead of rescanned at reap,
per-event snapshots skipped.  Predictor background fits are disabled
(``min_fit_samples`` past the trace) so the replay measures the engine
loop, not RNN training.

Two rows:

* ``perf/engine/replay_rps`` — full-scale requests/sec of wall clock.
  The detail carries the A/B on a smaller shared trace: the same
  workload replayed by this engine and by the retained pre-refactor
  reference path (``scheduler="linear"``, ``audit="full"`` — the exact
  per-step rescans the old engine ran), whose bit-identical results the
  equivalence suite asserts.  ``speedup`` is indexed/linear on that
  shared trace.
* ``perf/engine/events_per_sec`` — engine events processed per wall
  second on the full-scale replay (``events_emitted`` spans submits,
  commits, retirements, faults — the event-loop's actual tick rate).

Env knobs for CI sizing: ``ENGINE_SCALE_N`` (total requests, default
100000), ``ENGINE_SCALE_BASELINE_N`` (A/B trace size, default 12000 —
large enough that the reference path's per-pass history rescans carry
their real asymptotic weight, small enough to finish in CI time).

    PYTHONPATH=src python -m benchmarks.run engine_scale
"""
import os
import time

from benchmarks.common import emit
from repro.core.simulator import generate_zoo
from repro.serving import fast_trace_from_workload
from repro.serving.api import (BatchingSpec, EdgeServer, PredictorSpec,
                               ServingConfig, TenantSpec)

TENANTS = ["tinyllama-1.1b", "mamba2-780m", "gemma2-2b", "hymba-1.5b"]
MEAN_IAT_MS = 6.0
MAX_NEW = 6


def _trace(n_total: int):
    """The mixed zoo stream at ``n_total`` requests: diurnal baseline
    per tenant, one unpredicted flash crowd on the first."""
    per_app = max(n_total // (len(TENANTS) + 1), 1)
    burst = n_total - per_app * len(TENANTS)
    wl = generate_zoo(TENANTS, requests_per_app=per_app,
                      mean_iat_ms=MEAN_IAT_MS, amplitude=0.6,
                      burst_requests=burst, burst_iat_ms=0.5, seed=3)
    return wl


def _run(trace, scheduler: str, audit: str):
    """One engine replay; returns (stats dict, wall seconds, events)."""
    srv = EdgeServer.build(ServingConfig(
        tenants=tuple(TenantSpec(n) for n in TENANTS),
        executor="sim",
        policy="iws-bfe",
        delta_ms=750.0,
        batching=BatchingSpec(max_batch=8, window_ms=20.0,
                              continuous=True),
        # Fits off: the replay measures the engine loop, not the RNN's
        # background training schedule.
        predictor=PredictorSpec(min_fit_samples=10**9),
        kv_headroom_shape=(2, 12),
        audit=audit, scheduler=scheduler))
    cfgs = {t.name: t.cfg for t in srv.tenants.values()}
    reqs = fast_trace_from_workload(trace, cfgs, seed=1, max_new=MAX_NEW)
    t0 = time.perf_counter()
    stats = srv.engine.run_trace(reqs)
    wall = time.perf_counter() - t0
    events = srv.engine.events_emitted
    srv.close()
    return stats.to_dict(), wall, events


def run() -> None:
    n_total = int(os.environ.get("ENGINE_SCALE_N", "100000"))
    n_base = int(os.environ.get("ENGINE_SCALE_BASELINE_N", "12000"))
    # The A/B: one shared smaller trace through both paths — the linear
    # reference rescans per step (quadratic in history/loads), so it is
    # measured at a size it finishes in CI time.
    small = _trace(n_base)
    fast_small, fast_small_wall, _ = _run(small, "indexed", "counters")
    lin_small, lin_wall, _ = _run(small, "linear", "full")
    fast_rps_small = fast_small["requests"] / fast_small_wall
    lin_rps = lin_small["requests"] / lin_wall
    speedup = fast_rps_small / lin_rps
    # Full scale, fast path only.
    full = _trace(n_total)
    stats, wall, events = _run(full, "indexed", "counters")
    rps = stats["requests"] / wall
    emit("perf/engine/replay_rps", rps,
         f"n={stats['requests']} wall={wall:.2f}s "
         f"warm={stats['warm_ratio']:.3f} "
         f"speedup={speedup:.1f}x (indexed={fast_rps_small:.0f}rps "
         f"linear={lin_rps:.0f}rps n={lin_small['requests']})")
    emit("perf/engine/events_per_sec", events / wall,
         f"events={events} wall={wall:.2f}s audit=counters "
         f"replay_rps={rps:.0f}")


if __name__ == "__main__":
    run()
