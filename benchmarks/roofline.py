"""Roofline table: reads the dry-run JSON cache (results/dryrun.json) and
prints the three terms per (arch × shape) on the single-pod mesh."""
import json
import os
import time

from benchmarks.common import emit

_RESULTS = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "results")
# optimized table preferred; baseline kept for §Perf before/after
_CANDIDATES = [os.path.join(_RESULTS, n) for n in
               ("dryrun_optimized.json", "dryrun.json",
                "dryrun_baseline.json")]


def run(path: str = None) -> None:
    if path is None:
        found = [p for p in _CANDIDATES if os.path.exists(p)]
        if not found:
            emit("roofline/missing", 0.0,
                 "run `python -m repro.launch.dryrun --all --both-meshes "
                 "--out results/dryrun_optimized.json` first")
            return
        path = found[0]
    with open(path) as f:
        cells = json.load(f)
    emit("roofline/source", 0.0, os.path.basename(path))
    t0 = time.perf_counter()
    single = [c for c in cells if not c["multi_pod"]]
    for c in sorted(single, key=lambda c: (c["arch"], c["shape"])):
        name = f"roofline/{c['arch']}/{c['shape']}"
        if c["status"].startswith("SKIP"):
            emit(name, 0.0, c["status"])
            continue
        if c["status"] != "OK" or "roofline" not in c:
            emit(name, 0.0, f"{c['status']} {c.get('error', '')[:80]}")
            continue
        r = c["roofline"]
        mem = c.get("memory", {})
        emit(name, r["bound_s"] * 1e6,
             f"compute={r['compute_s'] * 1e3:.2f}ms "
             f"memory={r['memory_s'] * 1e3:.2f}ms "
             f"coll={r['collective_s'] * 1e3:.2f}ms "
             f"dominant={r['dominant']} useful={r['useful_ratio']:.2f} "
             f"hbm={mem.get('hbm_fraction', 0) * 100:.0f}%")
    mp = [c for c in cells if c["multi_pod"]]
    ok = sum(c["status"] == "OK" for c in mp)
    skip = sum(c["status"].startswith("SKIP") for c in mp)
    emit("roofline/multi_pod_gate", (time.perf_counter() - t0) * 1e6,
         f"{ok}_ok {skip}_skip {len(mp) - ok - skip}_fail of {len(mp)}")


if __name__ == "__main__":
    run()
