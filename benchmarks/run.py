"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Invoke as
``PYTHONPATH=src python -m benchmarks.run`` (all) or with module names:
``python -m benchmarks.run fig5_6_8_policies roofline``.

``python -m benchmarks.run --list`` prints the available benchmark
names with what each measures and the row-name prefixes it emits —
useful for picking which rows to gate in
``benchmarks/BENCH_serving_baseline.json``.
"""
import inspect
import re
import sys
import traceback

from benchmarks import (engine_scale, fig4_multitenancy, fig5_6_8_policies,
                        fig7_pareto, fig9_10_fairness, perf_compare,
                        quant_fidelity, roofline, serving_throughput,
                        table1_load_vs_infer)

MODULES = {
    "table1_load_vs_infer": table1_load_vs_infer,
    "fig4_multitenancy": fig4_multitenancy,
    "fig5_6_8_policies": fig5_6_8_policies,
    "fig7_pareto": fig7_pareto,
    "fig9_10_fairness": fig9_10_fairness,
    "quant_fidelity": quant_fidelity,
    "serving_throughput": serving_throughput,
    "engine_scale": engine_scale,
    "roofline": roofline,
    "perf_compare": perf_compare,
}


def row_prefixes(module) -> list:
    """Row-name prefixes a benchmark emits, scraped from its source.

    Matches the first argument of each ``emit("...")`` call; f-string
    names are truncated at the first ``{`` so dynamic suffixes (policy
    names, model ids) collapse into one prefix.
    """
    src = inspect.getsource(module)
    names = re.findall(r'emit\(\s*f?"([^"{]+)', src)
    seen: dict = {}
    for n in names:
        seen.setdefault(n.rstrip("/"), None)
    return list(seen)


def list_benchmarks() -> None:
    """Print each benchmark name, its one-line summary, and the row
    prefixes it emits (the names gated by the baseline JSON)."""
    for name, module in MODULES.items():
        summary = (module.__doc__ or "").strip().splitlines()[0]
        print(f"{name}: {summary}")
        for prefix in row_prefixes(module):
            print(f"    {prefix}")


def main() -> None:
    if "--list" in sys.argv[1:]:
        list_benchmarks()
        return
    names = sys.argv[1:] or list(MODULES)
    print("name,us_per_call,derived")
    failed = []
    for name in names:
        try:
            MODULES[name].run()
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()
