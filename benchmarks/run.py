"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Invoke as
``PYTHONPATH=src python -m benchmarks.run`` (all) or with module names:
``python -m benchmarks.run fig5_6_8_policies roofline``.
"""
import sys
import traceback

from benchmarks import (fig4_multitenancy, fig5_6_8_policies, fig7_pareto,
                        fig9_10_fairness, perf_compare, quant_fidelity,
                        roofline, serving_throughput, table1_load_vs_infer)

MODULES = {
    "table1_load_vs_infer": table1_load_vs_infer,
    "fig4_multitenancy": fig4_multitenancy,
    "fig5_6_8_policies": fig5_6_8_policies,
    "fig7_pareto": fig7_pareto,
    "fig9_10_fairness": fig9_10_fairness,
    "quant_fidelity": quant_fidelity,
    "serving_throughput": serving_throughput,
    "roofline": roofline,
    "perf_compare": perf_compare,
}


def main() -> None:
    names = sys.argv[1:] or list(MODULES)
    print("name,us_per_call,derived")
    failed = []
    for name in names:
        try:
            MODULES[name].run()
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()
