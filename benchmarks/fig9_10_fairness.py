"""Paper Figs 9 & 10: per-application cold-start % and accuracy — the
fairness analysis (no tenant may be starved or systematically degraded)."""
import time

from benchmarks.common import emit
from repro.configs.paper_edge import DEFAULT_MEMORY_MB, paper_zoos
from repro.core import generate_workload, simulate


def run() -> None:
    zoos = paper_zoos()
    t0 = time.perf_counter()
    wl = generate_workload(list(zoos), requests_per_app=60, deviation=0.3,
                           seed=0)
    for policy in ("none", "lfe", "ws-bfe", "iws-bfe"):
        res = simulate(zoos, wl, policy=policy,
                       budget_mb=DEFAULT_MEMORY_MB)
        per = res.metrics.per_app()
        us = (time.perf_counter() - t0) * 1e6
        colds = [v["cold_ratio"] + v["fail_ratio"] for v in per.values()]
        accs = [v["norm_accuracy"] for v in per.values()]
        spread_c = max(colds) - min(colds)
        spread_a = max(accs) - min(accs)
        emit(f"fig9_10/{policy}", us,
             f"cold_spread={spread_c:.3f} acc_spread={spread_a:.3f} " +
             " ".join(f"{a}:c={v['cold_ratio']:.2f}/a={v['norm_accuracy']:.2f}"
                      for a, v in per.items()))


if __name__ == "__main__":
    run()
