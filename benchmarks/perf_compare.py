"""§Perf before/after: baseline vs optimized roofline tables side by side.

Reads results/dryrun_baseline.json and results/dryrun_optimized.json and
emits per-cell dominant-term speedups.
"""
import json
import os

from benchmarks.common import emit

_RESULTS = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "results")


def _load(name):
    path = os.path.join(_RESULTS, name)
    if not os.path.exists(path):
        return {}
    with open(path) as f:
        cells = json.load(f)
    return {(c["arch"], c["shape"]): c for c in cells
            if not c["multi_pod"] and c["status"] == "OK"
            and "roofline" in c}


def run() -> None:
    base = _load("dryrun_baseline.json")
    opt = _load("dryrun_optimized.json")
    if not base or not opt:
        emit("perf_compare/missing", 0.0, "need both dryrun json files")
        return
    speedups = []
    for key in sorted(base):
        if key not in opt:
            continue
        b, o = base[key]["roofline"], opt[key]["roofline"]
        sp = b["bound_s"] / max(o["bound_s"], 1e-12)
        speedups.append(sp)
        hbm_b = base[key]["memory"].get("hbm_fraction", 0) * 100
        hbm_o = opt[key]["memory"].get("hbm_fraction", 0) * 100
        emit(f"perf/{key[0]}/{key[1]}", o["bound_s"] * 1e6,
             f"bound {b['bound_s'] * 1e3:.1f}ms->{o['bound_s'] * 1e3:.1f}ms "
             f"({sp:.2f}x) dominant {b['dominant']}->{o['dominant']} "
             f"useful {b['useful_ratio']:.2f}->{o['useful_ratio']:.2f} "
             f"hbm {hbm_b:.0f}%->{hbm_o:.0f}%")
    if speedups:
        import math
        geo = math.exp(sum(math.log(s) for s in speedups) / len(speedups))
        emit("perf/geomean_speedup", 0.0,
             f"{geo:.2f}x over {len(speedups)} cells")


if __name__ == "__main__":
    run()
