"""§Perf before/after: baseline vs optimized roofline tables side by side,
plus wall-clock staging rows for the serving loader channels.

Reads results/dryrun_baseline.json and results/dryrun_optimized.json and
emits per-cell dominant-term speedups.  The loader rows measure real
host→device transfer (``jax.device_put``) of a *non-reduced* variant's
byte count through the three staging paths — synchronous (admission-path
``stage_sync``), background (enqueue-side blocking vs total), and the
sharded channel's per-device streams — so the load/infer asymmetry the
framework exploits is measured at production size, not the reduced test
configs.  ``PERF_LOADER_ARCH`` picks the tenant (default tinyllama),
``PERF_LOADER_MB`` caps the staged bytes (default 256 MB) so the row
stays runnable on small machines; the cap is reported in the detail.
"""
import json
import os
import time

from benchmarks.common import emit

_RESULTS = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "results")


def _loader_staging_rows() -> None:
    """ROADMAP item: wall-clock stage_sync vs background vs sharded
    staging on a larger (non-reduced) config."""
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.core.model_zoo import zoo_from_config
    from repro.serving.loader import BackgroundLoader
    from repro.serving.sharded_loader import ShardedLoaderChannel

    arch = os.environ.get("PERF_LOADER_ARCH", "tinyllama-1.1b")
    cap_mb = float(os.environ.get("PERF_LOADER_MB", "256"))
    n_dev = int(os.environ.get("PERF_LOADER_DEVICES", "8"))
    cfg = get_config(arch, reduced=False)
    variant = zoo_from_config(cfg, precisions=(16, 8)).by_bits(8)
    mb = min(variant.size_mb, cap_mb)
    nbytes = (int(mb) * 1024 * 1024 // n_dev) * n_dev
    host = np.ones(nbytes, np.uint8)
    chunks = host.reshape(n_dev, -1)
    detail = (f"arch={arch} staged={nbytes / 2**20:.0f}MB "
              f"of int8 variant {variant.size_mb:.0f}MB")

    def put_all(app, v):
        jax.device_put(host).block_until_ready()

    def put_shard(app, v, d, n):
        jax.device_put(chunks[d]).block_until_ready()

    def best(fn, reps=3):
        times = []
        for _ in range(reps):
            t0 = time.perf_counter()
            fn()
            times.append((time.perf_counter() - t0) * 1e3)
        return min(times)

    jax.device_put(host[:1024]).block_until_ready()  # warm dispatch
    loader = BackgroundLoader(None, stage_fn=put_all)
    sync_ms = best(lambda: loader.stage_sync(arch, None))
    hot, total = [], []
    for _ in range(3):
        t0 = time.perf_counter()
        fut = loader.stage(arch, None)
        hot.append((time.perf_counter() - t0) * 1e3)
        fut.result()
        total.append((time.perf_counter() - t0) * 1e3)
    loader.close()
    sharded = ShardedLoaderChannel(None, n_devices=n_dev,
                                   stage_shard_fn=put_shard)
    shard_ms = best(lambda: sharded.stage_shards_sync(arch, None))
    sharded.close()

    emit("perf/loader/stage_sync_ms", sync_ms, detail)
    emit("perf/loader/background_hotpath_ms", min(hot),
         f"enqueue-side blocking; total={min(total):.3g}ms "
         f"({sync_ms / max(min(hot), 1e-9):.0f}x off the hot path)")
    emit("perf/loader/sharded_stream_ms", shard_ms,
         f"{n_dev} device streams; {sync_ms / max(shard_ms, 1e-9):.2f}x "
         f"vs stage_sync (host-side; per-chip DMA on a real mesh)")


def _load(name):
    path = os.path.join(_RESULTS, name)
    if not os.path.exists(path):
        return {}
    with open(path) as f:
        cells = json.load(f)
    return {(c["arch"], c["shape"]): c for c in cells
            if not c["multi_pod"] and c["status"] == "OK"
            and "roofline" in c}


def run() -> None:
    _loader_staging_rows()
    base = _load("dryrun_baseline.json")
    opt = _load("dryrun_optimized.json")
    if not base or not opt:
        emit("perf_compare/missing", 0.0, "need both dryrun json files")
        return
    speedups = []
    for key in sorted(base):
        if key not in opt:
            continue
        b, o = base[key]["roofline"], opt[key]["roofline"]
        sp = b["bound_s"] / max(o["bound_s"], 1e-12)
        speedups.append(sp)
        hbm_b = base[key]["memory"].get("hbm_fraction", 0) * 100
        hbm_o = opt[key]["memory"].get("hbm_fraction", 0) * 100
        emit(f"perf/{key[0]}/{key[1]}", o["bound_s"] * 1e6,
             f"bound {b['bound_s'] * 1e3:.1f}ms->{o['bound_s'] * 1e3:.1f}ms "
             f"({sp:.2f}x) dominant {b['dominant']}->{o['dominant']} "
             f"useful {b['useful_ratio']:.2f}->{o['useful_ratio']:.2f} "
             f"hbm {hbm_b:.0f}%->{hbm_o:.0f}%")
    if speedups:
        import math
        geo = math.exp(sum(math.log(s) for s in speedups) / len(speedups))
        emit("perf/geomean_speedup", 0.0,
             f"{geo:.2f}x over {len(speedups)} cells")


if __name__ == "__main__":
    run()
