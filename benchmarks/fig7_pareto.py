"""Paper Fig 7: bi-objective (cold-start % vs model error) Pareto analysis
over the window parameter Δ = D + α·σ, α ∈ [0, 2], at 30% deviation."""
import time

import numpy as np

from benchmarks.common import emit
from repro.configs.paper_edge import DEFAULT_MEMORY_MB, paper_zoos
from repro.core import generate_workload, simulate


def run() -> None:
    zoos = paper_zoos()
    apps = list(zoos)
    points = {}
    t0 = time.perf_counter()
    for policy in ("lfe", "ws-bfe", "iws-bfe"):
        for alpha in (0.0, 0.5, 1.02, 1.5, 2.0):
            cold, err = [], []
            for seed in (0, 1):
                wl = generate_workload(apps, requests_per_app=40,
                                       deviation=0.3, seed=seed)
                res = simulate(zoos, wl, policy=policy, alpha=alpha,
                               budget_mb=DEFAULT_MEMORY_MB)
                m = res.metrics
                cold.append(m.cold_ratio + m.fail_ratio)
                err.append(1.0 - m.mean_accuracy())
            points[(policy, alpha)] = (float(np.mean(cold)),
                                       float(np.mean(err)))
    us = (time.perf_counter() - t0) * 1e6 / len(points)
    # Pareto front: points not dominated by any other
    front = []
    for k, (c, e) in points.items():
        if not any(c2 <= c and e2 <= e and (c2, e2) != (c, e)
                   for c2, e2 in points.values()):
            front.append(k)
    for (policy, alpha), (c, e) in sorted(points.items()):
        tag = "PARETO" if (policy, alpha) in front else "dominated"
        emit(f"fig7/{policy}/a{alpha}", us,
             f"cold={c:.3f} err={e:.3f} {tag}")
    on_front = {p for p, _ in front}
    emit("fig7/front", us, f"policies_on_front={sorted(on_front)}")


if __name__ == "__main__":
    run()
