"""Paper Table I analogue: load time vs inference time vs size per zoo
variant — measured on REAL reduced models (storage = disk, memory = device)
to validate the load≫infer asymmetry that motivates Edge-MultiAI."""
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.models import transformer as T
from repro.quant.quantize import params_nbytes, quantize_params


def _save_tree(tree, d):
    flat, _ = jax.tree.flatten(tree)
    for i, leaf in enumerate(flat):
        np.save(os.path.join(d, f"{i}.npy"), np.asarray(leaf))


def _load_tree(template, d):
    import ml_dtypes

    flat, treedef = jax.tree.flatten(template)
    out = []
    for i, leaf in enumerate(flat):
        arr = np.load(os.path.join(d, f"{i}.npy"))
        if arr.dtype.kind == "V":  # numpy stores bf16 as void16
            arr = arr.view(ml_dtypes.bfloat16)
        out.append(jnp.asarray(arr))
    tree = treedef.unflatten(out)
    jax.block_until_ready(tree)
    return tree


def run() -> None:
    for arch in ("tinyllama-1.1b", "gemma2-2b", "mamba2-780m"):
        cfg = get_config(arch, reduced=True)
        params = T.init_params(cfg, jax.random.key(0), jnp.float32)
        tokens = jnp.zeros((1, 32), jnp.int32)
        batch = {"tokens": tokens}
        fwd = jax.jit(lambda p, b: T.forward(cfg, p, b))
        for bits in (16, 8):
            variant = quantize_params(params, bits=bits, group=32)
            size_mb = params_nbytes(variant) / 2 ** 20
            with tempfile.TemporaryDirectory() as d:
                _save_tree(variant, d)
                t0 = time.perf_counter()
                loaded = _load_tree(variant, d)
                load_ms = (time.perf_counter() - t0) * 1e3
            out = fwd(loaded, batch)
            jax.block_until_ready(out)  # compile
            t0 = time.perf_counter()
            for _ in range(5):
                jax.block_until_ready(fwd(loaded, batch))
            infer_ms = (time.perf_counter() - t0) / 5 * 1e3
            ratio = load_ms / max(infer_ms, 1e-9)
            emit(f"table1/{arch}/int{bits}", infer_ms * 1e3,
                 f"size={size_mb:.2f}MB load={load_ms:.1f}ms "
                 f"infer={infer_ms:.1f}ms load/infer={ratio:.1f}x")


if __name__ == "__main__":
    run()
