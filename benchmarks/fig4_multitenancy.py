"""Paper Fig 4: multi-tenancy satisfaction rate (warm-start %) versus
requested workload intensity — no-policy vs Edge-MultiAI (iWS-BFE)."""
import time

import numpy as np

from benchmarks.common import emit
from repro.configs.paper_edge import DEFAULT_MEMORY_MB, paper_zoos
from repro.core import generate_workload, simulate


def run() -> None:
    zoos = paper_zoos()
    apps = list(zoos)
    # intensity knob: shorter inter-arrival => higher concurrency
    for iat in (24000.0, 12000.0, 8000.0, 5000.0, 3000.0):
        rows = {}
        t0 = time.perf_counter()
        for policy in ("none", "iws-bfe"):
            warm, conc = [], []
            for seed in (0, 1, 2):
                wl = generate_workload(apps, requests_per_app=40,
                                       mean_iat_ms=iat, deviation=0.2,
                                       seed=seed)
                res = simulate(zoos, wl, policy=policy,
                               budget_mb=DEFAULT_MEMORY_MB)
                warm.append(res.metrics.warm_ratio)
                conc.append(res.mean_concurrency)
            rows[policy] = (float(np.mean(warm)), float(np.mean(conc)))
        us = (time.perf_counter() - t0) * 1e6 / 6
        gain = rows["iws-bfe"][0] / max(rows["none"][0], 1e-9)
        emit(f"fig4/iat{int(iat)}", us,
             f"conc={rows['iws-bfe'][1]:.2f} none={rows['none'][0]:.3f} "
             f"iws={rows['iws-bfe'][0]:.3f} gain={gain:.2f}x")


if __name__ == "__main__":
    run()
