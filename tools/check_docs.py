"""Docs-drift gate: the README must stay runnable and the docs must
only name symbols that exist.

Two checks, wired into the CI ``lint`` job:

1. **Quickstart executes.**  The first fenced ``python`` block in
   ``README.md`` is run as a subprocess (``PYTHONPATH=src``, under a
   timeout).  A README whose 30-second example no longer runs is worse
   than no README.

2. **Named symbols resolve.**  Every backticked dotted path starting
   with ``repro.`` or ``benchmarks.`` in ``README.md`` and ``docs/*.md``
   is resolved by importing the longest module prefix and walking the
   rest with ``getattr``; every backticked ``ClassName.field`` /
   ``ClassName(field=...)`` reference whose class lives in the public
   config surface is checked against the real dataclass fields.  Rename
   a config field without updating the docs and this fails.

    PYTHONPATH=src python tools/check_docs.py
"""
from __future__ import annotations

import dataclasses
import importlib
import os
import re
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
# The docs name repo-root packages (benchmarks.*) and src ones (repro.*).
for p in (REPO, os.path.join(REPO, "src")):
    if p not in sys.path:
        sys.path.insert(0, p)

# The public config surface: backticked `ClassName.x` / `ClassName(x=1)`
# docs references are validated against these classes' real attributes.
PUBLIC_CLASSES = {
    "ServingConfig": "repro.serving.api",
    "TenantSpec": "repro.serving.api",
    "BatchingSpec": "repro.serving.api",
    "LoaderSpec": "repro.serving.api",
    "PredictorSpec": "repro.serving.api",
    "FaultSpec": "repro.serving.elastic",
    "ClusterConfig": "repro.cluster.config",
    "RouterSpec": "repro.cluster.config",
    "ServingStats": "repro.serving.stats",
    "ResidencyPlan": "repro.core.actions",
    "Downgrade": "repro.core.actions",
    "Load": "repro.core.actions",
    "MemoryState": "repro.core.memory_state",
}

DOTTED = re.compile(r"`(?:~?)((?:repro|benchmarks)(?:\.[A-Za-z_]\w*)+)")
CLASS_REF = re.compile(r"`([A-Z]\w+)(?:\.(\w+)|\((\w+)=)")


def doc_files() -> list:
    out = [os.path.join(REPO, "README.md")]
    docs = os.path.join(REPO, "docs")
    if os.path.isdir(docs):
        out += [os.path.join(docs, f) for f in sorted(os.listdir(docs))
                if f.endswith(".md")]
    return out


def resolve_dotted(path: str) -> str | None:
    """Import the longest module prefix, getattr the rest; an error
    string on failure, None when the path resolves."""
    parts = path.split(".")
    for cut in range(len(parts), 0, -1):
        modname = ".".join(parts[:cut])
        try:
            obj = importlib.import_module(modname)
        except ImportError:
            continue
        try:
            for attr in parts[cut:]:
                obj = getattr(obj, attr)
        except AttributeError as e:
            return str(e)
        return None
    return f"no importable prefix of {path!r}"


def check_symbols() -> list:
    failures = []
    classes = {}
    for name, modname in PUBLIC_CLASSES.items():
        classes[name] = getattr(importlib.import_module(modname), name)
    for path in doc_files():
        rel = os.path.relpath(path, REPO)
        with open(path) as fh:
            text = fh.read()
        for m in DOTTED.finditer(text):
            err = resolve_dotted(m.group(1))
            if err is not None:
                failures.append(f"{rel}: `{m.group(1)}` — {err}")
        for m in CLASS_REF.finditer(text):
            cls_name, attr = m.group(1), m.group(2) or m.group(3)
            cls = classes.get(cls_name)
            if cls is None or attr is None:
                continue  # not part of the checked surface
            known = ({f.name for f in dataclasses.fields(cls)}
                     if dataclasses.is_dataclass(cls) else set())
            if attr not in known and not hasattr(cls, attr):
                failures.append(
                    f"{rel}: `{cls_name}.{attr}` — {cls_name} has no "
                    f"field or attribute {attr!r}")
    return failures


def quickstart_block() -> str | None:
    with open(os.path.join(REPO, "README.md")) as fh:
        text = fh.read()
    m = re.search(r"```python\n(.*?)```", text, re.DOTALL)
    return m.group(1) if m else None


def check_quickstart(timeout_s: float = 240.0) -> list:
    code = quickstart_block()
    if code is None:
        return ["README.md: no fenced python quickstart block found"]
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(REPO, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    env.setdefault("JAX_PLATFORMS", "cpu")
    try:
        proc = subprocess.run([sys.executable, "-"], input=code,
                              capture_output=True, text=True,
                              timeout=timeout_s, env=env, cwd=REPO)
    except subprocess.TimeoutExpired:
        return [f"README quickstart: timed out after {timeout_s:.0f}s"]
    if proc.returncode != 0:
        tail = proc.stderr.strip().splitlines()[-12:]
        return ["README quickstart: exited "
                f"{proc.returncode}:\n  " + "\n  ".join(tail)]
    print(f"README quickstart ran: {proc.stdout.strip()}")
    return []


def main() -> int:
    failures = check_symbols()
    failures += check_quickstart()
    if failures:
        print("\ndocs-drift gate FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    n_files = len(doc_files())
    print(f"docs-drift gate passed ({n_files} files checked)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
