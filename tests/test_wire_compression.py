"""Quantize-on-the-wire staging + in-place downgrade tests: the wire
ratio math, compressed loads shrinking transfer *time* while claims and
ledgers still charge the *resident* footprint, cancel-mid-compressed-load
releasing exactly the landed shards' resident MB, the in-place
``Downgrade`` shipping zero bytes through the loader channel, and an
in-place plan failing mid-sequence rolling back with no ledger drift.

Synthetic zoos drive the manager + channels directly (no models), the
same idiom as test_sharded_loader.py.
"""
import pytest

from repro.core import EdgeMultiAI
from repro.core import actions as A
from repro.core.memory_state import DeviceLedger
from repro.core.model_zoo import ModelVariant, ModelZoo
from repro.distributed import sharding as SH
from repro.distributed.compression import wire_compression_ratio
from repro.serving.api import LoaderSpec, ServingConfig, TenantSpec
from repro.serving.loader import BackgroundLoader
from repro.serving.sharded_loader import ShardedLoaderChannel

N_DEV = 4


def _zoo(name, sizes):
    return ModelZoo(app_name=name, variants=tuple(
        ModelVariant(f"{name}-{i}", bits=32 >> i, size_mb=s,
                     accuracy=90.0 - 10 * i, load_ms=s * 2)
        for i, s in enumerate(sizes)))


def make_manager(budget_mb=1000.0, devices=True, **zoos):
    zoos = zoos or {"a": _zoo("a", [500, 300]), "b": _zoo("b", [400, 200])}
    mgr = EdgeMultiAI(zoos, budget_mb=budget_mb, policy="iws-bfe",
                      delta_ms=10.0)
    if devices:
        mgr.state.devices = DeviceLedger(
            (budget_mb / N_DEV,) * N_DEV,
            split_fn=lambda app, v: SH.variant_shard_mb(v.size_mb, N_DEV))
    return mgr


# ---------------------------------------------------------------------------
# The wire ratio itself
# ---------------------------------------------------------------------------
def test_wire_compression_ratio_values():
    # int8 payload (1 B/elem) + one f32 scale per group of 32 elems,
    # against bits/8 resident bytes per element.
    assert wire_compression_ratio(32) == pytest.approx(1.125 / 4)
    assert wire_compression_ratio(16) == pytest.approx(0.5625)
    # Already at (or below) the wire width: clamped — compression must
    # never make a transfer *slower*.
    assert wire_compression_ratio(8) == 1.0
    assert wire_compression_ratio(4) == 1.0
    # Coarser groups ship fewer scale bytes.
    assert wire_compression_ratio(16, group=128) < \
        wire_compression_ratio(16, group=32)
    with pytest.raises(ValueError):
        wire_compression_ratio(16, scheme="gzip")


def test_loader_compress_validation():
    mgr = make_manager(devices=False)
    with pytest.raises(ValueError):
        BackgroundLoader(mgr, compress="gzip")
    with pytest.raises(ValueError):
        LoaderSpec(compress="gzip")
    spec = LoaderSpec(sharded=True, mesh_shape=(4,), compress="int8")
    cfg = ServingConfig(tenants=(TenantSpec("tinyllama-1.1b"),),
                        loader=spec, executor="sim")
    assert ServingConfig.from_dict(cfg.to_dict()).loader == spec


# ---------------------------------------------------------------------------
# Compressed staging: wire time shrinks, resident accounting does not
# ---------------------------------------------------------------------------
def test_compressed_load_shrinks_wire_time_not_claims():
    mgr = make_manager(devices=False)
    loader = BackgroundLoader(mgr, compress="int8")
    ratio = wire_compression_ratio(32)  # "a-0" is the 32-bit variant
    ld = loader.enqueue(mgr.plan_demand("a", 0.0), now_ms=0.0, demand=True)
    assert ld is not None and ld.variant.bits == 32
    # The claim is the *resident* footprint — the chip holds full-width
    # weights after dequantize-on-land.
    assert mgr.state.inflight_mb == 500.0
    # The transfer is the *wire* time — fewer bytes through the link.
    assert ld.ready_ms == pytest.approx(1000.0 * ratio)
    # Nothing commits before the (shorter) wire window closes...
    assert loader.reap(1000.0 * ratio - 1.0) == []
    recs = loader.reap(1000.0 * ratio)
    assert [r.app for r in recs] == ["a"]
    assert recs[0].load_ms == pytest.approx(1000.0 * ratio)
    assert loader.wire_mb_staged == pytest.approx(500.0 * ratio)
    # ...and the committed weights charge full width.
    assert mgr.state.tenants["a"].loaded.size_mb == 500.0
    loader.close()


def test_compressed_sharded_slots_tile_the_wire_time():
    mgr = make_manager()
    loader = ShardedLoaderChannel(mgr, n_devices=N_DEV, compress="int8")
    ratio = wire_compression_ratio(32)
    ld = loader.enqueue(mgr.plan_demand("a", 0.0), 0.0, demand=True)
    wire_ms = 1000.0 * ratio
    assert [s.load_ms for s in ld.shards] == \
        pytest.approx([wire_ms / N_DEV] * N_DEV)
    assert ld.ready_ms == pytest.approx(wire_ms)
    # Per-chip claims are the resident shard MB, not the wire MB.
    assert mgr.state.devices.inflight["a"] == pytest.approx([125.0] * N_DEV)
    recs = loader.reap(wire_ms)
    assert recs[0].load_ms == pytest.approx(wire_ms)
    assert mgr.state.devices.weights["a"] == pytest.approx([125.0] * N_DEV)
    loader.close()


def test_cancel_mid_compressed_load_releases_resident_mb():
    """Cancelling a compressed sharded load releases exactly the landed
    shards' *resident* claims (125MB per chip), not the smaller wire MB
    — and the partial overlap credit is the landed shards' wire time."""
    mgr = make_manager()
    loader = ShardedLoaderChannel(mgr, n_devices=N_DEV, compress="int8")
    loader.enqueue(mgr.plan_proactive("a", 0.0), 0.0, predicted_ms=900.0)
    ratio = wire_compression_ratio(32)
    slot_ms = 1000.0 * ratio / N_DEV  # 70.3125
    led = mgr.state.devices
    released = []
    orig = led.release_inflight_shard

    def spy(app, device, mb):
        released.append((device, mb))
        orig(app, device, mb)

    led.release_inflight_shard = spy
    # Two wire slots have passed; cancel mid-flight.
    loader.reap(2.5 * slot_ms)
    assert loader.shards_landed == 2
    ld = loader.cancel("a", 2.5 * slot_ms)
    assert ld is not None
    assert [d for d, _ in released] == list(range(N_DEV))
    assert all(mb == pytest.approx(125.0) for _, mb in released), \
        "released claims are resident shard MB, not wire MB"
    assert mgr.state.inflight_mb == 0.0
    assert led.inflight == {}
    led.check_invariant()
    recs = loader.reap(2.5 * slot_ms)
    assert len(recs) == 1 and recs[0].partial
    assert recs[0].load_ms == pytest.approx(2 * slot_ms), \
        "overlap credit = the landed shards' wire slots"
    loader.close()


# ---------------------------------------------------------------------------
# In-place downgrades: zero bytes over the link
# ---------------------------------------------------------------------------
def test_downgrade_action_prefers_in_place():
    zoo = _zoo("a", [500, 300])
    big, small = zoo.variants
    assert A.downgrade_action("a", big, small).in_place
    assert not A.downgrade_action("a", None, small).in_place
    assert not A.downgrade_action("a", small, small).in_place
    acts = A.eviction_actions([A.Eviction("a", big, small),
                               A.Eviction("b", big, None)])
    assert isinstance(acts[0], A.Downgrade) and acts[0].in_place
    assert isinstance(acts[1], A.Unload)


def test_inplace_downgrade_stages_zero_wire_bytes():
    """The acceptance-criterion test: an in-place ``Downgrade`` enacted
    through the loader channel moves zero bytes over the host link."""
    mgr = make_manager()
    loader = ShardedLoaderChannel(mgr, n_devices=N_DEV, compress="int8")
    big, small = mgr.state.tenants["a"].zoo.variants
    mgr.state.apply(A.plan_of(A.Load("a", big)))
    assert loader.execute(
        A.plan_of(A.downgrade_action("a", big, small)), 0.0) is None
    assert loader.wire_mb_staged == 0.0, "zero bytes staged over the wire"
    assert loader.inplace_downgrades == 1
    assert mgr.state.tenants["a"].loaded is small
    led = mgr.state.devices
    assert led.weights["a"] == pytest.approx([small.size_mb / N_DEV] * N_DEV)
    led.check_invariant()
    # The same downgrade *not* in place ships the compressed payload.
    mgr2 = make_manager()
    loader2 = ShardedLoaderChannel(mgr2, n_devices=N_DEV, compress="int8")
    mgr2.state.apply(A.plan_of(A.Load("a", big)))
    loader2.execute(A.plan_of(A.Downgrade("a", small)), 0.0)
    assert loader2.wire_mb_staged == pytest.approx(
        small.size_mb * wire_compression_ratio(small.bits))
    assert loader2.inplace_downgrades == 0
    loader.close()
    loader2.close()


def test_inplace_downgrade_validation():
    mgr = make_manager()
    big, small = mgr.state.tenants["a"].zoo.variants
    # Nothing resident: no leaves to requantize.
    with pytest.raises(A.PlanError):
        mgr.state.apply(A.plan_of(A.Downgrade("a", small, in_place=True)))
    # Not strictly lower-bits: int8->int8 (or back up) is not derivable.
    mgr.state.apply(A.plan_of(A.Load("a", small)))
    with pytest.raises(A.PlanError):
        mgr.state.apply(A.plan_of(A.Downgrade("a", small, in_place=True)))
    assert mgr.state.tenants["a"].loaded is small


def test_inplace_downgrade_plan_rolls_back_without_ledger_drift():
    """An in-place downgrade in a plan whose *later* action fails must
    roll back whole: the original variant stays resident and the ledger
    shows no drift."""
    mgr = make_manager()
    big_a, small_a = mgr.state.tenants["a"].zoo.variants
    _, small_b = mgr.state.tenants["b"].zoo.variants
    mgr.state.apply(A.plan_of(A.Load("a", big_a)))
    led = mgr.state.devices
    weights_before = {app: list(w) for app, w in led.weights.items()}
    free_before = mgr.state.free_mb
    # Action 2 fails: "b" has nothing resident to requantize in place.
    with pytest.raises(A.PlanError):
        mgr.state.apply(A.plan_of(
            A.Downgrade("a", small_a, in_place=True),
            A.Downgrade("b", small_b, in_place=True)))
    assert mgr.state.tenants["a"].loaded is big_a, \
        "the already-applied in-place downgrade rolled back"
    assert {app: list(w) for app, w in led.weights.items()} == \
        weights_before
    assert mgr.state.free_mb == pytest.approx(free_before)
    assert mgr.state.inflight_mb == 0.0
    led.check_invariant()
