"""Checkpointing, fault tolerance, compression, elastic resharding.

Multi-device cases run in a subprocess with 8 fake CPU devices (the flag
must be set before jax initializes, so it cannot live in this process)."""
import os
import subprocess
import sys
import tempfile
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.distributed import checkpoint as ckpt
from repro.distributed.compression import CompressionState, compress_grads
from repro.distributed.fault_tolerance import (FailureInjector, NodeFailure,
                                               run_supervised)
from repro.training.data import DataConfig, SyntheticStream
from repro.training.optim import AdamW, warmup_cosine
from repro.training.train_step import init_state, make_train_step


class TestCheckpoint:
    def test_roundtrip(self):
        tree = {"a": jnp.arange(12.0).reshape(3, 4),
                "b": {"c": jnp.ones((5,), jnp.int32)}}
        with tempfile.TemporaryDirectory() as d:
            ckpt.save(tree, d, step=7)
            assert ckpt.latest_step(d) == 7
            out = ckpt.restore(tree, d)
            np.testing.assert_array_equal(np.asarray(out["a"]),
                                          np.asarray(tree["a"]))

    def test_atomic_no_partial_commit(self):
        tree = {"a": jnp.zeros((4,))}
        with tempfile.TemporaryDirectory() as d:
            ckpt.save(tree, d, step=1)
            # simulate a crashed save: stray tmp dir must be ignored
            os.makedirs(os.path.join(d, "step_00000002.tmp"))
            assert ckpt.latest_step(d) == 1
            ckpt.restore(tree, d)

    def test_gc_keeps_recent(self):
        tree = {"a": jnp.zeros((2,))}
        with tempfile.TemporaryDirectory() as d:
            for s in range(6):
                ckpt.save(tree, d, step=s)
            kept = sorted(x for x in os.listdir(d) if x.startswith("step_"))
            assert len(kept) == 3

    def test_bf16_roundtrip(self):
        """numpy stores bf16 as void16; restore must view it back."""
        tree = {"w": jnp.arange(8.0, dtype=jnp.bfloat16),
                "q": jnp.arange(4, dtype=jnp.int8)}
        with tempfile.TemporaryDirectory() as d:
            ckpt.save(tree, d, step=1)
            out = ckpt.restore(tree, d)
            assert out["w"].dtype == jnp.bfloat16
            np.testing.assert_array_equal(
                np.asarray(out["w"], np.float32),
                np.asarray(tree["w"], np.float32))

    def test_async_save(self):
        tree = {"a": jnp.arange(6.0)}
        with tempfile.TemporaryDirectory() as d:
            saver = ckpt.AsyncCheckpointer()
            saver.save_async(tree, d, step=3)
            saver.wait()
            assert ckpt.latest_step(d) == 3


class TestFaultTolerance:
    def _setup(self):
        cfg = get_config("tinyllama-1.1b", reduced=True)
        opt = AdamW(lr=warmup_cosine(3e-3, 5, 40), weight_decay=0.01)
        step_fn = jax.jit(make_train_step(cfg, opt, remat=True,
                                          compute_dtype=None))
        state = init_state(cfg, jax.random.key(0), opt)
        ds = SyntheticStream(DataConfig(vocab_size=cfg.vocab_size,
                                        seq_len=32, global_batch=4))
        def batch_fn(s):
            return {k: jnp.asarray(v)
                    for k, v in ds.batch_at(s).items()}
        return state, step_fn, batch_fn

    def test_recovery_bitwise_identical(self):
        state, step_fn, batch_fn = self._setup()
        with tempfile.TemporaryDirectory() as d1, \
                tempfile.TemporaryDirectory() as d2:
            a = run_supervised(init_state=state, step_fn=step_fn,
                               batch_fn=batch_fn, total_steps=14,
                               ckpt_dir=d1, ckpt_every=4, async_save=False)
            b = run_supervised(
                init_state=state, step_fn=step_fn, batch_fn=batch_fn,
                total_steps=14, ckpt_dir=d2, ckpt_every=4,
                injector=FailureInjector(fail_at_steps=(6, 11)),
                async_save=False)
            assert b.restarts == 2
            np.testing.assert_allclose(a.losses[-1], b.losses[-1],
                                       rtol=1e-6)

    def test_loss_decreases(self):
        state, step_fn, batch_fn = self._setup()
        with tempfile.TemporaryDirectory() as d:
            rep = run_supervised(init_state=state, step_fn=step_fn,
                                 batch_fn=batch_fn, total_steps=25,
                                 ckpt_dir=d, ckpt_every=10,
                                 async_save=False)
        assert rep.losses[-1] < rep.losses[0] * 0.8

    def test_gives_up_after_max_restarts(self):
        state, step_fn, batch_fn = self._setup()
        with tempfile.TemporaryDirectory() as d:
            with pytest.raises(NodeFailure):
                run_supervised(
                    init_state=state, step_fn=step_fn, batch_fn=batch_fn,
                    total_steps=10, ckpt_dir=d, ckpt_every=100,
                    injector=FailureInjector(fail_at_steps=(1,) ),
                    max_restarts=0)


class TestCompression:
    def test_error_feedback_unbiased(self):
        """Long-run mean of compressed grads ≈ mean of true grads."""
        rng = np.random.default_rng(0)
        g_true = jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)
        state = CompressionState.init({"w": g_true})
        acc = jnp.zeros_like(g_true)
        for _ in range(50):
            out, state = compress_grads({"w": g_true}, state)
            acc = acc + out["w"]
        np.testing.assert_allclose(np.asarray(acc / 50),
                                   np.asarray(g_true), atol=5e-3)

    def test_training_with_compression_converges(self):
        cfg = get_config("tinyllama-1.1b", reduced=True)
        opt = AdamW(lr=3e-3)
        step = jax.jit(make_train_step(cfg, opt, compression=True,
                                       compute_dtype=None))
        state = init_state(cfg, jax.random.key(0), opt, compression=True)
        ds = SyntheticStream(DataConfig(vocab_size=cfg.vocab_size,
                                        seq_len=32, global_batch=4))
        losses = []
        for s in range(20):
            batch = {k: jnp.asarray(v) for k, v in ds.batch_at(s).items()}
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0]


MULTIDEV = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np, tempfile
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.distributed.compression import compressed_allreduce_demo
    from repro.distributed import checkpoint as ckpt
    from repro.distributed.elastic import reshard, validate_elastic_plan

    from repro.launch.mesh import make_mesh_compat
    mesh8 = make_mesh_compat((8,), ("data",))
    mesh24 = make_mesh_compat((2, 4), ("data", "model"))

    # 1. compressed all-reduce ~= exact all-reduce
    x = jnp.asarray(np.random.default_rng(0).normal(size=(8, 128)),
                    jnp.float32)
    got = compressed_allreduce_demo(x, mesh8)
    want = x.reshape(8, 1, 128).sum(0)
    rel = float(jnp.abs(got - want).max() / jnp.abs(want).max())
    assert rel < 0.02, rel
    print("compressed_allreduce ok", rel)

    # 2. sharded checkpoint -> restore onto a DIFFERENT mesh (elastic)
    w = jnp.arange(16 * 32, dtype=jnp.float32).reshape(16, 32)
    sh8 = NamedSharding(mesh8, P("data", None))
    w8 = jax.device_put(w, sh8)
    with tempfile.TemporaryDirectory() as d:
        ckpt.save({"w": w8}, d, step=1)
        sh24 = NamedSharding(mesh24, P("data", "model"))
        out = ckpt.restore({"w": w}, d, shardings={"w": sh24})
        np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(w))
        assert out["w"].sharding == sh24
    print("elastic restore ok")

    # 3. live reshard
    r = reshard({"w": w8}, {"w": P("data", "model")}, mesh24)
    np.testing.assert_array_equal(np.asarray(r["w"]), np.asarray(w))
    plan = validate_elastic_plan(mesh8, mesh24, global_batch=16)
    assert plan["ok"]
    print("reshard ok")
""")


def test_multidevice_subprocess():
    """Compression collective + elastic checkpoint on 8 fake devices."""
    proc = subprocess.run(
        [sys.executable, "-c", MULTIDEV], capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=300)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "reshard ok" in proc.stdout
