"""Scheduling-equivalence properties: indexed vs linear engines.

The indexed scheduler (lazy-deletion event heap, memoized prediction
triggers, online overlap accounting, provable maintenance skipping) is
an *optimization*, not a semantics change: on any trace it must produce
the bit-identical audit trail and ``ServingStats`` the linear reference
path produces.  These tests randomize traces and serving shapes across
the four engine configurations — scalar batching, continuous batching,
sharded loader, and a faulted elastic mesh — and assert exact equality.

The property section uses ``hypothesis`` when available; without it the
same checker runs over a seeded parameter grid so the module always
collects and the equivalence stays guarded.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # degrade to the seeded fallback below
    HAVE_HYPOTHESIS = False

from repro.serving import EdgeServer, poisson_trace
from repro.serving.api import (BatchingSpec, FaultSpec, LoaderSpec,
                               PredictorSpec, ServingConfig, TenantSpec)

TENANTS = ("tinyllama-1.1b", "mamba2-780m")
FAULT = FaultSpec(events=((3000.0, 3, "down"), (9000.0, 3, "up")))

# The four engine shapes the refactor touches: scalar reactive batching,
# continuous batching, the sharded loader channel, and chip faults.
CONFIGS = {
    "scalar": dict(continuous=False, sharded=False, fault=None),
    "continuous": dict(continuous=True, sharded=False, fault=None),
    "sharded": dict(continuous=True, sharded=True, fault=None),
    "faulted": dict(continuous=True, sharded=True, fault=FAULT),
}
CONFIG_NAMES = tuple(CONFIGS)


def _run(scheduler, shape, *, mean_iat_ms, requests_per_app, delta_ms,
         max_batch, trace_seed, min_fit_samples=10**9):
    """One full replay; returns (stats dict, audit trail, events)."""
    kw = {}
    if shape["sharded"] or shape["fault"] is not None:
        kw["loader"] = LoaderSpec(sharded=True, mesh_shape=(4,))
    srv = EdgeServer.build(ServingConfig(
        tenants=tuple(TenantSpec(n) for n in TENANTS),
        executor="sim", policy="iws-bfe", delta_ms=delta_ms,
        batching=BatchingSpec(max_batch=max_batch, window_ms=20.0,
                              continuous=shape["continuous"]),
        predictor=PredictorSpec(min_fit_samples=min_fit_samples),
        kv_headroom_shape=(2, 12), fault=shape["fault"],
        audit="full", scheduler=scheduler, **kw))
    cfgs = {t.name: t.cfg for t in srv.tenants.values()}
    trace, _ = poisson_trace(cfgs, requests_per_app=requests_per_app,
                             mean_iat_ms=mean_iat_ms, seed=trace_seed)
    stats = srv.engine.run_trace(trace)
    srv.engine.check_event_invariant()
    trail = srv.engine.audit_trail
    emitted = srv.engine.events_emitted
    srv.close()
    return stats.to_dict(), trail, emitted


def _check_equivalence(config_name, *, mean_iat_ms, requests_per_app,
                       delta_ms, max_batch, trace_seed,
                       min_fit_samples=10**9):
    shape = CONFIGS[config_name]
    params = dict(mean_iat_ms=mean_iat_ms,
                  requests_per_app=requests_per_app, delta_ms=delta_ms,
                  max_batch=max_batch, trace_seed=trace_seed,
                  min_fit_samples=min_fit_samples)
    s_idx, t_idx, e_idx = _run("indexed", shape, **params)
    s_lin, t_lin, e_lin = _run("linear", shape, **params)
    assert e_idx == e_lin, (config_name, params)
    assert t_idx == t_lin, (config_name, params)
    assert s_idx == s_lin, (config_name, params)


def _params_from_rng(rng: np.random.Generator) -> dict:
    """Seeded-numpy mirror of the hypothesis parameter strategy."""
    return dict(
        mean_iat_ms=float(rng.uniform(100.0, 900.0)),
        requests_per_app=int(rng.integers(15, 45)),
        delta_ms=float(rng.uniform(150.0, 900.0)),
        max_batch=int(rng.integers(2, 7)),
        trace_seed=int(rng.integers(0, 2**31)))


if HAVE_HYPOTHESIS:
    @settings(max_examples=6, deadline=None)
    @given(st.sampled_from(CONFIG_NAMES),
           st.floats(100.0, 900.0), st.integers(15, 44),
           st.floats(150.0, 900.0), st.integers(2, 6),
           st.integers(0, 2**31 - 1))
    def test_equivalence_property(config_name, mean_iat_ms,
                                  requests_per_app, delta_ms, max_batch,
                                  trace_seed):
        _check_equivalence(
            config_name, mean_iat_ms=mean_iat_ms,
            requests_per_app=requests_per_app, delta_ms=delta_ms,
            max_batch=max_batch, trace_seed=trace_seed)


@pytest.mark.parametrize("config_name", CONFIG_NAMES)
@pytest.mark.parametrize("seed", range(2))
def test_equivalence_seeded(config_name, seed):
    rng = np.random.default_rng(
        1000 * seed + CONFIG_NAMES.index(config_name))
    _check_equivalence(config_name, **_params_from_rng(rng))


def test_equivalence_with_background_fits():
    """Fits enabled (sync in sim builds): the fit lands at a virtual
    instant and changes every later prediction — both schedulers must
    agree through it (the memoized trigger keys on the fit counter)."""
    _check_equivalence(
        "continuous", mean_iat_ms=300.0, requests_per_app=40,
        delta_ms=500.0, max_batch=4, trace_seed=11,
        min_fit_samples=24)
