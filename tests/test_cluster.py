"""Cluster-tier tests: warm-aware routing across a fleet of edge servers.

Config tests exercise the declarative round trip (ClusterConfig ↔ dict,
including nested ServingConfig trees with FaultSpec and LoaderSpec) and
the build-time validation.  Router tests drive the registry and the
three built-ins over synthetic ServerViews.  Cluster tests build real
2–3 server sim fleets: bit-determinism across two builds (equal audit
trails and stats), warm-aware beating round-robin on the flash-crowd
trace, and the transactional tenant hand-off (fires under contention,
moves the queue, drains the donor, aborts clean when the receiver
cannot host).  Trace-generator tests pin seeded determinism for the
flash-crowd and diurnal arrival processes.
"""
import math

import numpy as np
import pytest

from repro.cluster import (ClusterConfig, EdgeCluster, Router, RouterSpec,
                           ServerView, available_routers, register_router,
                           resolve_router)
from repro.core.simulator import (generate_diurnal, generate_flash_crowd,
                                  generate_workload)
from repro.serving import trace_from_workload
from repro.serving.api import (BatchingSpec, FaultSpec, LoaderSpec,
                               ServingConfig, TenantSpec)
from repro.serving.batcher import Request

TEN = ["tinyllama-1.1b", "mamba2-780m", "gemma2-2b"]


def sim_config(service_ms=None, **kw):
    return ServingConfig(
        tenants=tuple(TenantSpec(t, service_ms=service_ms) for t in TEN),
        policy="bfe", executor="sim", **kw)


def _req(app, t, rid=None):
    return Request(app=app, prompt=np.zeros(8, np.int32), max_new=4,
                   arrival_ms=t, rid=rid)


def flash_trace(cluster, seed=7):
    wl = generate_flash_crowd(TEN, requests_per_app=36, base_iat_ms=8000.0,
                              burst_app=TEN[0], burst_requests=40,
                              burst_iat_ms=100.0, seed=seed)
    cfgs = {t.name: t.cfg for t in cluster.servers[0].tenants.values()}
    return trace_from_workload(wl, cfgs, seed=3, prompt_len=(8, 9),
                               max_new=4)


# ---------------------------------------------------------------------------
# Config round trip + validation
# ---------------------------------------------------------------------------
def test_cluster_config_round_trip():
    base = sim_config(
        batching=BatchingSpec(max_batch=4, window_ms=20.0),
        loader=LoaderSpec(prefetch=True, sharded=True, mesh_shape=(4,)),
        fault=FaultSpec(events=((3000.0, 1, "down"),), prob=0.25, seed=5))
    cfg = ClusterConfig.uniform(
        3, base, RouterSpec(name="least-loaded", spill_penalty=2.0,
                            handoff_queue=6))
    d = cfg.to_dict()
    back = ClusterConfig.from_dict(d)
    assert back == cfg
    # The nested specs survive as typed objects, not dicts.
    assert back.servers[0].fault == base.fault
    assert back.servers[0].loader == base.loader
    assert back.router.handoff_queue == 6
    # And the dict form is plain data (JSON-able).
    import json
    assert ClusterConfig.from_dict(json.loads(json.dumps(d))) == cfg


def test_cluster_config_validation():
    base = sim_config()
    with pytest.raises(ValueError, match="at least one server"):
        ClusterConfig(servers=())
    with pytest.raises(ValueError, match="executor='sim'"):
        ClusterConfig(servers=(ServingConfig(
            tenants=(TenantSpec(TEN[0]),)),))
    with pytest.raises(ValueError, match="prefetch"):
        ClusterConfig(servers=(sim_config(
            loader=LoaderSpec(prefetch=False)),))
    with pytest.raises(ValueError, match="continuous"):
        ClusterConfig(servers=(sim_config(
            batching=BatchingSpec(continuous=True)),))
    other = ServingConfig(tenants=(TenantSpec(TEN[0]),),
                          executor="sim")
    with pytest.raises(ValueError, match="same tenant set"):
        ClusterConfig(servers=(base, other))
    with pytest.raises(ValueError, match="unknown router"):
        RouterSpec(name="psychic")
    with pytest.raises(ValueError, match="spill_penalty"):
        RouterSpec(spill_penalty=-1.0)
    with pytest.raises(ValueError, match="handoff_queue"):
        RouterSpec(handoff_queue=-1)
    assert ClusterConfig.uniform(2, base).tenant_names == tuple(sorted(TEN))


# ---------------------------------------------------------------------------
# Router registry + built-ins (synthetic views)
# ---------------------------------------------------------------------------
def _view(i, pending=0, resident=None, staging=None, queued=None):
    return ServerView(index=i, pending=pending, served=0, warm=0,
                      queued=queued or {}, resident=resident or {},
                      staging=staging or {})


def test_router_registry_and_protocol():
    assert {"round-robin", "least-loaded", "warm-aware"} <= set(
        available_routers())
    for name in ("round-robin", "least-loaded", "warm-aware"):
        r = resolve_router(name)
        assert isinstance(r, Router)
        assert r.name == name
    bad = RouterSpec.__new__(RouterSpec)  # skip __post_init__ validation
    object.__setattr__(bad, "name", "psychic")
    with pytest.raises(KeyError, match="unknown router"):
        resolve_router(bad)


def test_register_router_decorator():
    @register_router("always-two")
    class AlwaysTwo:
        def __init__(self, spec=None):
            pass

        def route(self, app, views, now_ms):
            return 2

    try:
        r = resolve_router("always-two")
        assert r.name == "always-two"
        assert r.route("x", [_view(0), _view(1), _view(2)], 0.0) == 2
    finally:
        from repro.cluster.routers import _ROUTERS
        del _ROUTERS["always-two"]


def test_round_robin_rotates():
    r = resolve_router("round-robin")
    views = [_view(0), _view(1), _view(2)]
    assert [r.route("a", views, 0.0) for _ in range(5)] == [0, 1, 2, 0, 1]


def test_least_loaded_picks_shortest_queue():
    r = resolve_router("least-loaded")
    assert r.route("a", [_view(0, pending=3), _view(1, pending=1),
                         _view(2, pending=1)], 0.0) == 1


def test_warm_aware_prefers_residency_then_spills():
    r = resolve_router(RouterSpec(name="warm-aware", spill_penalty=5.0))
    # Residency wins over an idle cold server.
    views = [_view(0, resident={"a": 95.0}), _view(1), _view(2)]
    assert r.route("a", views, 0.0) == 0
    # Staging counts half: a staging server still beats a cold one.
    views = [_view(0), _view(1, staging={"a": 95.0}), _view(2)]
    assert r.route("a", views, 0.0) == 1
    # Deep queue on the warm server spills to the idle cold one:
    # 95 - 5*20 < 0.
    views = [_view(0, pending=20, resident={"a": 95.0}), _view(1)]
    assert r.route("a", views, 0.0) == 1
    # Cold everywhere: ties break toward the least crowded server.
    views = [_view(0, resident={"b": 90.0}), _view(1)]
    assert r.route("a", views, 0.0) == 1


# ---------------------------------------------------------------------------
# Cluster runs: determinism + routing A/B
# ---------------------------------------------------------------------------
def _run_fleet(router, n=3, handoff=0, seed=7):
    cfg = ClusterConfig.uniform(
        n, sim_config(), RouterSpec(name=router, handoff_queue=handoff))
    cl = EdgeCluster.build(cfg)
    stats = cl.run_trace(flash_trace(cl, seed=seed))
    cl.check_event_invariant()
    trails = cl.audit_trails()
    cl.close()
    return stats, trails


def test_cluster_two_builds_bit_identical():
    s1, t1 = _run_fleet("warm-aware")
    s2, t2 = _run_fleet("warm-aware")
    assert t1 == t2          # per-server audit trails, event for event
    assert s1 == s2          # aggregated stats (cluster block included)
    assert len(t1) == 3 and all(tr for tr in t1)


def test_warm_aware_beats_round_robin_on_flash_crowd():
    warm, _ = _run_fleet("warm-aware")
    rr, _ = _run_fleet("round-robin")
    assert warm.requests == rr.requests > 0
    assert warm.warm_ratio > rr.warm_ratio
    # Warm-aware partitions residency: every server serves someone, and
    # nothing spills (each tenant keeps one home).
    assert all(n > 0 for n in warm.cluster["per_server_requests"])
    assert warm.cluster["spilled"] == 0
    assert rr.cluster["spilled"] > 0
    assert warm.cluster["router"] == "warm-aware"
    assert warm.cluster["routed"] == warm.requests


def test_cluster_stats_block_shape():
    stats, _ = _run_fleet("round-robin")
    c = stats.cluster
    assert c["servers"] == 3
    assert sum(c["per_server_requests"]) == stats.requests
    assert len(c["per_server_warm_ratio"]) == 3
    d = stats.to_dict()
    assert d["cluster"] == c
    assert set(stats.per_tenant) == set(TEN)


# ---------------------------------------------------------------------------
# Transactional hand-off
# ---------------------------------------------------------------------------
def _handoff_trace():
    """Warm-up places A,B on server 0 and C on server 1 (crowding
    tie-break), then an interleaved A/B burst at 2ms spacing piles both
    queues on server 0 while its 30ms virtual service can't drain them —
    A's crowd is stuck behind B's work, the hand-off trigger."""
    reqs = [_req(TEN[0], 0.0), _req(TEN[2], 1.0), _req(TEN[1], 2.0)]
    t = 500.0
    for _ in range(20):
        for app in (TEN[0], TEN[1]):
            reqs.append(_req(app, t))
            t += 2.0
    return reqs


def _handoff_fleet(handoff=4):
    cfg = ClusterConfig.uniform(
        2, sim_config(service_ms=30.0),
        RouterSpec(name="warm-aware", handoff_queue=handoff))
    return EdgeCluster.build(cfg)


def test_handoff_fires_and_stays_deterministic():
    def run():
        cl = _handoff_fleet()
        stats = cl.run_trace(_handoff_trace())
        cl.check_event_invariant()
        trails = cl.audit_trails()
        cl.close()
        return stats, trails

    s1, t1 = run()
    s2, t2 = run()
    assert s1.cluster["handoffs"] >= 1
    assert s1.requests == 43          # nothing lost across the move
    assert t1 == t2 and s1 == s2
    # Both sides logged the hand-off event (staged in / drained out).
    kinds = [(ev.kind, ev.app) for tr in t1 for ev in tr]
    assert kinds.count(("handoff", TEN[0])) >= 2


def test_handoff_moves_queue_and_drains_donor():
    cl = _handoff_fleet()
    reqs = _handoff_trace()
    for i, r in enumerate(reqs):
        r.rid = i
    # Drive arrivals until the first hand-off, then inspect mid-flight.
    engines = [srv.engine for srv in cl.servers]
    for r in sorted(reqs, key=lambda r: r.arrival_ms):
        t = r.arrival_ms
        for eng in engines:
            eng.cluster_advance(t)
        views = cl.views()
        target = cl.router.route(r.app, views, t)
        target = cl._maybe_handoff(r.app, target, views, t)
        engines[target].cluster_submit(r)
        if cl.handoffs:
            break
    assert cl.handoffs == 1
    donor, recv = cl.servers[0], cl.servers[1]
    # Exactly one of the two burst tenants moved (whichever queue hit
    # the trigger first); the donor drained it via one Unload plan and
    # holds none of its requests, the receiver is staging it and owns
    # the queue.
    moved = [a for a in (TEN[0], TEN[1])
             if donor.manager.state.tenants[a].loaded is None]
    assert len(moved) == 1
    app = moved[0]
    assert donor.engine.batcher.queued(app) == 0
    assert (app in recv.loader.inflight
            or recv.manager.state.tenants[app].loaded is not None)
    assert recv.engine.batcher.queued(app) >= 4
    # Drain to completion: every request still retires exactly once.
    while True:
        nxt = [eng.cluster_advance(math.inf) for eng in engines]
        if all(x == math.inf for x in nxt):
            break
    for eng in engines:
        eng.cluster_finish()
    served = [r.rid for srv in cl.servers for r in srv.engine.results]
    assert sorted(served) == sorted(r.rid for r in reqs
                                    if r.rid in set(served))
    cl.close()


def test_handoff_aborts_clean_when_receiver_cannot_host():
    # Receiver budget too small for any variant of A: the staged-load
    # simulate fails for every zoo size, so _handoff returns False and
    # neither server mutates.
    tiny = ServingConfig(
        tenants=tuple(TenantSpec(t, service_ms=30.0) for t in TEN),
        policy="bfe", executor="sim", budget_mb=0.01)
    cfg = ClusterConfig(servers=(sim_config(service_ms=30.0), tiny),
                        router=RouterSpec(name="warm-aware",
                                          handoff_queue=4))
    cl = EdgeCluster.build(cfg)
    # Seed donor residency + queue.
    donor = cl.servers[0]
    for i in range(6):
        donor.engine.cluster_submit(_req(TEN[0], float(i), rid=i))
    donor.engine.cluster_advance(50.0)
    assert donor.manager.state.tenants[TEN[0]].loaded is not None
    before_q = donor.engine.batcher.queued(TEN[0])
    assert not cl._handoff(TEN[0], 0, 1, 100.0)
    assert cl.handoffs == 0
    assert donor.manager.state.tenants[TEN[0]].loaded is not None
    assert donor.engine.batcher.queued(TEN[0]) == before_q
    assert not cl.servers[1].loader.inflight
    cl.close()


def test_handoff_not_triggered_by_own_crowd():
    # A's crowd alone (no other tenant queued on its home) must not
    # hand off: the queue would move with the tenant, so moving is
    # churn — the spill penalty handles that overflow instead.
    cl = _handoff_fleet()
    reqs = [_req(TEN[0], 0.0)]
    t = 500.0
    for _ in range(30):
        reqs.append(_req(TEN[0], t))
        t += 2.0
    stats = cl.run_trace(reqs)
    assert stats.cluster["handoffs"] == 0
    cl.close()


# ---------------------------------------------------------------------------
# Trace generators
# ---------------------------------------------------------------------------
def test_flash_crowd_deterministic_and_burst_unpredicted():
    a = generate_flash_crowd(TEN, burst_app=TEN[0], seed=3)
    b = generate_flash_crowd(TEN, burst_app=TEN[0], seed=3)
    c = generate_flash_crowd(TEN, burst_app=TEN[0], seed=4)
    assert a.requests == b.requests and a.predictions == b.predictions
    assert a.requests != c.requests
    # The burst rides on top of the Poisson baseline…
    n_burst = sum(1 for _, app in a.requests if app == TEN[0]) - 20
    assert n_burst == 40
    # …and is invisible to the predictor: predictions cover at most the
    # baseline arrivals (deviation drops some even of those) — the
    # flood itself must surprise the prefetcher.
    assert len(a.predictions[TEN[0]]) <= 20
    assert all(t1 <= t2 for (t1, _), (t2, _) in
               zip(a.requests, a.requests[1:]))
    with pytest.raises(ValueError, match="burst_app"):
        generate_flash_crowd(TEN, burst_app="nobody")


def test_diurnal_deterministic_and_validated():
    a = generate_diurnal(TEN, requests_per_app=30, seed=11)
    b = generate_diurnal(TEN, requests_per_app=30, seed=11)
    c = generate_diurnal(TEN, requests_per_app=30, seed=12)
    assert a.requests == b.requests and a.predictions == b.predictions
    assert a.requests != c.requests
    assert all(t1 <= t2 for (t1, _), (t2, _) in
               zip(a.requests, a.requests[1:]))
    assert {app for _, app in a.requests} == set(TEN)
    with pytest.raises(ValueError, match="amplitude"):
        generate_diurnal(TEN, amplitude=1.5)


def test_generate_workload_unchanged_by_refactor():
    # The extracted helpers must leave the original generator's stream
    # bit-identical (same seed → same Workload fields).
    wl = generate_workload(TEN[:2], requests_per_app=10, seed=0)
    wl2 = generate_workload(TEN[:2], requests_per_app=10, seed=0)
    assert wl.requests == wl2.requests
    assert wl.delta_D == wl2.delta_D and wl.kl == wl2.kl
