"""End-to-end behaviour tests: the paper's framework driving real models,
training end-to-end with faults, and the public API surface."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_NAMES, get_config
from repro.configs.paper_edge import paper_zoos
from repro.core import generate_workload, simulate
from repro.models import transformer as T
from repro.serving import EdgeServer, kv_cache_mb


def test_public_api_importable():
    import repro.core as core
    import repro.kernels.ops as ops  # noqa: F401
    import repro.quant.quantize  # noqa: F401
    import repro.serving  # noqa: F401
    import repro.training.train_step  # noqa: F401
    import repro.distributed.checkpoint  # noqa: F401

    assert {"lfe", "bfe", "ws-bfe", "iws-bfe",
            "batch-bfe"} <= set(core.available_policies())
    assert len(ARCH_NAMES) == 10


def test_end_to_end_paper_pipeline():
    """Workload → simulate all policies → paper-shaped outcome."""
    zoos = paper_zoos()
    wl = generate_workload(list(zoos), requests_per_app=40,
                           deviation=0.3, seed=0)
    results = {p: simulate(zoos, wl, policy=p)
               for p in ("none", "iws-bfe")}
    assert (results["iws-bfe"].metrics.warm_ratio
            > results["none"].metrics.warm_ratio * 1.4)


def test_end_to_end_serving_with_predictors():
    """Tenants served warm after the RNN predictor learns the cadence."""
    srv = EdgeServer(budget_mb=1e9, policy="iws-bfe", delta_ms=500.0)
    names = ["tinyllama-1.1b", "mamba2-780m"]
    for n in names:
        cfg = get_config(n, reduced=True)
        srv.register(n, cfg, T.init_params(cfg, jax.random.key(1),
                                           jnp.float32))
    # Feasible contention, with headroom for the largest per-request
    # decode cache (max_new=2 on a 4-token prompt).
    kv = max(kv_cache_mb(get_config(n, reduced=True), 1, 6) for n in names)
    srv.budget_mb = srv.contention_budget(kv)
    srv.start()
    rng = np.random.default_rng(0)
    now = 0.0
    for i in range(10):
        n = names[i % 2]
        cfg = get_config(n, reduced=True)
        srv.predict_and_preload(now)
        prompts = rng.integers(0, cfg.vocab_size, (1, 4)).astype(np.int32)
        r = srv.serve(n, prompts, max_new=2, now_ms=now)
        assert not r.failed
        now += 1000.0
    s = srv.stats()
    assert s.requests == 10
    assert s.fail_ratio == 0.0


def test_training_end_to_end_loss_decreases():
    from repro.training.data import DataConfig, SyntheticStream
    from repro.training.optim import AdamW, warmup_cosine
    from repro.training.train_step import init_state, make_train_step

    cfg = get_config("mamba2-780m", reduced=True)
    opt = AdamW(lr=warmup_cosine(3e-3, 5, 30))
    step = jax.jit(make_train_step(cfg, opt, compute_dtype=None))
    state = init_state(cfg, jax.random.key(0), opt)
    ds = SyntheticStream(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                    global_batch=4))
    losses = []
    for s in range(30):
        batch = {k: jnp.asarray(v) for k, v in ds.batch_at(s).items()}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.8, losses[::10]
