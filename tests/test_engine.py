"""Serving-engine tests: KV-cache residency accounting and the
admit→execute→retire protocol.

Model execution is stubbed (injectable executor) so these exercise the
full admission/accounting path — real configs, real zoos, real manager —
without touching XLA; the end-to-end engine-with-real-models path is
covered by tests/test_serving.py and the serving_throughput benchmark.
"""
import asyncio

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import EdgeMultiAI
from repro.core.memory_state import MemoryState, TenantState
from repro.core.model_zoo import ModelVariant, ModelZoo
from repro.models import transformer as T
from repro.serving import (Batch, EdgeServer, Request,
                           kv_cache_mb, poisson_trace)

TENANTS = ["tinyllama-1.1b", "mamba2-780m"]


def stub_executor(runtime, batch, extra=None):
    return np.zeros((len(batch.requests), batch.max_new), np.int32)


def make_server(budget_mb=1e9, **kw):
    srv = EdgeServer(budget_mb=budget_mb, policy="iws-bfe",
                     delta_ms=1000.0, **kw)
    for name in TENANTS:
        cfg = get_config(name, reduced=True)
        srv.register(name, cfg, T.init_params(
            cfg, jax.random.key(hash(name) % 2 ** 31), jnp.float32))
    return srv


@pytest.fixture(scope="module")
def cfgs():
    return {n: get_config(n, reduced=True) for n in TENANTS}


def one_batch(app, cfg, batch_size=2, plen=6, max_new=4):
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (batch_size, plen)).astype(np.int32)
    reqs = [Request(app=app, prompt=prompts[i], max_new=max_new,
                    arrival_ms=0.0) for i in range(batch_size)]
    return Batch(app, reqs, prompts, max_new)


# ---------------------------------------------------------------------------
# Charge / release protocol
# ---------------------------------------------------------------------------
def test_kv_charged_during_execution_released_after(cfgs):
    srv = make_server()
    srv.start()
    app = TENANTS[0]
    kv_expect = kv_cache_mb(cfgs[app], 2, 6 + 4)
    seen = {}

    def probing_executor(runtime, batch, extra=None):
        seen["kv_during"] = srv.manager.state.kv_mb
        return stub_executor(runtime, batch)

    srv.engine._executor = probing_executor
    results, _, toks = srv.engine.execute_batch(
        one_batch(app, cfgs[app]), now_ms=0.0)
    assert toks is not None and not results[0].failed
    assert seen["kv_during"] == pytest.approx(kv_expect)
    # Retirement is per request: each result carries its own share of
    # the charge (equal max_new -> equal split), summing to the total.
    assert sum(r.kv_mb for r in results) == pytest.approx(kv_expect)
    assert results[0].kv_mb == pytest.approx(kv_expect / 2)
    assert srv.manager.state.kv_mb == 0.0, "released on retirement"
    assert srv.manager.state.tenants[app].kv_mb == 0.0


def test_kv_released_when_executor_raises(cfgs):
    """A crashed batch (XLA OOM, bad inputs) must not leak its charge."""
    srv = make_server()
    srv.start()

    def boom(runtime, batch, extra=None):
        raise RuntimeError("simulated XLA OOM")

    srv.engine._executor = boom
    with pytest.raises(RuntimeError):
        srv.engine.execute_batch(one_batch(TENANTS[0], cfgs[TENANTS[0]]),
                                 now_ms=0.0)
    assert srv.manager.state.kv_mb == 0.0, "charge released on crash"
    assert srv.engine.events[-1].kind == "retire", "audit trail balances"
    # The crashed batch's requests are recorded as failures, not lost.
    assert len(srv.engine.results) == 2
    assert all(r.failed and not r.warm for r in srv.engine.results)
    srv.engine.check_event_invariant()


def test_kv_sized_from_real_cache_pytree(cfgs):
    cfg = cfgs[TENANTS[0]]
    cache = T.init_cache(cfg, 2, 10)
    nbytes = sum(np.asarray(leaf).nbytes
                 for leaf in jax.tree.leaves(cache))
    assert kv_cache_mb(cfg, 2, 10) == pytest.approx(nbytes / (1024 * 1024))


def test_event_invariant_covers_devices_with_sharded_loads(cfgs):
    """On a sharded mesh every audit event snapshots per-device weights
    + shard claims, and ``check_event_invariant`` holds them to the
    per-chip budgets while sharded loads are in flight."""
    from repro.serving.api import SimTenant

    srv = EdgeServer(budget_mb=0.0, policy="iws-bfe", delta_ms=1000.0,
                     max_batch=4, sharded_mesh=(4,))
    for name in TENANTS:
        srv.register_tenant(name, SimTenant(name, cfgs[name]))
    srv.budget_mb = srv.contention_budget(0.05)
    srv.start()
    trace, _ = poisson_trace(cfgs, requests_per_app=15,
                             mean_iat_ms=300.0, seed=3)
    stats = srv.engine.run_trace(trace)
    assert stats.requests == len(trace)
    assert stats.shards_landed > 0, "the mesh path actually staged"
    srv.engine.check_event_invariant()
    loads = [e for e in srv.engine.events
             if e.kind in ("prefetch", "demand")]
    assert loads and all(e.device_mb is not None for e in loads)
    assert any(max(e.device_mb) > 0 for e in loads), \
        "claims visible per device while loads are in flight"
    # A tampered snapshot must trip the per-device check.
    bad = srv.engine.events[-1]
    bad.device_mb = tuple(b + 1.0
                          for b in srv.manager.state.devices.budgets_mb)
    with pytest.raises(AssertionError, match="device"):
        srv.engine.check_event_invariant()
    srv.close()


def test_event_log_and_invariant_under_contention(cfgs):
    srv = make_server(max_batch=4)
    srv.budget_mb = srv.contention_budget(0.1)
    srv.start()
    srv.engine._executor = stub_executor
    trace, _ = poisson_trace(cfgs, requests_per_app=15,
                             mean_iat_ms=300.0, seed=3)
    stats = srv.engine.run_trace(trace)
    assert stats.requests == len(trace)
    srv.engine.check_event_invariant()  # used_mb ≤ budget at every event
    kinds = {e.kind for e in srv.engine.events}
    assert {"submit", "admit", "retire"} <= kinds
    admits = sum(e.kind == "admit" for e in srv.engine.events)
    retires = sum(e.kind == "retire" for e in srv.engine.events)
    assert admits == retires, "every admitted batch must retire"
    assert srv.manager.state.kv_mb == 0.0


# ---------------------------------------------------------------------------
# Over-budget admission: downgrade or counted failure, never an assert
# ---------------------------------------------------------------------------
def test_overbudget_admit_downgrades_at_procure_without_thrash(cfgs):
    app = TENANTS[0]
    srv = make_server()
    zoo = srv.tenants[app].zoo
    kv = kv_cache_mb(cfgs[app], 2, 6 + 4)
    # bf16 fits but not bf16+cache; int8+cache fits
    srv.budget_mb = zoo.by_bits(16).size_mb + 0.5 * kv
    assert (zoo.by_bits(16).size_mb - zoo.by_bits(8).size_mb) > 0.5 * kv
    srv.start()
    srv.engine._executor = stub_executor
    loads = []
    orig = srv.tenants[app].set_variant
    srv.tenants[app].set_variant = lambda v: (loads.append(v), orig(v))
    results, _, toks = srv.engine.execute_batch(
        one_batch(app, cfgs[app]), now_ms=0.0)
    assert toks is not None and not results[0].failed
    assert results[0].bits == 8, "requester downgraded to fit its cache"
    # KV-aware procurement picks int8 directly: ONE weight transfer, not
    # a bf16 load immediately thrashed down to int8.
    assert [v.bits for v in loads] == [8]
    srv.engine.check_event_invariant()


def test_overbudget_admit_counted_failure_not_assert(cfgs):
    app = TENANTS[0]
    srv = make_server()
    zoo = srv.tenants[app].zoo
    big_kv = kv_cache_mb(cfgs[app], 8, 64)
    srv.budget_mb = zoo.by_bits(8).size_mb + 0.25 * big_kv
    srv.start()
    srv.engine._executor = stub_executor
    batch = one_batch(app, cfgs[app], batch_size=8, plen=32, max_new=32)
    results, _, toks = srv.engine.execute_batch(batch, now_ms=0.0)
    assert toks is None
    assert all(r.failed for r in results)
    assert srv.engine.kv_rejections == 1
    assert srv.manager.kv_rejections == 1
    assert srv.manager.state.kv_mb == 0.0
    srv.engine.check_event_invariant()  # rejection never overcommits


# ---------------------------------------------------------------------------
# Manager-level protocol (synthetic zoos, no models)
# ---------------------------------------------------------------------------
def _zoo(name, sizes):
    return ModelZoo(app_name=name, variants=tuple(
        ModelVariant(f"{name}-{i}", bits=32 >> i, size_mb=s,
                     accuracy=90.0 - 10 * i, load_ms=s * 2)
        for i, s in enumerate(sizes)))


def test_manager_admit_release_cycle():
    mgr = EdgeMultiAI({"a": _zoo("a", [500, 300]),
                       "b": _zoo("b", [400, 200])},
                      budget_mb=1000.0, policy="iws-bfe", delta_ms=10.0)
    adm = mgr.admit_batch("a", now=0.0, kv_mb=120.0)
    assert not adm.failed and adm.kv_mb == 120.0
    assert mgr.state.tenants["a"].kv_mb == 120.0
    assert mgr.state.used_mb == pytest.approx(500.0 + 120.0)
    mgr.release_kv("a", adm.kv_mb)
    assert mgr.state.kv_mb == 0.0
    assert mgr.state.used_mb == pytest.approx(500.0)


def test_manager_kv_pressure_scavenges_victim():
    mgr = EdgeMultiAI({"a": _zoo("a", [500, 300]),
                       "b": _zoo("b", [400, 200])},
                      budget_mb=950.0, policy="iws-bfe", delta_ms=10.0,
                      history_ms=10.0)
    mgr.state.load("b", mgr.state.tenants["b"].zoo.largest)  # 400
    mgr.state.tenants["b"].last_request = -1000.0  # outside LRU-K history
    # a loads 500 -> free 50; KV of 150 forces scavenging b down to 200
    adm = mgr.admit_batch("a", now=0.0, kv_mb=150.0)
    assert not adm.failed
    assert mgr.state.tenants["b"].loaded.size_mb == 200.0
    assert mgr.state.used_mb <= mgr.state.budget_mb + 1e-6


def test_manager_warm_tenant_self_downgrades_for_cache():
    """A tenant already warm at a large variant shrinks itself when its
    next batch's cache no longer fits beside the big weights."""
    mgr = EdgeMultiAI({"a": _zoo("a", [500, 300])},
                      budget_mb=520.0, policy="iws-bfe", delta_ms=10.0)
    mgr.state.load("a", mgr.state.tenants["a"].zoo.largest)  # warm at 500
    adm = mgr.admit_batch("a", now=0.0, kv_mb=100.0)
    assert not adm.failed and adm.warm
    assert adm.self_downgraded
    served = mgr.state.tenants["a"].loaded
    assert served.size_mb == 300.0
    assert mgr.state.used_mb == pytest.approx(400.0)
    # The inference record describes the variant that actually serves.
    rec = mgr.records[-1]
    assert rec.bits == served.bits == adm.bits
    assert rec.accuracy == served.accuracy


def test_manager_rejects_impossible_kv_without_assert():
    mgr = EdgeMultiAI({"a": _zoo("a", [500, 300])},
                      budget_mb=600.0, policy="iws-bfe", delta_ms=10.0)
    adm = mgr.admit_batch("a", now=0.0, kv_mb=1e6)
    assert adm.failed and adm.kv_mb == 0.0
    assert adm.kv_rejected, "weights were procurable; the cache was not"
    assert mgr.kv_rejections == 1
    assert mgr.state.kv_mb == 0.0
    mgr.state.check_invariant()  # state stayed consistent
    # Metrics must agree with the admission outcome: no phantom success.
    rec = mgr.records[-1]
    assert rec.failed and not rec.warm and rec.bits is None
    assert mgr.metrics().fail_ratio == 1.0


def test_manager_warm_rejection_retracts_success_record():
    """A warm tenant whose cache cannot fit even after self-downgrade is
    rejected — and the success record on_request logged is retracted so
    Metrics agree with the engine's view."""
    mgr = EdgeMultiAI({"a": _zoo("a", [500, 300])},
                      budget_mb=520.0, policy="iws-bfe", delta_ms=10.0)
    mgr.state.load("a", mgr.state.tenants["a"].zoo.largest)  # warm at 500
    adm = mgr.admit_batch("a", now=0.0, kv_mb=300.0)  # 220 free after dgrade
    assert adm.failed and adm.kv_rejected
    assert not adm.warm, "a rejected request is not a warm serve"
    rec = mgr.records[-1]
    assert rec.failed and not rec.warm and rec.bits is None
    assert mgr.metrics().fail_ratio == 1.0


def test_manager_weight_failure_not_counted_as_kv():
    """A tenant whose smallest variant cannot fit at all is a weight
    failure, not a KV rejection."""
    mgr = EdgeMultiAI({"a": _zoo("a", [500, 300])},
                      budget_mb=100.0, policy="iws-bfe", delta_ms=10.0)
    adm = mgr.admit_batch("a", now=0.0, kv_mb=1.0)
    assert adm.failed and not adm.kv_rejected
    assert mgr.kv_rejections == 0


def test_memory_state_kv_reserve_release_invariants():
    s = MemoryState(budget_mb=100.0,
                    tenants={"a": TenantState(zoo=_zoo("a", [50, 20]))})
    s.reserve_kv("a", 30.0)
    assert s.kv_mb == 30.0 and s.used_mb == 30.0 and s.free_mb == 70.0
    with pytest.raises(ValueError):
        s.reserve_kv("a", -1.0)
    s.release_kv("a", 100.0)  # over-release clamps at zero
    assert s.tenants["a"].kv_mb == 0.0


# ---------------------------------------------------------------------------
# Async entry + stats schema
# ---------------------------------------------------------------------------
def test_run_async_and_stats_schema(cfgs):
    srv = make_server()
    srv.start()
    srv.engine._executor = stub_executor
    trace, _ = poisson_trace(cfgs, requests_per_app=5,
                             mean_iat_ms=500.0, seed=1)
    stats = asyncio.run(srv.engine.run_async(trace))
    assert stats.requests == len(trace)
    assert stats.requests_per_sec is not None
    assert "requests_per_sec" in stats.to_dict()
    for app in TENANTS:
        s = stats.per_tenant[app]
        for key in ("p50_ms", "p95_ms", "p99_ms", "warm_ratio",
                    "fail_ratio", "throughput_rps", "mean_batch"):
            assert key in s
        assert s["p50_ms"] <= s["p95_ms"] <= s["p99_ms"]
    # server.stats() surfaces the engine view
    sstats = srv.stats()
    assert sstats.per_tenant.keys() == stats.per_tenant.keys()
    assert sstats.kv_mb == 0.0
