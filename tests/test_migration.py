"""Cross-device victim migration tests: the planner, MigrateShard under
the transactional applier (per-event DeviceLedger invariant included),
the sharded loader's migrate-instead-of-fail path, the admission-path
migration, and sim-time determinism of a migrating engine run.

Synthetic zoos drive manager + channel directly; engine runs build the
declarative sim stack on a deliberately skewed mesh (one tight chip,
roomy neighbors — the regime migration exists for).
"""
import pytest

from repro.configs import get_config
from repro.core import EdgeMultiAI
from repro.core import actions as A
from repro.core.memory_state import DeviceLedger
from repro.core.model_zoo import ModelVariant, ModelZoo, zoo_from_config
from repro.distributed import sharding as SH
from repro.serving import EdgeServer, poisson_trace
from repro.serving.api import SimTenant
from repro.serving.sharded_loader import ShardedLoaderChannel

N_DEV = 4


def _zoo(name, sizes):
    return ModelZoo(app_name=name, variants=tuple(
        ModelVariant(f"{name}-{i}", bits=32 >> i, size_mb=s,
                     accuracy=90.0 - 10 * i, load_ms=s * 2)
        for i, s in enumerate(sizes)))


def make_manager(budgets, migrate=True, budget_mb=2000.0):
    zoos = {"a": _zoo("a", [500, 300]), "b": _zoo("b", [400, 200])}
    mgr = EdgeMultiAI(zoos, budget_mb=budget_mb, policy="iws-bfe",
                      delta_ms=10.0, migrate=migrate)
    mgr.state.devices = DeviceLedger(
        tuple(budgets),
        split_fn=lambda app, v: SH.variant_shard_mb(v.size_mb, N_DEV))
    return mgr


# ---------------------------------------------------------------------------
# The planner
# ---------------------------------------------------------------------------
def test_plan_migration_moves_victim_shard_off_the_tight_chip():
    mgr = make_manager(budgets=(150.0, 400.0, 400.0, 400.0))
    st = mgr.state
    st.apply(A.plan_of(A.Load("b", st.tenants["b"].zoo.largest)))  # 100/chip
    claims = (125.0,) * N_DEV  # a.bf16: blocked on chip 0 (free 50)
    assert not st.devices.fits(claims)
    moves = A.plan_migration(st, "a", claims)
    assert moves is not None and len(moves) == 1
    mv = moves[0]
    assert mv.app == "b" and mv.src == 0 and mv.mb == pytest.approx(100.0)
    assert mv.dst != 0
    # The moves + the staged load simulate clean as one atomic group.
    plan = A.ResidencyPlan(moves + (
        A.Load("a", st.tenants["a"].zoo.largest, staged=True,
               claim_mb=500.0, shard_claims=claims),))
    assert st.simulate(plan) is None
    st.apply(plan)
    st.devices.check_invariant()
    assert st.devices.weights["b"][0] == 0.0
    assert st.devices.shards_migrated == 1


def test_plan_migration_respects_frozen_tenants_and_gives_up_cleanly():
    mgr = make_manager(budgets=(150.0, 400.0, 400.0, 400.0))
    st = mgr.state
    st.apply(A.plan_of(A.Load("b", st.tenants["b"].zoo.largest)))
    # The only victim is mid-staging: the loader owns its residency.
    st.tenants["b"].inflight_mb = 1.0
    assert A.plan_migration(st, "a", (125.0,) * N_DEV) is None
    st.tenants["b"].inflight_mb = 0.0
    # No destination has room: uniform tight chips, nothing to relieve.
    mgr2 = make_manager(budgets=(150.0,) * N_DEV)
    st2 = mgr2.state
    st2.apply(A.plan_of(A.Load("b", st2.tenants["b"].zoo.largest)))
    assert A.plan_migration(st2, "a", (125.0,) * N_DEV) is None


def test_downgrading_migrated_victim_keeps_layout_and_budgets():
    """Regression: a migrated victim's later downgrade must scale its
    *actual* layout in place — re-deriving the canonical split would put
    weight back on the chip it vacated and silently break the per-chip
    budget migration just restored."""
    mgr = make_manager(budgets=(150.0, 400.0, 400.0, 400.0))
    st = mgr.state
    za, zb = st.tenants["a"].zoo, st.tenants["b"].zoo
    st.apply(A.plan_of(A.Load("b", zb.largest)))  # 100/chip
    claims = (125.0,) * N_DEV
    moves = A.plan_migration(st, "a", claims)
    st.apply(A.ResidencyPlan(moves + (
        A.Load("a", za.largest, staged=True, claim_mb=500.0,
               shard_claims=claims),)))
    st.apply(A.plan_of(A.Load("a", za.largest, claim_mb=500.0,
                              shard_claims=claims)))  # commit: 125/chip
    st.devices.check_invariant()
    # Downgrade the migrated victim: its layout scales (chip 0 stays
    # vacated), every chip stays in budget.
    st.apply(A.plan_of(A.Downgrade("b", zb.smallest)))
    assert st.devices.weights["b"][0] == 0.0, "vacated chip stays vacated"
    assert sum(st.devices.weights["b"]) == pytest.approx(200.0)
    st.devices.check_invariant()
    # And an upgrade back scales the same layout, claim-checked exactly.
    act = A.staged_load_action(st, "b", zb.largest)
    assert act.shard_claims[0] == 0.0, "no claim on the vacated chip"
    assert sum(act.shard_claims) == pytest.approx(200.0)
    st.apply(A.plan_of(act))
    st.apply(A.plan_of(A.Load("b", zb.largest, claim_mb=act.claim_mb,
                              shard_claims=act.shard_claims)))
    assert st.devices.weights["b"][0] == 0.0
    st.devices.check_invariant()


def test_migrate_shard_validates_source_and_destination():
    mgr = make_manager(budgets=(150.0, 110.0, 400.0, 400.0))
    st = mgr.state
    st.apply(A.plan_of(A.Load("b", st.tenants["b"].zoo.largest)))
    before_weights = dict(st.devices.weights)
    with pytest.raises(A.PlanError):  # b holds only 100 on chip 0
        st.apply(A.plan_of(A.MigrateShard("b", 0, 2, 150.0)))
    with pytest.raises(A.PlanError):  # chip 1 cannot absorb 100 more
        st.apply(A.plan_of(A.MigrateShard("b", 0, 1, 100.0)))
    assert dict(st.devices.weights) == before_weights, "rollback clean"
    assert st.devices.shards_migrated == 0


# ---------------------------------------------------------------------------
# Sharded loader: migrate instead of failing the whole load
# ---------------------------------------------------------------------------
def _blocked_fixture(migrate):
    mgr = make_manager(budgets=(150.0, 400.0, 400.0, 400.0),
                       migrate=migrate)
    st = mgr.state
    st.apply(A.plan_of(A.Load("b", st.tenants["b"].zoo.largest)))
    loader = ShardedLoaderChannel(mgr, n_devices=N_DEV, migrate=migrate)
    return mgr, loader


def test_blocked_load_migrates_victim_and_lands():
    mgr, loader = _blocked_fixture(migrate=True)
    st = mgr.state
    plan = mgr.plan_demand("a", 0.0)
    assert plan is not None and plan.variant.size_mb == 500.0
    ld = loader.enqueue(plan, 0.0, demand=True)
    assert ld is not None, "migration funded the chip, load staged"
    assert st.devices.shards_migrated == 1
    assert st.devices.weights["b"][0] == 0.0, "victim shard moved off"
    assert st.inflight_mb == 500.0
    st.devices.check_invariant()
    loader.reap(ld.ready_ms)
    assert st.tenants["a"].loaded.size_mb == 500.0
    assert st.inflight_mb == 0.0 and st.devices.inflight == {}
    st.devices.check_invariant()
    loader.close()


def test_blocked_load_without_migration_fails_cleanly_as_before():
    mgr, loader = _blocked_fixture(migrate=False)
    st = mgr.state
    assert loader.enqueue(mgr.plan_demand("a", 0.0), 0.0,
                          demand=True) is None
    assert st.inflight_mb == 0.0 and st.devices.inflight == {}
    assert st.devices.shards_migrated == 0
    loader.close()


def test_loader_emits_migrate_event():
    mgr, loader = _blocked_fixture(migrate=True)
    events = []
    loader.on_event = lambda t, kind, app, mb: events.append((kind, app))
    assert loader.enqueue(mgr.plan_demand("a", 0.0), 0.0) is not None
    assert ("migrate", "b") in events
    loader.close()


# ---------------------------------------------------------------------------
# Admission path: migrate before downgrading the whole load
# ---------------------------------------------------------------------------
def test_admission_migration_vs_downgrade_only():
    for migrate, want_bits, want_moves in ((True, 32, 1), (False, 16, 0)):
        mgr = make_manager(budgets=(200.0, 500.0, 500.0, 500.0),
                           migrate=migrate)
        migrations = []
        mgr.on_migrate = lambda t, app, mb: migrations.append((t, app, mb))
        st = mgr.state
        st.apply(A.plan_of(A.Load("b", st.tenants["b"].zoo.largest)))
        adm = mgr.admit_batch("a", now=7.0, kv_mb=0.0)
        assert not adm.failed
        assert adm.bits == want_bits, \
            f"migrate={migrate}: served at {adm.bits} bits"
        assert adm.self_downgraded == (not migrate)
        assert st.devices.shards_migrated == want_moves
        # Admission-path moves surface through the observer hook (the
        # serving runtime wires it into the engine audit trail).
        assert migrations == ([(7.0, "b", 100.0)] if migrate else [])
        st.devices.check_invariant()


# ---------------------------------------------------------------------------
# Engine integration on a skewed sim mesh: invariant + determinism
# ---------------------------------------------------------------------------
def _skewed_budgets(names, tight=0.7, roomy=3.0):
    """Per-chip budgets around the derived default: chip 0 tight enough
    to block bf16 upgrades once every tenant is resident, neighbors
    roomy enough to absorb a migrated shard."""
    mesh = SH.serving_mesh((N_DEV,))
    shard8 = shard16 = 0.0
    for name in names:
        cfg = get_config(name, reduced=True)
        zoo = zoo_from_config(cfg, precisions=(16, 8))
        frac = SH.weight_shard_fraction(cfg, mesh)
        shard8 += zoo.by_bits(8).size_mb * frac
        shard16 += zoo.by_bits(16).size_mb * frac
    tight_mb = shard8 + tight * (shard16 - shard8)
    return (tight_mb,) + (roomy * shard16,) * (N_DEV - 1)


def _skewed_run(migrate, names=("tinyllama-1.1b", "mamba2-780m"), seed=0):
    srv = EdgeServer(budget_mb=0.0, policy="iws-bfe", delta_ms=750.0,
                     max_batch=4, sharded_mesh=(N_DEV,),
                     device_budget_mb=_skewed_budgets(names),
                     migrate=migrate)
    for name in names:
        srv.register_tenant(name, SimTenant(name, get_config(
            name, reduced=True)))
    srv.budget_mb = srv.contention_budget(0.05)
    srv.start()
    cfgs = {n: t.cfg for n, t in srv.tenants.items()}
    trace, _ = poisson_trace(cfgs, requests_per_app=15,
                             mean_iat_ms=400.0, seed=seed)
    stats = srv.engine.run_trace(trace)
    srv.engine.check_event_invariant()
    base = min(r.rid for r in srv.engine.results)
    results = [(r.rid - base, r.app, r.arrival_ms, r.done_ms, r.warm,
                r.failed, r.bits) for r in srv.engine.results]
    srv.close()
    return stats, results


def test_migration_preserves_per_event_device_invariant():
    """A full migrating engine run holds every per-event per-chip budget
    (check_event_invariant inside _skewed_run), and migration admits the
    staged loads the downgrade-only path could not even begin: with the
    tight chip, the blocked channel stages nothing speculative (zero
    prefetch hits), while migration funds the chip and the prefetches
    land."""
    stats, _ = _skewed_run(migrate=True)
    assert stats.shards_migrated > 0, "the skewed mesh migrated"
    off, _ = _skewed_run(migrate=False)
    assert off.shards_migrated == 0
    assert off.prefetch_hits == 0, "blocked chip kills every prefetch"
    assert stats.prefetch_hits > 0, "migration admits those loads"
    assert stats.warm_ratio >= off.warm_ratio


def test_migrating_sim_run_is_bit_deterministic():
    s1, r1 = _skewed_run(migrate=True)
    s2, r2 = _skewed_run(migrate=True)
    assert r1 == r2
    assert s1 == s2
