"""Paged KV cache + continuous batching.

Covers the page pool's conservation invariant under charge / evict /
crash-release, simulate ≡ apply for page-granular actions on a device
ledger, the over-release accounting the scalar clamp used to hide, the
per-instance batcher counter, per-request retirement in the scalar
engine, and the continuous-batching engine's join/leave determinism,
KV-rejection advantage, and page preemption path.
"""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import actions as A
from repro.core.memory_state import (DeviceLedger, KVPagePool, MemoryState,
                                     TenantState)
from repro.core.model_zoo import ModelVariant, ModelZoo
from repro.serving import EdgeServer, poisson_trace
from repro.serving.api import BatchingSpec, ServingConfig, TenantSpec
from repro.serving.batcher import Batcher, Request

TENANTS = ["tinyllama-1.1b", "mamba2-780m"]


def _zoo(name, sizes):
    return ModelZoo(app_name=name, variants=tuple(
        ModelVariant(f"{name}-{i}", bits=32 >> i, size_mb=s,
                     accuracy=90.0 - 10 * i, load_ms=s * 2)
        for i, s in enumerate(sizes)))


@pytest.fixture(scope="module")
def cfgs():
    return {n: get_config(n, reduced=True) for n in TENANTS}


def sim_config(*, continuous, max_batch=4, budget_mb=None,
               kv_headroom_shape=(4, 128), kv_page_mb=0.0,
               window_ms=0.0, fallback="desperation"):
    return ServingConfig(
        tenants=tuple(TenantSpec(n) for n in TENANTS),
        executor="sim",
        budget_mb=budget_mb,
        kv_headroom_shape=kv_headroom_shape,
        fallback=fallback,
        batching=BatchingSpec(max_batch=max_batch, continuous=continuous,
                              kv_page_mb=kv_page_mb,
                              window_ms=window_ms),
    )


# ---------------------------------------------------------------------------
# KVPagePool: conservation under charge / evict / crash-release
# ---------------------------------------------------------------------------
def test_page_conservation_and_id_reuse():
    pool = KVPagePool(2.0, 8)
    a = pool.allocate("a", 1, 3)
    b = pool.allocate("b", 7, 2)
    pool.check_invariant()
    assert pool.free_pages == 3 and pool.used_pages == 5
    assert a == (0, 1, 2) and b == (3, 4)  # lowest free id first
    assert pool.release("a", 1) == 3
    pool.check_invariant()
    # Freed ids go back to the front of the free list and are reused.
    c = pool.allocate("c", 9, 2)
    assert c == (0, 1)
    # Unknown sequence releases nothing (the caller accounts the drift).
    assert pool.release("a", 999) == 0
    # Crash-release drops every sequence a tenant holds.
    pool.allocate("b", 8, 1)
    assert pool.release_app("b") == 3
    pool.check_invariant()
    assert pool.free_pages == 6 and pool.held_pages("b") == 0


def test_pool_rejects_double_charge_and_exhaustion():
    pool = KVPagePool(1.0, 4)
    pool.allocate("a", 1, 2)
    with pytest.raises(A.PlanError, match="already holds"):
        pool.allocate("a", 1, 1)
    with pytest.raises(A.PlanError, match="exhausted"):
        pool.allocate("b", 2, 3)
    pool.check_invariant()
    assert pool.free_pages == 2, "failed allocation must not leak"


def test_pool_pages_for_rounding():
    pool = KVPagePool(2.0, 4)
    assert pool.pages_for(0.0) == 0
    assert pool.pages_for(0.1) == 1
    assert pool.pages_for(2.0) == 1  # exact fit does not round up
    assert pool.pages_for(2.1) == 2
    assert pool.pages_for(4.0) == 2


def test_pool_device_partition_and_balance():
    pool = KVPagePool(1.0, device_pages=(2, 4))
    assert [pool.device_of(p) for p in range(6)] == [0, 0, 1, 1, 1, 1]
    # Allocation drains the device with the most free pages first.
    got = pool.allocate("a", 1, 3)
    assert got == (2, 3, 0), "most-free device first, ties to lowest"
    pool.check_invariant()


def test_pool_victims_youngest_first():
    pool = KVPagePool(1.0, 8)
    pool.allocate("a", 1, 2)
    pool.allocate("b", 2, 3)
    pool.allocate("a", 3, 1)
    assert pool.victim_seqs(exclude="c") == [
        ("a", 3, 1), ("b", 2, 3), ("a", 1, 2)]
    assert pool.victim_seqs(exclude="a") == [("b", 2, 3)]


# ---------------------------------------------------------------------------
# simulate ≡ apply for page actions (device-ledger state)
# ---------------------------------------------------------------------------
def _paged_state(n_pages=6, page_mb=10.0, devices=False):
    st = MemoryState(budget_mb=1000.0, tenants={
        "a": TenantState(zoo=_zoo("a", [300, 150])),
        "b": TenantState(zoo=_zoo("b", [200, 100]))})
    if devices:
        st.devices = DeviceLedger(
            (500.0, 500.0),
            split_fn=lambda app, v: (v.size_mb / 2,) * 2)
        st.kv_pool = KVPagePool(page_mb,
                                device_pages=(n_pages // 2, n_pages // 2))
    else:
        st.kv_pool = KVPagePool(page_mb, n_pages)
    return st


def _digest(st):
    pool = st.kv_pool
    return ({a: (t.loaded, t.kv_mb, t.inflight_mb)
             for a, t in st.tenants.items()},
            st.pending_mb, st.kv_overrelease_mb,
            tuple(tuple(f) for f in pool.free),
            {a: dict(t) for a, t in pool.tables.items()})


@pytest.mark.parametrize("devices", [False, True])
def test_simulate_matches_apply_for_page_actions(devices):
    st = _paged_state(devices=devices)
    plan = A.ResidencyPlan((
        A.ChargeKV("a", 25.0, seq=1),   # 3 pages
        A.ChargeKV("b", 10.0, seq=2),   # 1 page
        A.EvictKV("a", 0.0, seq=1),
    ))
    before = _digest(st)
    assert st.simulate(plan) is None
    assert _digest(st) == before, "simulate must not mutate"
    st.apply(plan)
    st.check_invariant()
    assert st.kv_pool.held_pages("a") == 0
    assert st.kv_pool.held_pages("b") == 1
    assert st.tenants["b"].kv_mb == pytest.approx(10.0)
    assert st.tenants["a"].kv_mb == 0.0


@pytest.mark.parametrize("devices", [False, True])
def test_infeasible_page_plan_rolls_back(devices):
    st = _paged_state(devices=devices)
    st.apply(A.ResidencyPlan((A.ChargeKV("a", 40.0, seq=1),)))  # 4 of 6
    before = _digest(st)
    bad = A.ResidencyPlan((
        A.ChargeKV("b", 10.0, seq=2),
        A.ChargeKV("b", 20.0, seq=3),   # 1 + 2 pages > 2 free
    ))
    assert st.simulate(bad) is not None
    assert _digest(st) == before, "failed simulate must not mutate"
    with pytest.raises(A.PlanError):
        st.apply(bad)
    assert _digest(st) == before, "failed apply must roll back the pool"
    st.check_invariant()


def test_charge_is_page_rounded():
    st = _paged_state(n_pages=6, page_mb=10.0)
    st.apply(A.ResidencyPlan((A.ChargeKV("a", 11.0, seq=1),)))
    assert st.kv_pool.held_pages("a") == 2
    assert st.tenants["a"].kv_mb == pytest.approx(20.0), \
        "the charge is the page-rounded footprint, not the raw need"
    st.apply(A.ResidencyPlan((A.EvictKV("a", 0.0, seq=1),)))
    assert st.tenants["a"].kv_mb == 0.0 and st.kv_overrelease_mb == 0.0


# ---------------------------------------------------------------------------
# Over-release accounting (the drift the scalar clamp hid)
# ---------------------------------------------------------------------------
def test_overrelease_counted_and_audited():
    st = MemoryState(budget_mb=100.0, tenants={
        "a": TenantState(zoo=_zoo("a", [50, 20]))})
    audits = []
    st.on_audit = lambda kind, app, mb: audits.append((kind, app, mb))
    st.reserve_kv("a", 30.0)
    st.release_kv("a", 50.0)  # 20 MB of drift
    assert st.tenants["a"].kv_mb == 0.0, "still clamps (compat)"
    assert st.kv_overrelease_mb == pytest.approx(20.0)
    assert audits == [("kv_overrelease", "a", pytest.approx(20.0))]


def test_overrelease_raises_under_strict():
    st = MemoryState(budget_mb=100.0, tenants={
        "a": TenantState(zoo=_zoo("a", [50, 20]))})
    st.strict_kv = True
    st.reserve_kv("a", 30.0)
    with pytest.raises(AssertionError, match="over-release"):
        st.release_kv("a", 50.0)


def test_overrelease_in_plan_is_plan_error_and_rolls_back():
    st = _paged_state()
    st.strict_kv = True
    st.apply(A.ResidencyPlan((A.ChargeKV("a", 10.0, seq=1),)))
    before = _digest(st)
    bad = A.ResidencyPlan((A.EvictKV("a", 50.0),))  # scalar over-release
    assert st.simulate(bad) is not None, "strict drift fails simulate"
    with pytest.raises(A.PlanError):
        st.apply(bad)
    assert _digest(st) == before
    st.check_invariant()


# ---------------------------------------------------------------------------
# Batcher: per-instance request ids (two builds, one process)
# ---------------------------------------------------------------------------
def test_batcher_ids_are_per_instance():
    b1, b2 = Batcher(), Batcher()
    r1 = b1.assign(Request(app="a", prompt=np.zeros(4, np.int32),
                           max_new=4, arrival_ms=0.0))
    r2 = b2.assign(Request(app="a", prompt=np.zeros(4, np.int32),
                           max_new=4, arrival_ms=0.0))
    assert r1.rid == 0 and r2.rid == 0, \
        "a second build must not inherit the first stack's counter"
    assert b1.assign(r1).rid == 0, "assign is idempotent"


def test_two_builds_one_process_identical(cfgs):
    """Two EdgeServer.build stacks in one process replay the same trace
    to identical results — the bug was a module-global id counter that
    made the second stack's tie-breaks depend on the first's history."""
    outs = []
    for _ in range(2):
        srv = EdgeServer.build(sim_config(continuous=False))
        trace, _ = poisson_trace(cfgs, requests_per_app=12,
                                 mean_iat_ms=250.0, seed=5)
        srv.engine.run_trace(trace)
        outs.append([(r.rid, r.app, r.arrival_ms, r.done_ms, r.warm,
                      r.failed) for r in srv.engine.results])
        srv.close()
    assert outs[0] == outs[1]


# ---------------------------------------------------------------------------
# Scalar engine: per-request retirement (no whole-batch max_new hold)
# ---------------------------------------------------------------------------
def test_short_requests_retire_before_long(cfgs):
    srv = EdgeServer.build(sim_config(continuous=False))
    app = TENANTS[0]
    prompts = np.zeros((3, 6), np.int32)
    reqs = [srv.engine.batcher.assign(
        Request(app=app, prompt=prompts[i], max_new=mn, arrival_ms=0.0))
        for i, mn in enumerate((2, 16, 4))]
    from repro.serving.batcher import Batch
    batch = Batch(app, reqs, prompts, max(r.max_new for r in reqs))
    results, _, toks = srv.engine.execute_batch(batch, now_ms=0.0)
    assert toks is not None
    by_new = {r.rid: res for r, res in zip(reqs, results)}
    assert by_new[reqs[0].rid].done_ms < by_new[reqs[1].rid].done_ms
    assert by_new[reqs[2].rid].done_ms < by_new[reqs[1].rid].done_ms
    # The per-request shares drain the charge exactly (no float residue).
    assert srv.manager.state.kv_mb == 0.0
    assert all(res.kv_mb > 0 for res in results)
    assert srv.manager.state.kv_overrelease_mb == 0.0
    srv.engine.check_event_invariant()
    srv.close()


# ---------------------------------------------------------------------------
# Continuous batching: determinism, fewer rejections, preemption
# ---------------------------------------------------------------------------
def _run(cfgs, *, continuous, seed=3, n=20, iat=300.0, max_new=8, **kw):
    srv = EdgeServer.build(sim_config(continuous=continuous, **kw))
    trace, _ = poisson_trace(cfgs, requests_per_app=n,
                             mean_iat_ms=iat, seed=seed, max_new=max_new)
    stats = srv.engine.run_trace(trace)
    srv.engine.check_event_invariant()
    srv.close()
    return srv, stats


def test_continuous_join_leave_deterministic(cfgs):
    outs = []
    for _ in range(2):
        srv, stats = _run(cfgs, continuous=True)
        assert stats.requests == 40
        outs.append([(r.rid, r.app, r.done_ms, r.warm, r.failed, r.kv_mb)
                     for r in srv.engine.results])
    assert outs[0] == outs[1]


def test_continuous_pool_drains_on_completion(cfgs):
    srv, stats = _run(cfgs, continuous=True)
    assert stats.kv_pages_used == 0, "every retired seq freed its pages"
    assert srv.manager.state.kv_mb == 0.0
    assert stats.kv_overrelease_mb == 0.0, \
        "page-granular release cannot drift from its charge"


# The contention regime the A/B gate runs in: a KV budget too small for
# whole max_batch batches (the derived budget minus the serving tenant's
# smallest weights cannot fund kv(8, prompt+max_new)), arrivals dense
# enough that the 50 ms batching window actually forms full batches.
CONTENTION = dict(budget_mb=0.30, max_batch=8, window_ms=50.0,
                  n=24, iat=1.0, max_new=120, seed=11)


def test_continuous_fewer_kv_rejections_than_scalar(cfgs):
    """The acceptance gate's mechanism, in miniature: under a KV budget
    too small for whole batches, page-granular admission keeps accepting
    single requests where the batch-scalar path rejects wholesale."""
    _, scalar = _run(cfgs, continuous=False, **CONTENTION)
    _, paged = _run(cfgs, continuous=True, **CONTENTION)
    assert scalar.kv_rejections > 0, "the scenario actually contends"
    assert scalar.kv_rejections > paged.kv_rejections
    assert paged.warm_ratio >= scalar.warm_ratio


def test_manager_preempts_cold_kv_pages_in_one_plan():
    """Desperation composes weight evictions and cold-KV-page evictions
    in a single transactional plan: tenant b's admission preempts a's
    youngest sequence (not the oldest — least decode progress lost) and
    the victim surfaces through take_preempted()."""
    from repro.core import EdgeMultiAI

    mgr = EdgeMultiAI({"a": _zoo("a", [10.0, 5.0]),
                       "b": _zoo("b", [10.0, 5.0])},
                      budget_mb=100.0, policy="iws-bfe", delta_ms=10.0)
    mgr.state.kv_pool = KVPagePool(10.0, 4)
    mgr.admit_batch("a", now=0.0, kv_mb=10.0, seq=1)
    mgr.admit_batch("a", now=1.0, kv_mb=10.0, seq=2)
    mgr.admit_batch("a", now=2.0, kv_mb=10.0, seq=3)
    assert mgr.state.kv_pool.free_pages == 1
    adm = mgr.admit_batch("b", now=3.0, kv_mb=20.0, seq=4)  # needs 2
    assert not adm.failed and adm.kv_mb == pytest.approx(20.0)
    assert mgr.kv_preemptions == 1
    assert mgr.take_preempted() == (("a", 3),), "youngest victim first"
    assert mgr.take_preempted() == (), "drained"
    assert mgr.state.kv_pool.held_pages("a") == 2
    assert mgr.state.kv_pool.held_pages("b") == 2
    mgr.state.check_invariant()


def test_own_pages_are_never_preempted():
    """A tenant cannot evict its own sequences to admit a new one — the
    admission is rejected instead (the caller decides scheduling)."""
    from repro.core import EdgeMultiAI

    mgr = EdgeMultiAI({"a": _zoo("a", [10.0, 5.0])},
                      budget_mb=100.0, policy="iws-bfe", delta_ms=10.0)
    mgr.state.kv_pool = KVPagePool(10.0, 2)
    mgr.admit_batch("a", now=0.0, kv_mb=10.0, seq=1)
    mgr.admit_batch("a", now=1.0, kv_mb=10.0, seq=2)
    adm = mgr.admit_batch("a", now=2.0, kv_mb=10.0, seq=3)
    assert adm.failed and adm.kv_rejected
    assert mgr.kv_preemptions == 0
    assert mgr.state.kv_pool.held_pages("a") == 2
    mgr.state.check_invariant()


def test_continuous_on_sharded_mesh_partitions_pages(cfgs):
    """On a mesh the pool's pages are partitioned across chips
    proportional to the ledger budgets, and the continuous engine runs
    clean against the per-chip page ranges."""
    from repro.serving.api import LoaderSpec

    srv = EdgeServer.build(ServingConfig(
        tenants=tuple(TenantSpec(n) for n in TENANTS), executor="sim",
        kv_headroom_shape=(4, 128),
        loader=LoaderSpec(sharded=True, mesh_shape=(4,)),
        batching=BatchingSpec(max_batch=4, continuous=True)))
    pool = srv.manager.state.kv_pool
    assert pool.n_devices == 4 and min(pool.device_pages) >= 1
    trace, _ = poisson_trace(cfgs, requests_per_app=10,
                             mean_iat_ms=200.0, seed=7)
    stats = srv.engine.run_trace(trace)
    srv.engine.check_event_invariant()
    srv.close()
    assert stats.requests == 20
    assert stats.kv_pages_used == 0
    assert stats.kv_overrelease_mb == 0.0


def test_preempted_request_requeues_in_engine(cfgs):
    """End to end: a saturating burst with coarse pages triggers page
    preemption inside the continuous loop; the victim re-queues (a
    "preempt" event, not a lost request) and every request still reaches
    a result with the pool fully drained."""
    srv, stats = _run(cfgs, continuous=True, budget_mb=0.30,
                      kv_page_mb=0.03, max_batch=8, window_ms=50.0,
                      n=24, iat=0.01, max_new=120, seed=11)
    assert stats.requests == 48, "every request reaches a result"
    assert stats.kv_preemptions >= 1
    assert "preempt" in [e.kind for e in srv.engine.events]
    assert stats.kv_pages_used == 0
    assert stats.kv_overrelease_mb == 0.0
