"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels import decode_attention as da
from repro.kernels import flash_attention as fa
from repro.kernels import quant_matmul as qm
from repro.kernels import ssd_scan as ssd

KEY = jax.random.key(42)


def rand(*shape, dtype=jnp.float32, key=KEY, scale=1.0):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


TOL = {jnp.float32: dict(rtol=3e-5, atol=3e-5),
       jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,S,H,KV,D", [
    (1, 64, 4, 4, 32),     # MHA
    (2, 160, 8, 4, 64),    # GQA, ragged block boundary
    (1, 257, 6, 2, 128),   # odd length
    (2, 128, 25, 5, 64),   # hymba-style non-pow2 heads
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes(B, S, H, KV, D, dtype):
    q = rand(B, S, H, D, dtype=dtype)
    k = rand(B, S, KV, D, dtype=dtype)
    v = rand(B, S, KV, D, dtype=dtype)
    want = ref.flash_attention(q, k, v)
    got = fa.flash_attention(q, k, v, block_q=64, block_k=64,
                             interpret=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        **TOL[dtype])


@pytest.mark.parametrize("kwargs", [
    dict(window=32), dict(softcap=20.0), dict(window=16, prefix=8),
    dict(window=32, softcap=50.0, prefix=4), dict(q_offset=64),
])
def test_flash_attention_masking_modes(kwargs):
    B, S, H, KV, D = 2, 96, 4, 2, 32
    q, k, v = (rand(B, S, n, D, key=jax.random.key(i))
               for i, n in ((0, H), (1, KV), (2, KV)))
    want = ref.flash_attention(q, k, v, **kwargs)
    got = fa.flash_attention(q, k, v, block_q=32, block_k=32,
                             interpret=True, **kwargs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,T,H,KV,D", [
    (2, 300, 8, 4, 64),
    (1, 64, 4, 4, 32),
    (3, 1000, 14, 2, 64),  # internvl2-style
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_shapes(B, T, H, KV, D, dtype):
    q = rand(B, H, D, dtype=dtype)
    kc = rand(B, T, KV, D, dtype=dtype, key=jax.random.key(1))
    vc = rand(B, T, KV, D, dtype=dtype, key=jax.random.key(2))
    lengths = jnp.asarray(
        np.random.default_rng(0).integers(1, T, B), jnp.int32)
    want = ref.decode_attention(q, kc, vc, lengths)
    got = da.decode_attention(q, kc, vc, lengths, block_t=128,
                              interpret=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        **TOL[dtype])


@pytest.mark.parametrize("B,T,H,KV,D,ps", [
    (2, 300, 8, 4, 64, 128),
    (3, 96, 4, 2, 32, 16),   # many small pages, ragged last page
    (1, 64, 4, 4, 32, 64),   # single page per sequence
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_attention_matches_dense(B, T, H, KV, D, ps, dtype):
    """The paged kernel gathers KV blocks through a (permuted) page
    table and must match the dense kernel's math exactly — including
    per-sequence valid lengths that end mid-page."""
    q = rand(B, H, D, dtype=dtype)
    kc = rand(B, T, KV, D, dtype=dtype, key=jax.random.key(1))
    vc = rand(B, T, KV, D, dtype=dtype, key=jax.random.key(2))
    lengths = jnp.asarray(
        np.random.default_rng(7).integers(1, T, B), jnp.int32)
    want = ref.decode_attention(q, kc, vc, lengths)
    kp, vp, table = da.paginate_kv(kc, vc, lengths, ps)
    # The physical layout is really scattered, not logical order.
    if B * ((T + ps - 1) // ps) > 1:
        assert not np.array_equal(
            np.asarray(table).ravel(),
            np.arange(table.size))
    got = da.paged_decode_attention(q, kp, vp, table, lengths,
                                    interpret=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        **TOL[dtype])
    # And through the ops dispatcher's reference path.
    from repro.kernels import ops
    got_ref = ops.paged_decode_attention(q, kp, vp, table, lengths,
                                         impl="reference")
    np.testing.assert_allclose(
        np.asarray(got_ref, np.float32), np.asarray(want, np.float32),
        **TOL[dtype])


def test_decode_attention_window_softcap():
    B, T, H, KV, D = 2, 200, 4, 2, 32
    q = rand(B, H, D)
    kc = rand(B, T, KV, D, key=jax.random.key(1))
    vc = rand(B, T, KV, D, key=jax.random.key(2))
    lengths = jnp.array([150, 37], jnp.int32)
    for kwargs in [dict(window=64), dict(softcap=30.0),
                   dict(window=32, prefix=8)]:
        want = ref.decode_attention(q, kc, vc, lengths, **kwargs)
        got = da.decode_attention(q, kc, vc, lengths, block_t=64,
                                  interpret=True, **kwargs)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("M,K,N,group,bits", [
    (64, 256, 128, 128, 8),
    (100, 384, 200, 128, 8),   # ragged M/N
    (32, 128, 64, 32, 4),      # int4
    (8, 512, 512, 512, 8),     # single group
])
def test_quant_matmul_shapes(M, K, N, group, bits):
    x = rand(M, K)
    w = rand(K, N, key=jax.random.key(7))
    wq, sc = ref.quantize_weights(w, bits=bits, group=group)
    want = ref.quant_matmul(x, wq, sc)
    got = qm.quant_matmul(x, wq, sc, block_m=32, block_n=64, block_k=group,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_quant_matmul_batched_lhs():
    x = rand(2, 5, 7, 128)
    w = rand(128, 96, key=jax.random.key(3))
    wq, sc = ref.quantize_weights(w, bits=8, group=64)
    want = ref.quant_matmul(x, wq, sc)
    got = qm.quant_matmul(x, wq, sc, interpret=True)
    assert got.shape == (2, 5, 7, 96)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_quantize_roundtrip_error_bounded():
    w = rand(256, 128, key=jax.random.key(11))
    for bits, bound in ((8, 0.02), (4, 0.35)):
        wq, sc = ref.quantize_weights(w, bits=bits, group=64)
        wd = (wq.astype(jnp.float32).reshape(4, 64, 128)
              * sc[:, None, :]).reshape(256, 128)
        err = float(jnp.max(jnp.abs(wd - w)))
        assert err < bound, f"{bits}-bit max err {err}"


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,S,H,P,G,N,chunk", [
    (1, 64, 2, 16, 1, 8, 16),
    (2, 96, 4, 32, 2, 16, 32),
    (1, 50, 2, 16, 1, 8, 16),   # ragged chunk
    (2, 128, 48, 64, 1, 128, 64),  # mamba2-like dims (scaled down B/S)
])
def test_ssd_scan_shapes(B, S, H, P, G, N, chunk):
    ks = jax.random.split(jax.random.key(5), 6)
    x = rand(B, S, H, P, key=ks[0], scale=0.5)
    dt = jax.nn.softplus(rand(B, S, H, key=ks[1]))
    A = -jnp.exp(rand(H, key=ks[2], scale=0.5))
    Bm = rand(B, S, G, N, key=ks[3], scale=0.3)
    Cm = rand(B, S, G, N, key=ks[4], scale=0.3)
    D = rand(H, key=ks[5])
    want, wstate = ref.ssd_scan(x, dt, A, Bm, Cm, D, return_state=True)
    got_c, cstate = ref.ssd_scan_chunked(x, dt, A, Bm, Cm, D, chunk=chunk,
                                         return_state=True)
    np.testing.assert_allclose(np.asarray(got_c), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(cstate), np.asarray(wstate),
                               rtol=2e-4, atol=2e-4)
    got_p, pstate = ssd.ssd_scan(x, dt, A, Bm, Cm, D, chunk=chunk,
                                 return_state=True, interpret=True)
    np.testing.assert_allclose(np.asarray(got_p), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(pstate), np.asarray(wstate),
                               rtol=2e-4, atol=2e-4)


def test_ssd_state_continuation():
    """Scanning [0:S1] then [S1:S] with carried state == scanning [0:S]."""
    B, S, H, P, G, N = 1, 80, 2, 16, 1, 8
    ks = jax.random.split(jax.random.key(9), 6)
    x = rand(B, S, H, P, key=ks[0], scale=0.5)
    dt = jax.nn.softplus(rand(B, S, H, key=ks[1]))
    A = -jnp.exp(rand(H, key=ks[2], scale=0.5))
    Bm = rand(B, S, G, N, key=ks[3], scale=0.3)
    Cm = rand(B, S, G, N, key=ks[4], scale=0.3)
    D = rand(H, key=ks[5])
    full = ref.ssd_scan_chunked(x, dt, A, Bm, Cm, D, chunk=16)
    y1, st1 = ref.ssd_scan_chunked(
        x[:, :48], dt[:, :48], A, Bm[:, :48], Cm[:, :48], D, chunk=16,
        return_state=True)
    y2 = ref.ssd_scan_chunked(
        x[:, 48:], dt[:, 48:], A, Bm[:, 48:], Cm[:, 48:], D, chunk=16,
        init_state=st1)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([y1, y2], axis=1)), np.asarray(full),
        rtol=2e-4, atol=2e-4)


def test_ssd_step_matches_scan():
    """Sequential ssd_step over tokens == the batched scan."""
    B, S, H, P, G, N = 1, 12, 2, 8, 1, 4
    ks = jax.random.split(jax.random.key(13), 6)
    x = rand(B, S, H, P, key=ks[0], scale=0.5)
    dt = jax.nn.softplus(rand(B, S, H, key=ks[1]))
    A = -jnp.exp(rand(H, key=ks[2], scale=0.5))
    Bm = rand(B, S, G, N, key=ks[3], scale=0.3)
    Cm = rand(B, S, G, N, key=ks[4], scale=0.3)
    D = rand(H, key=ks[5])
    want = ref.ssd_scan(x, dt, A, Bm, Cm, D)
    state = jnp.zeros((B, H, P, N), jnp.float32)
    outs = []
    for t in range(S):
        y, state = ref.ssd_step(x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t],
                                D, state)
        outs.append(y)
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_conv1d_step_matches_batch():
    B, S, C, W = 2, 10, 8, 4
    ks = jax.random.split(jax.random.key(17), 3)
    x = rand(B, S, C, key=ks[0])
    w = rand(W, C, key=ks[1])
    b = rand(C, key=ks[2], scale=0.1)
    want = ref.causal_conv1d(x, w, b)
    buf = jnp.zeros((B, W - 1, C))
    outs = []
    for t in range(S):
        y, buf = ref.causal_conv1d_step(x[:, t], w, b, buf)
        outs.append(y)
    np.testing.assert_allclose(
        np.asarray(jnp.stack(outs, 1)), np.asarray(want),
        rtol=1e-5, atol=1e-5)
