"""Simulator, manager, and paper-claim validation tests.

The property section uses ``hypothesis`` when available; without it the
same invariant checker runs over a seeded parameter grid so the module
always collects and the invariants stay guarded.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # degrade to the seeded fallback below
    HAVE_HYPOTHESIS = False

from repro.configs.paper_edge import paper_zoos
from repro.core import (EdgeMultiAI, generate_workload, simulate,
                        sweep_policies)


class TestWorkload:
    def test_equal_requests_per_app(self):
        wl = generate_workload(["a", "b", "c"], requests_per_app=20, seed=1)
        counts = {}
        for _, app in wl.requests:
            counts[app] = counts.get(app, 0) + 1
        assert all(c == 20 for c in counts.values())

    def test_requests_sorted(self):
        wl = generate_workload(["a", "b"], requests_per_app=30, seed=2)
        ts = [t for t, _ in wl.requests]
        assert ts == sorted(ts)

    def test_deviation_increases_residuals(self):
        lo = generate_workload(["a", "b"], deviation=0.1, seed=3,
                               requests_per_app=100)
        hi = generate_workload(["a", "b"], deviation=0.8, seed=3,
                               requests_per_app=100)
        assert hi.delta_D > lo.delta_D
        assert hi.kl >= lo.kl * 0.5  # KL noisy but should not collapse

    def test_dropped_predictions(self):
        wl = generate_workload(["a"], deviation=0.9, seed=4,
                               requests_per_app=200)
        assert len(wl.predictions["a"]) < 200  # some were dropped


class TestManagerAccounting:
    def test_record_totals(self):
        zoos = paper_zoos()
        wl = generate_workload(list(zoos), requests_per_app=20, seed=0)
        res = simulate(zoos, wl, policy="iws-bfe")
        m = res.metrics
        assert m.total == len(wl.requests)
        assert abs(m.warm_ratio + m.cold_ratio + m.fail_ratio - 1.0) < 1e-9

    def test_memory_never_exceeded(self):
        # MemoryState.load asserts the invariant on every mutation, so a
        # full simulation passing is itself the property.
        zoos = paper_zoos()
        for policy in ("none", "lfe", "bfe", "ws-bfe", "iws-bfe"):
            wl = generate_workload(list(zoos), requests_per_app=30,
                                   deviation=0.5, seed=7)
            simulate(zoos, wl, policy=policy, budget_mb=900.0)

    def test_unknown_policy_raises(self):
        with pytest.raises(KeyError):
            EdgeMultiAI(paper_zoos(), 1000.0, policy="nope")


class TestPaperClaims:
    """The paper's headline numbers (§IV), validated end-to-end."""

    @pytest.fixture(scope="class")
    def sweep(self):
        return sweep_policies(
            paper_zoos(), deviations=(0.3,),
            policies=("none", "lfe", "bfe", "ws-bfe", "iws-bfe"),
            seeds=(0, 1, 2), requests_per_app=50)

    def test_warm_start_gain_over_no_policy(self, sweep):
        """Claim: ≈60% more warm-starts than no-policy."""
        gain = sweep["iws-bfe"][0.3]["warm"] / sweep["none"][0.3]["warm"]
        assert gain > 1.5, f"warm-start gain {gain:.2f}"

    def test_ws_policies_mitigate_cold_starts(self, sweep):
        """Claim: WS-BFE / iWS-BFE cut cold starts ≥65% vs LFE/BFE."""
        lfe_cold = sweep["lfe"][0.3]["cold"]
        for p in ("ws-bfe", "iws-bfe"):
            assert sweep[p][0.3]["cold"] < lfe_cold * 0.35, p

    def test_iws_beats_ws_on_cold_starts(self, sweep):
        """Claim: iWS-BFE ≈40% fewer cold-starts than WS-BFE."""
        assert (sweep["iws-bfe"][0.3]["cold"]
                <= sweep["ws-bfe"][0.3]["cold"])

    def test_robustness_ordering(self, sweep):
        """Fig 8 ordering: iws ≥ ws > lfe/bfe > none."""
        r = {p: sweep[p][0.3]["rob"] for p in sweep}
        assert r["iws-bfe"] >= r["ws-bfe"] - 0.02
        assert r["ws-bfe"] > r["lfe"]
        assert r["lfe"] > r["none"]

    def test_lfe_bfe_accuracy_above_ws(self, sweep):
        """Fig 6: LFE/BFE accuracy > WS-BFE (they never keep
        low-precision models resident)."""
        assert sweep["lfe"][0.3]["acc"] > sweep["ws-bfe"][0.3]["acc"]

    def test_robustness_degrades_with_deviation(self):
        out = sweep_policies(paper_zoos(), deviations=(0.0, 0.9),
                             policies=("iws-bfe",), seeds=(0, 1))
        assert out["iws-bfe"][0.0]["rob"] > out["iws-bfe"][0.9]["rob"]


class TestFairness:
    def test_no_app_starved(self):
        """Figs 9/10: outcomes must not be biased to one application."""
        zoos = paper_zoos()
        wl = generate_workload(list(zoos), requests_per_app=60, seed=5,
                               deviation=0.3)
        res = simulate(zoos, wl, policy="iws-bfe")
        per = res.metrics.per_app()
        warms = [v["warm_ratio"] for v in per.values()]
        assert min(warms) > 0.7, per
        assert max(warms) - min(warms) < 0.3


def _check_simulation_invariants(seed, deviation, policy):
    zoos = paper_zoos()
    wl = generate_workload(list(zoos), requests_per_app=15,
                           deviation=deviation, seed=seed)
    res = simulate(zoos, wl, policy=policy)
    m = res.metrics
    assert m.total == len(wl.requests)
    assert 0.0 <= m.warm_ratio <= 1.0
    assert 0.0 <= m.robustness() <= 1.0
    assert m.state.used_mb <= m.state.budget_mb + 1e-6


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 10_000), st.floats(0.0, 0.9),
           st.sampled_from(["lfe", "bfe", "ws-bfe", "iws-bfe"]))
    def test_simulation_total_invariants(seed, deviation, policy):
        _check_simulation_invariants(seed, deviation, policy)


@pytest.mark.parametrize("policy", ["lfe", "bfe", "ws-bfe", "iws-bfe"])
@pytest.mark.parametrize("seed,deviation", [(0, 0.0), (17, 0.3), (401, 0.9)])
def test_simulation_total_invariants_seeded(seed, deviation, policy):
    _check_simulation_invariants(seed, deviation, policy)


def test_sweep_kl_averaged_across_seeds():
    """Regression: ``kl`` must aggregate over seeds like the other
    metrics, not record only the last seed's workload."""
    zoos = paper_zoos()
    seeds = (0, 1)
    out = sweep_policies(zoos, deviations=(0.3,), policies=("lfe",),
                         requests_per_app=10, seeds=seeds)
    kls = [generate_workload(list(zoos), requests_per_app=10,
                             mean_iat_ms=8000.0, deviation=0.3, seed=s).kl
           for s in seeds]
    assert out["lfe"][0.3]["kl"] == pytest.approx(float(np.mean(kls)))
    assert out["lfe"][0.3]["kl"] != pytest.approx(kls[-1])
