"""Residency-action IR tests: simulate-vs-apply equivalence, the
all-or-nothing rollback contract, the pending-charge scope, loader
execute() with per-action completion callbacks, the cost-aware policy
plugin, and the adaptive prediction window.

Synthetic zoos throughout — the IR is pure accounting, no models.
"""
import pytest

from repro.core import EdgeMultiAI
from repro.core import actions as A
from repro.core.memory_state import DeviceLedger, MemoryState, TenantState
from repro.core.model_zoo import ModelVariant, ModelZoo
from repro.core.policies import resolve_policy
from repro.serving import BackgroundLoader

N_DEV = 4


def _zoo(name, sizes):
    return ModelZoo(app_name=name, variants=tuple(
        ModelVariant(f"{name}-{i}", bits=32 >> i, size_mb=s,
                     accuracy=90.0 - 10 * i, load_ms=s * 2)
        for i, s in enumerate(sizes)))


def make_state(budget_mb=1000.0, devices=False, device_budget_mb=None,
               **zoos):
    zoos = zoos or {"a": _zoo("a", [500, 300]), "b": _zoo("b", [400, 200])}
    st = MemoryState(budget_mb=budget_mb,
                     tenants={n: TenantState(zoo=z)
                              for n, z in zoos.items()})
    if devices:
        per = (budget_mb / N_DEV if device_budget_mb is None
               else device_budget_mb)
        st.devices = DeviceLedger(
            (per,) * N_DEV,
            split_fn=lambda app, v: (v.size_mb / N_DEV,) * N_DEV)
    return st


def digest(st: MemoryState):
    """Everything an action may mutate, as comparable data."""
    out = {a: (t.loaded, t.kv_mb, t.inflight_mb)
           for a, t in st.tenants.items()}
    out["_pending"] = st.pending_mb
    if st.devices is not None:
        out["_dev"] = (dict(st.devices.weights),
                       {a: tuple(c) for a, c in st.devices.inflight.items()},
                       st.devices.shards_migrated)
    return out


def zoo_of(st, app):
    return st.tenants[app].zoo


# ---------------------------------------------------------------------------
# simulate ≡ apply
# ---------------------------------------------------------------------------
def _plan_matrix(st):
    za, zb = zoo_of(st, "a"), zoo_of(st, "b")
    return [
        # (plan, feasible on a fresh 1000MB two-tenant state?)
        (A.plan_of(A.Load("a", za.largest)), True),
        (A.plan_of(A.Load("a", za.largest), A.Load("b", zb.largest)), True),
        (A.plan_of(A.Load("a", za.largest),
                   A.Load("b", zb.largest),
                   A.ChargeKV("b", 200.0)), False),  # 500+400+200 > 1000
        (A.plan_of(A.Load("a", za.largest, staged=True, claim_mb=500.0),
                   A.Load("b", zb.largest, staged=True,
                          claim_mb=400.0)), True),
        (A.plan_of(A.Load("a", za.largest, staged=True,
                          claim_mb=600.0),
                   A.Load("b", zb.largest, staged=True,
                          claim_mb=500.0)), False),  # second claim 500>400
        (A.plan_of(A.ChargeKV("a", 999.0)), True),
        (A.plan_of(A.ChargeKV("a", 1001.0)), False),
        (A.plan_of(A.ChargeKV("a", -1.0)), False),
        (A.plan_of(A.MigrateShard("a", 0, 1, 10.0)), False),  # no ledger
    ]


def test_simulate_matches_apply_and_neither_leaks_on_failure():
    """simulate() returns None exactly when apply() succeeds; simulate
    never mutates; a failed apply leaves the state bit-identical."""
    for i, (plan, feasible) in enumerate(_plan_matrix(make_state())):
        st = make_state()
        before = digest(st)
        err = st.simulate(plan)
        assert digest(st) == before, f"plan {i}: simulate mutated state"
        assert (err is None) == feasible, f"plan {i}: {err}"
        if feasible:
            st.apply(plan)
            assert digest(st) != before or len(plan) == 0
        else:
            with pytest.raises(A.PlanError):
                st.apply(plan)
            assert digest(st) == before, f"plan {i}: apply leaked"


def test_apply_is_sequential_order_matters():
    """An eviction earlier in the plan funds a load later in it."""
    st = make_state(budget_mb=600.0)
    za, zb = zoo_of(st, "a"), zoo_of(st, "b")
    st.apply(A.plan_of(A.Load("b", zb.largest)))  # 400 resident, 200 free
    good = A.plan_of(A.Downgrade("b", zb.smallest),  # frees 200 -> 400
                     A.Load("a", za.smallest, staged=True))  # needs 300
    bad = A.plan_of(A.Load("a", za.smallest, staged=True),
                    A.Downgrade("b", zb.smallest))
    assert st.simulate(bad) is not None, "claim before the eviction"
    assert st.simulate(good) is None
    st.apply(good)
    assert st.tenants["a"].inflight_mb == 300.0
    assert st.tenants["b"].loaded is zb.smallest


def test_all_or_nothing_rollback_on_mid_plan_shard_failure():
    """A valid downgrade followed by a staged load whose shard overflows
    its chip must leave *no trace* — the downgrade rolls back too."""
    st = make_state(devices=True, device_budget_mb=100.0)
    za, zb = zoo_of(st, "a"), zoo_of(st, "b")
    st.apply(A.plan_of(A.Load("b", zb.smallest)))  # 50/chip
    before = digest(st)
    plan = A.plan_of(
        A.Downgrade("b", zb.smallest),  # no-op downgrade, still valid
        A.Load("a", za.largest, staged=True, claim_mb=500.0,
               shard_claims=(125.0,) * N_DEV))  # 125 > 100-50 free
    assert st.simulate(plan) is not None
    with pytest.raises(A.PlanError):
        st.apply(plan)
    assert digest(st) == before, "mid-plan failure left partial state"
    assert st.devices.inflight == {}, "no shard claim survived rollback"


def test_staged_load_commit_is_net_zero_and_releases_shards():
    st = make_state(devices=True)
    za = zoo_of(st, "a")
    claims = (125.0,) * N_DEV
    st.apply(A.plan_of(A.Load("a", za.largest, staged=True,
                              claim_mb=500.0, shard_claims=claims)))
    assert st.free_mb == pytest.approx(500.0)
    assert st.devices.inflight["a"] == pytest.approx([125.0] * N_DEV)
    st.apply(A.plan_of(A.Load("a", za.largest, claim_mb=500.0,
                              shard_claims=claims)))
    assert st.free_mb == pytest.approx(500.0), "commit is net zero"
    assert st.devices.inflight == {}
    assert st.devices.weights["a"] == pytest.approx((125.0,) * N_DEV)


def test_shrink_cancel_and_kv_actions():
    st = make_state()
    za = zoo_of(st, "a")
    st.apply(A.plan_of(A.Load("a", za.largest, staged=True)))
    assert st.tenants["a"].inflight_mb == 500.0, "claim_mb=None = marginal"
    st.apply(A.plan_of(A.Shrink("a", za.smallest, release_mb=200.0)))
    assert st.tenants["a"].inflight_mb == 300.0
    st.apply(A.plan_of(A.CancelPrefetch("a", claim_mb=300.0)))
    assert st.tenants["a"].inflight_mb == 0.0
    st.apply(A.plan_of(A.ChargeKV("a", 150.0)))
    assert st.tenants["a"].kv_mb == 150.0
    st.apply(A.plan_of(A.EvictKV("a", 999.0)))  # over-release clamps
    assert st.tenants["a"].kv_mb == 0.0
    with pytest.raises(A.PlanError):
        st.apply(A.plan_of(A.Load("zzz", za.largest)))


def test_pending_scope_always_restores():
    st = make_state()
    with pytest.raises(RuntimeError):
        with st.pending(123.0):
            assert st.pending_mb == 123.0
            raise RuntimeError("boom")
    assert st.pending_mb == 0.0


def test_procure_actions_compiles_evictions_and_target():
    st = make_state()
    za, zb = zoo_of(st, "a"), zoo_of(st, "b")
    plan = A.ProcurePlan("a", za.largest, (
        A.Eviction("b", zb.largest, None),
        A.Eviction("b", zb.largest, zb.smallest)))
    acts = A.procure_actions(plan, staged=True)
    assert isinstance(acts[0], A.Unload)
    assert isinstance(acts[1], A.Downgrade) and acts[1].variant is zb.smallest
    assert isinstance(acts[2], A.Load) and acts[2].staged


# ---------------------------------------------------------------------------
# LoaderChannel.execute: atomicity + per-action completion callbacks
# ---------------------------------------------------------------------------
def make_manager(budget_mb=1000.0):
    return EdgeMultiAI(
        {"a": _zoo("a", [500, 300]), "b": _zoo("b", [400, 200])},
        budget_mb=budget_mb, policy="iws-bfe", delta_ms=10.0)


def test_execute_fires_per_action_callbacks_in_order():
    mgr = make_manager()
    st = mgr.state
    zb = st.tenants["b"].zoo
    st.apply(A.plan_of(A.Load("b", zb.largest)))
    loader = BackgroundLoader(mgr)
    fired = []
    za = st.tenants["a"].zoo
    ld = loader.execute(
        A.plan_of(A.Downgrade("b", zb.smallest),
                  A.Load("a", za.largest, staged=True)),
        now_ms=0.0, on_action=lambda act, t: fired.append((type(act), t)))
    assert ld is not None and ld.charge_mb == 500.0
    assert fired == [(A.Downgrade, 0.0)], \
        "instantaneous actions complete during execute; the staged " \
        "load completes at commit"
    loader.reap(ld.ready_ms)
    assert [f[0] for f in fired] == [A.Downgrade, A.Load]
    assert fired[-1][1] == ld.ready_ms
    loader.close()


def test_execute_stale_plan_enacts_nothing_not_even_evictions():
    """The pre-IR enqueue enacted a plan's evictions and only then
    noticed the claim no longer fit, stranding the downgrade.  The
    transactional applier rolls the whole group back."""
    mgr = make_manager()
    st = mgr.state
    za, zb = st.tenants["a"].zoo, st.tenants["b"].zoo
    st.apply(A.plan_of(A.Load("b", zb.largest),
                       A.ChargeKV("b", 550.0)))  # free = 50
    loader = BackgroundLoader(mgr)
    before = digest(st)
    out = loader.execute(
        A.plan_of(A.Downgrade("b", zb.smallest),  # frees 200 -> free 250
                  A.Load("a", za.largest, staged=True)),  # needs 500
        now_ms=0.0)
    assert out is None
    assert digest(st) == before, "stale plan left its evictions behind"
    loader.close()


def test_cancel_stale_accepts_per_tenant_delta():
    """Staleness must agree with the (possibly adaptive) per-tenant Δ:
    cancel_stale takes a callable, so a widened window is not cancelled
    early and a narrowed one does not squat."""
    mgr = make_manager()
    loader = BackgroundLoader(mgr)
    loader.enqueue(mgr.plan_proactive("a", 0.0), 0.0, predicted_ms=1000.0)
    wide = {"a": 600.0}
    assert loader.cancel_stale(1500.0, lambda app: wide[app],
                               has_queued=lambda a: False) == 0, \
        "still inside the widened per-tenant window"
    assert loader.cancel_stale(1700.0, lambda app: wide[app],
                               has_queued=lambda a: False) == 1
    loader.close()


# ---------------------------------------------------------------------------
# cost-bfe: plan candidates enumerated + simulated, ranked by cost
# ---------------------------------------------------------------------------
def test_cost_bfe_prefers_variant_ready_before_predicted_request():
    """With the next request predicted mid-transfer of the big variant,
    the smaller variant (ready in time, smaller accuracy) scores higher;
    with no prediction the choice degrades to plain BFE (largest)."""
    st = make_state(budget_mb=1000.0)
    za = zoo_of(st, "a")
    pol = resolve_policy("cost-bfe")
    # No prediction: identical to BFE.
    plan = pol.plan_procure(st, "a", 0.0, delta=10.0, history=0.0)
    assert plan.variant is za.largest
    # Next request lands at t=650: the 1000ms bf16 transfer misses it
    # (score 90*0.65=58.5), the 600ms int8 makes it (score 80*1=80).
    st.tenants["a"].predicted_next = 650.0
    plan = pol.plan_procure(st, "a", 0.0, delta=10.0, history=0.0)
    assert plan.ok and plan.variant is za.smallest
    # Imminent request: nothing can be ready — serve the largest anyway
    # (all scores 0, ties keep the bigger variant).
    st.tenants["a"].predicted_next = 0.0
    plan = pol.plan_procure(st, "a", 0.0, delta=10.0, history=0.0)
    assert plan.ok and plan.variant is za.largest


def test_cost_bfe_skips_candidates_that_do_not_simulate():
    """A candidate whose shard overflows its chip is unfundable in a way
    device-blind eviction math cannot see: the per-variant simulate()
    (device-aware staged claims) filters it, and cost-bfe lands on the
    variant that actually fits every chip."""
    st = make_state(budget_mb=1000.0, devices=True, device_budget_mb=110.0)
    za = zoo_of(st, "a")
    # bf16's 125MB/chip shard > 110MB chip budget; int8's 75MB fits.
    pol = resolve_policy("cost-bfe")
    plan = pol.plan_procure(st, "a", 0.0, delta=10.0, history=0.0)
    assert plan.ok and plan.variant is za.smallest
    # Plain BFE (device-blind, no simulate pass) would have picked bf16.
    blind = resolve_policy("bfe").plan_procure(st, "a", 0.0, delta=10.0,
                                               history=0.0)
    assert blind.variant is za.largest


# ---------------------------------------------------------------------------
# Adaptive prediction window (satellite): Δ from arrival residuals
# ---------------------------------------------------------------------------
def test_adaptive_delta_tracks_residuals_and_stays_bounded():
    mgr = EdgeMultiAI({"a": _zoo("a", [500, 300])}, budget_mb=1000.0,
                      policy="iws-bfe", delta_ms=400.0,
                      adaptive_delta=True)
    assert mgr.delta_for("a") == 400.0, "no residuals yet: configured Δ"
    # Tight predictions (|resid| = 20) shrink the window toward 2*EWMA,
    # clamped at Δ/4.
    for t in (1000.0, 2000.0, 3000.0, 4000.0):
        mgr.set_prediction("a", t + 20.0)
        mgr.on_request("a", t)
    assert mgr.delta_for("a") == pytest.approx(100.0), "clamped at Δ/4"
    # A noisy stretch (resid 2000) grows it, clamped at 2Δ.
    for t in (5000.0, 6000.0, 7000.0, 8000.0):
        mgr.set_prediction("a", t + 2000.0)
        mgr.on_request("a", t)
    assert mgr.delta_for("a") == pytest.approx(800.0), "clamped at 2Δ"


def test_adaptive_delta_off_by_default_keeps_fixed_window():
    mgr = EdgeMultiAI({"a": _zoo("a", [500, 300])}, budget_mb=1000.0,
                      policy="iws-bfe", delta_ms=400.0)
    for t in (1000.0, 2000.0, 3000.0):
        mgr.set_prediction("a", t + 5.0)
        mgr.on_request("a", t)
    assert mgr.delta_for("a") == 400.0
