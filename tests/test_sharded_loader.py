"""Sharded loader tests: per-shard staging schedules, whole-load claims
released shard-by-shard, per-device budget ledgers, the
shard-doesn't-fit → whole-load-failure → downgrade path, sim-executor
bit-determinism, and (under the CI ``test-multidevice`` job's 8 fake CPU
devices) real-mesh shard placement matching the accounting fractions.

Synthetic-zoo tests drive the manager + channel directly (no models);
engine tests build through the declarative API with sim executors.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import EdgeMultiAI
from repro.core.memory_state import DeviceLedger
from repro.core.model_zoo import ModelVariant, ModelZoo, zoo_from_config
from repro.distributed import sharding as SH
from repro.serving import Batch, EdgeServer, Request, poisson_trace
from repro.serving.api import (BatchingSpec, LoaderSpec, ServingConfig,
                               SimTenant, TenantSpec)
from repro.serving.sharded_loader import ShardedLoaderChannel

N_DEV = 4


def _zoo(name, sizes):
    return ModelZoo(app_name=name, variants=tuple(
        ModelVariant(f"{name}-{i}", bits=32 >> i, size_mb=s,
                     accuracy=90.0 - 10 * i, load_ms=s * 2)
        for i, s in enumerate(sizes)))


def make_manager(budget_mb=1000.0, device_budget_mb=None, **zoos):
    zoos = zoos or {"a": _zoo("a", [500, 300]), "b": _zoo("b", [400, 200])}
    mgr = EdgeMultiAI(zoos, budget_mb=budget_mb, policy="iws-bfe",
                      delta_ms=10.0)
    per_dev = (budget_mb / N_DEV if device_budget_mb is None
               else device_budget_mb)
    mgr.state.devices = DeviceLedger(
        (per_dev,) * N_DEV,
        split_fn=lambda app, v: SH.variant_shard_mb(v.size_mb, N_DEV))
    return mgr


# ---------------------------------------------------------------------------
# Per-shard schedule + claim lifecycle (synthetic zoos, no models)
# ---------------------------------------------------------------------------
def test_enqueue_claims_whole_load_and_shards_tile_the_transfer():
    mgr = make_manager()
    loader = ShardedLoaderChannel(mgr, n_devices=N_DEV)
    ld = loader.enqueue(mgr.plan_demand("a", 0.0), now_ms=0.0, demand=True)
    assert ld is not None and ld.charge_mb == 500.0
    st = mgr.state
    assert st.inflight_mb == 500.0, "claim charged once, up front"
    led = st.devices
    assert led.inflight["a"] == pytest.approx([125.0] * N_DEV)
    # Shared host link: shard slots tile [0, load_ms] exactly.
    assert [s.load_ms for s in ld.shards] == pytest.approx([250.0] * N_DEV)
    assert ld.shards[0].t_start_ms == 0.0
    assert ld.shards[-1].ready_ms == pytest.approx(1000.0)  # 500 * 2
    assert ld.ready_ms == pytest.approx(1000.0)
    assert sum(s.global_mb for s in ld.shards) == pytest.approx(500.0)
    # Wake semantics match the single-stream loader (next commit) so
    # the A/B differs only in staging accounting, but progress is still
    # observable per shard at any reap point.
    assert loader.earliest_ready() == pytest.approx(1000.0)
    assert loader.reap(250.0) == []
    assert ld.shards[0].landed and not ld.shards[1].landed
    assert loader.shards_landed == 1
    assert loader.reap(510.0) == []
    assert loader.shards_landed == 2
    recs = loader.reap(1000.0)
    assert [r.app for r in recs] == ["a"]
    assert len(recs[0].shard_intervals) == N_DEV
    assert st.inflight_mb == 0.0
    assert led.inflight == {}
    assert led.weights["a"] == pytest.approx([125.0] * N_DEV)
    assert st.tenants["a"].loaded.size_mb == 500.0
    loader.close()


def test_cancel_releases_shard_claims_in_device_order():
    mgr = make_manager()
    loader = ShardedLoaderChannel(mgr, n_devices=N_DEV)
    loader.enqueue(mgr.plan_proactive("a", 0.0), 0.0, predicted_ms=900.0)
    led = mgr.state.devices
    order = []
    orig = led.release_inflight_shard

    def spy(app, device, mb):
        order.append((device, mb))
        orig(app, device, mb)

    led.release_inflight_shard = spy
    # Two shards landed by t=600; cancel mid-flight.
    loader.reap(600.0)
    assert loader.shards_landed == 2
    ld = loader.cancel("a", 600.0)
    assert ld is not None
    assert [d for d, _ in order] == list(range(N_DEV)), \
        "claims released shard-by-shard in device order"
    assert all(mb == pytest.approx(125.0) for _, mb in order)
    assert mgr.state.inflight_mb == 0.0
    assert led.inflight == {}
    assert mgr.state.tenants["a"].loaded is None
    # The landed shards' transfer still earns overlap credit: a partial
    # record is queued for the engine's next reap.
    recs = loader.reap(600.0)
    assert len(recs) == 1 and recs[0].partial
    assert len(recs[0].shard_intervals) == 2
    assert recs[0].load_ms == pytest.approx(500.0), "2 of 4 shard slots"
    assert loader.loads_committed == 0
    loader.close()


def test_shard_that_does_not_fit_fails_whole_load_cleanly():
    """One overfull chip fails the load before any claim lands."""
    # Global 1000MB is plenty; per-chip 100MB < a.bf16's 125MB shard.
    mgr = make_manager(device_budget_mb=100.0)
    loader = ShardedLoaderChannel(mgr, n_devices=N_DEV)
    plan = mgr.plan_demand("a", 0.0)
    assert plan is not None and plan.variant.size_mb == 500.0
    assert loader.enqueue(plan, 0.0, demand=True) is None
    assert mgr.state.inflight_mb == 0.0, "no global claim landed"
    assert mgr.state.devices.inflight == {}, "no shard claim landed"
    assert "a" not in loader.inflight
    loader.close()


def test_sharded_shrink_restages_smaller_shards():
    mgr = make_manager()
    loader = ShardedLoaderChannel(mgr, n_devices=N_DEV)
    loader.enqueue(mgr.plan_proactive("a", 0.0), 0.0, predicted_ms=2000.0)
    loader.reap(300.0)  # one 250ms shard slot landed
    small = mgr.state.tenants["a"].zoo.smallest  # 300MB, load 600ms
    ld = loader.shrink_inflight("a", small, 300.0)
    assert ld is not None and ld.variant is small
    assert mgr.state.inflight_mb == pytest.approx(300.0)
    assert mgr.state.devices.inflight["a"] == pytest.approx([75.0] * N_DEV)
    assert ld.shards[-1].ready_ms == pytest.approx(300.0 + 600.0)
    assert loader.prefetch_shrunk == 1
    # The old load's landed shard is credited; the shrunk load commits.
    recs = loader.reap(900.0)
    kinds = [(r.partial, r.bits) for r in recs]
    assert (True, 32) in kinds and (False, small.bits) in kinds
    assert mgr.state.tenants["a"].loaded is small
    assert mgr.state.inflight_mb == 0.0
    loader.close()


def test_shrink_mid_release_cannot_double_release_claims():
    """The cancel-vs-shrink race: shrinking an in-flight prefetch retires
    the old action record and reserves fresh (smaller) claims under a new
    one — a stale path still holding the OLD record (its shards
    mid-release) must not release the NEW record's claims.  The record
    state machine (staging → cancelled, one-way) guards every release."""
    mgr = make_manager()
    loader = ShardedLoaderChannel(mgr, n_devices=N_DEV)
    old = loader.enqueue(mgr.plan_proactive("a", 0.0), 0.0,
                         predicted_ms=2000.0)
    small = mgr.state.tenants["a"].zoo.smallest
    new = loader.shrink_inflight("a", small, 100.0)
    assert new is not None and new is not old
    assert old.state == "cancelled" and new.staging
    st, led = mgr.state, mgr.state.devices
    assert st.inflight_mb == pytest.approx(300.0)
    claims_before = {a: list(c) for a, c in led.inflight.items()}
    # The race, replayed deliberately: retire the old record again.
    assert loader._retire_load(old) is False, "stale release refused"
    assert st.inflight_mb == pytest.approx(300.0), "no double release"
    assert {a: list(c) for a, c in led.inflight.items()} == claims_before
    # And the live record releases exactly once under repeated cancels.
    assert loader.cancel("a", 200.0) is not None
    assert loader.cancel("a", 200.0) is None
    assert st.inflight_mb == 0.0 and led.inflight == {}
    assert loader.prefetch_wasted == 1
    loader.close()


# ---------------------------------------------------------------------------
# Engine integration: downgrade path, invariant, determinism
# ---------------------------------------------------------------------------
def _sim_server(device_budget_mb, names=("tinyllama-1.1b",)):
    srv = EdgeServer(budget_mb=0.0, policy="iws-bfe", delta_ms=1000.0,
                     sharded_mesh=(N_DEV,),
                     device_budget_mb=device_budget_mb)
    for name in names:
        cfg = get_config(name, reduced=True)
        srv.register_tenant(name, SimTenant(name, cfg))
    srv.budget_mb = srv.contention_budget(0.05)
    srv.start()
    return srv


def test_device_pressure_feeds_admission_downgrade_path():
    """A demand load whose bf16 shard overflows its chip fails in the
    loader; the synchronous admission then downgrades until every shard
    fits — the per-device analogue of the KV self-downgrade."""
    app = "tinyllama-1.1b"
    cfg = get_config(app, reduced=True)
    zoo = zoo_from_config(cfg, precisions=(16, 8))
    mesh = SH.serving_mesh((N_DEV,))
    frac = SH.weight_shard_fraction(cfg, mesh)
    shard16 = zoo.by_bits(16).size_mb * frac
    shard8 = zoo.by_bits(8).size_mb * frac
    assert shard8 < shard16
    srv = _sim_server(device_budget_mb=(shard8 + shard16) / 2)
    plan = srv.manager.plan_demand(app, 0.0)
    assert plan is not None and plan.variant.bits == 16
    assert srv.loader.enqueue(plan, 0.0, demand=True) is None, \
        "bf16 shard overflows its chip: whole load fails cleanly"
    prompts = np.zeros((1, 4), np.int32)
    reqs = [Request(app=app, prompt=prompts[0], max_new=2,
                    arrival_ms=0.0)]
    results, _, toks = srv.engine.execute_batch(
        Batch(app, reqs, prompts, 2), now_ms=0.0)
    assert toks is not None and not results[0].failed
    assert results[0].bits == 8, "admission downgraded to the fitting shard"
    led = srv.manager.state.devices
    led.check_invariant()
    assert led.weights[app] == pytest.approx([shard8] * N_DEV)
    srv.engine.check_event_invariant()
    ev = srv.engine.events[-1]
    assert ev.device_mb is not None and len(ev.device_mb) == N_DEV
    srv.close()


def test_unfittable_smallest_shard_rejects_batch_cleanly():
    """When even the smallest variant's shard overflows its chip, the
    admission is a counted weight failure — never over-budget committed
    per-device state that trips the invariant later."""
    app = "tinyllama-1.1b"
    cfg = get_config(app, reduced=True)
    zoo = zoo_from_config(cfg, precisions=(16, 8))
    frac = SH.weight_shard_fraction(cfg, SH.serving_mesh((N_DEV,)))
    shard8 = zoo.by_bits(8).size_mb * frac
    srv = _sim_server(device_budget_mb=shard8 * 0.5)
    prompts = np.zeros((1, 4), np.int32)
    reqs = [Request(app=app, prompt=prompts[0], max_new=2,
                    arrival_ms=0.0)]
    results, _, toks = srv.engine.execute_batch(
        Batch(app, reqs, prompts, 2), now_ms=0.0)
    assert toks is None and results[0].failed
    assert srv.engine.weight_failures == 1
    assert srv.engine.kv_rejections == 0
    assert srv.manager.state.tenants[app].loaded is None
    srv.manager.state.devices.check_invariant()
    srv.engine.check_event_invariant()
    srv.close()


def test_event_invariant_holds_with_sharded_loads_in_flight():
    srv = _sim_server(device_budget_mb=None,
                      names=("tinyllama-1.1b", "mamba2-780m"))
    cfgs = {n: t.cfg for n, t in srv.tenants.items()}
    trace, _ = poisson_trace(cfgs, requests_per_app=15,
                             mean_iat_ms=300.0, seed=3)
    stats = srv.engine.run_trace(trace)
    assert stats.requests == len(trace)
    srv.engine.check_event_invariant()
    assert any(e.device_mb is not None for e in srv.engine.events)
    assert srv.manager.state.inflight_mb == 0.0, "no stranded claims"
    assert srv.manager.state.devices.inflight == {}
    srv.close()


def _deterministic_run():
    srv = EdgeServer.build(ServingConfig(
        tenants=(TenantSpec("tinyllama-1.1b"), TenantSpec("mamba2-780m")),
        policy="iws-bfe", delta_ms=750.0,
        batching=BatchingSpec(max_batch=4, window_ms=20.0),
        loader=LoaderSpec(sharded=True, mesh_shape=(N_DEV,)),
        executor="sim", kv_headroom_shape=(2, 12)))
    cfgs = {t.name: t.cfg for t in srv.tenants.values()}
    trace, _ = poisson_trace(cfgs, requests_per_app=20,
                             mean_iat_ms=400.0, seed=0)
    stats = srv.engine.run_trace(trace)
    srv.engine.check_event_invariant()
    base = min(r.rid for r in srv.engine.results)
    results = [(r.rid - base, r.app, r.arrival_ms, r.start_ms, r.done_ms,
                r.warm, r.failed, r.bits) for r in srv.engine.results]
    srv.close()
    return stats, results


def test_sharded_sim_run_is_bit_deterministic():
    """Two full sharded sim-executor runs must agree bit-for-bit (the
    acceptance criterion the CI multidevice job re-checks): virtual
    shard schedules never read the wall clock."""
    s1, r1 = _deterministic_run()
    s2, r2 = _deterministic_run()
    assert r1 == r2
    assert s1 == s2
    assert s1.shards_landed > 0 and s1.shards_landed % N_DEV == 0


def test_loader_spec_round_trip_and_validation():
    spec = LoaderSpec(sharded=True, mesh_shape=[2, 4])
    assert spec.mesh_shape == (2, 4)  # list normalized to tuple
    cfg = ServingConfig(tenants=(TenantSpec("tinyllama-1.1b"),),
                        loader=spec, executor="sim")
    rt = ServingConfig.from_dict(cfg.to_dict())
    assert rt.loader == spec
    with pytest.raises(ValueError):
        LoaderSpec(sharded=True, prefetch=False)
    with pytest.raises(ValueError):
        LoaderSpec(sharded=True, mesh_shape=(2, 2, 2))


# ---------------------------------------------------------------------------
# Real mesh placement (CI test-multidevice: 8 fake CPU devices)
# ---------------------------------------------------------------------------
@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs 8 devices (the CI test-multidevice "
                           "job forces 8 fake CPU devices)")
def test_real_mesh_placement_matches_ledger_fractions():
    """device_put the real partition specs onto an 8-way mesh and check
    the bytes each chip actually holds match weight_shard_fraction — the
    figure the per-device ledger budgets with."""
    import jax.numpy as jnp

    from repro.launch.mesh import make_mesh_compat
    from repro.models import transformer as T

    cfg = get_config("tinyllama-1.1b", reduced=True)
    params = jax.tree.map(
        lambda x: x.astype(jnp.bfloat16),
        T.init_params(cfg, jax.random.key(0), jnp.float32))
    mesh = make_mesh_compat((1, 8), ("data", "model"))
    specs = SH.param_specs(cfg, params, mesh, fsdp=False)
    placed = jax.device_put(params, SH.named(mesh, specs))
    per_device = {d.id: 0 for d in mesh.devices.flatten()}
    total = 0
    for leaf in jax.tree.leaves(placed):
        total += leaf.nbytes
        for sh in leaf.addressable_shards:
            per_device[sh.device.id] += sh.data.nbytes
    frac = SH.weight_shard_fraction(
        cfg, SH.LogicalMesh({"data": 1, "model": 8}))
    for dev, nbytes in per_device.items():
        assert nbytes / total == pytest.approx(frac, rel=1e-6), \
            (dev, nbytes, total, frac)
