"""Quantization substrate: zoo building, dispatch, fidelity ordering."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.quant.quantize import (dequantize_params, fidelity,
                                  params_nbytes, quantize_params)

KEY = jax.random.key(3)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("tinyllama-1.1b", reduced=True)
    params = T.init_params(cfg, KEY, jnp.float32)
    tokens = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    return cfg, params, {"tokens": tokens}


def test_size_reduction(setup):
    cfg, params, _ = setup
    base = params_nbytes(params)
    q16 = quantize_params(params, bits=16)
    q8 = quantize_params(params, bits=8, group=32)
    assert params_nbytes(q16) < base * 0.6
    assert params_nbytes(q8) < base * 0.45  # ~3.5x (paper observation B)


def test_quantized_forward_runs_directly(setup):
    """mm() dispatch serves {"q","s"} weights without dequantizing."""
    cfg, params, batch = setup
    q8 = quantize_params(params, bits=8, group=32)
    logits = T.forward(cfg, q8, batch)
    assert np.all(np.isfinite(np.asarray(logits)))


def test_quantized_equals_dequantized(setup):
    """Serving through quant_matmul == dense forward on dequantized w."""
    cfg, params, batch = setup
    q8 = quantize_params(params, bits=8, group=32)
    deq = dequantize_params(q8)
    f_q = T.forward(cfg, q8, batch)
    f_d = T.forward(cfg, deq, batch)
    np.testing.assert_allclose(np.asarray(f_q), np.asarray(f_d),
                               rtol=2e-4, atol=2e-4)


def test_fidelity_ordering(setup):
    """Paper observation (C): lower precision -> lower accuracy. int8
    stays close to the reference; int4 degrades substantially."""
    cfg, params, batch = setup
    def fwd(c, p, b):
        return T.forward(c, p, b)[..., 0, :]
    q8 = quantize_params(params, bits=8, group=32)
    q4 = quantize_params(params, bits=4, group=32)
    f8 = fidelity(cfg, params, q8, batch, fwd)
    f4 = fidelity(cfg, params, q4, batch, fwd)
    assert f8["top1_agreement"] > f4["top1_agreement"]
    assert f8["logit_mse"] < f4["logit_mse"]
    assert f8["top1_agreement"] > 85.0


def test_one_d_params_not_quantized(setup):
    cfg, params, _ = setup
    q8 = quantize_params(params, bits=8, group=32)
    # norm scales survive untouched
    assert not isinstance(q8["layers"]["ln1"], dict)
    assert q8["layers"]["ln1"].dtype == params["layers"]["ln1"].dtype
    # embeddings excluded
    assert not isinstance(q8["embed"], dict)


def test_quantized_decode(setup):
    cfg, params, batch = setup
    q8 = quantize_params(params, bits=8, group=32)
    logits, cache = T.prefill(cfg, q8, batch, max_len=20)
    tok = T.greedy_token(cfg, logits)
    logits, cache = T.decode_step(cfg, q8, cache, tok)
    assert np.all(np.isfinite(np.asarray(logits)))
