"""Elastic-mesh tests: chip loss & recovery as transactional drain plans.

Planner tests drive synthetic zoos against the manager directly (the
three drain outcomes — migrate, downgrade+migrate, unload — plus KV-page
preemption and the all-or-nothing applier).  Engine tests build the
declarative sim stack with a ``FaultSpec`` and check the per-event
ledger invariant, the typed elastic counters, warm-ratio recovery, and
bit-determinism of a faulted run.  Under the CI ``test-multidevice``
job's 8 fake CPU devices, ``TenantRuntime.set_variant`` on an attached
mesh must place real per-chip buffers matching the ledger fractions.
"""
import jax
import pytest

from repro.core import EdgeMultiAI
from repro.core import actions as A
from repro.core.memory_state import DeviceLedger, KVPagePool
from repro.core.model_zoo import ModelVariant, ModelZoo
from repro.distributed import sharding as SH
from repro.serving import EdgeServer, poisson_trace
from repro.serving.api import (BatchingSpec, FaultSpec, LoaderSpec,
                               ServingConfig, TenantSpec)
from repro.serving.elastic import (ElasticController, drain_plan,
                                   rebalance_plan)
from repro.serving.stats import EventKind

N_DEV = 4


def _zoo(name, sizes):
    return ModelZoo(app_name=name, variants=tuple(
        ModelVariant(f"{name}-{i}", bits=32 >> i, size_mb=s,
                     accuracy=90.0 - 10 * i, load_ms=s * 2)
        for i, s in enumerate(sizes)))


def make_manager(budgets, budget_mb=4000.0, **zoos):
    zoos = zoos or {"a": _zoo("a", [400, 200]), "b": _zoo("b", [400, 200])}
    mgr = EdgeMultiAI(zoos, budget_mb=budget_mb, policy="iws-bfe",
                      delta_ms=10.0, migrate=True)
    mgr.state.devices = DeviceLedger(
        tuple(budgets),
        split_fn=lambda app, v: SH.variant_shard_mb(v.size_mb,
                                                    len(budgets)))
    return mgr


# ---------------------------------------------------------------------------
# FaultSpec
# ---------------------------------------------------------------------------
def test_fault_spec_normalizes_and_validates():
    spec = FaultSpec(events=[[9000.0, 3, "up"], (3000, 3, "down")])
    assert spec.events == ((3000.0, 3, "down"), (9000.0, 3, "up"))
    with pytest.raises(ValueError):
        FaultSpec(events=((0.0, 0, "explode"),))
    with pytest.raises(ValueError):
        FaultSpec(events=((-1.0, 0, "down"),))


def test_controller_rejects_chip_beyond_mesh_and_ledgerless_state():
    mgr = make_manager(budgets=(500.0,) * N_DEV)
    with pytest.raises(ValueError, match="chip 9"):
        ElasticController(FaultSpec(events=((0.0, 9, "down"),)), mgr)
    mgr.state.devices = None
    with pytest.raises(ValueError, match="device ledger"):
        ElasticController(FaultSpec(), mgr)


# ---------------------------------------------------------------------------
# The drain planner: simulate == apply, three outcomes
# ---------------------------------------------------------------------------
def test_drain_migrates_dead_shard_and_simulate_matches_apply():
    mgr = make_manager(budgets=(500.0,) * N_DEV)
    st = mgr.state
    st.apply(A.plan_of(A.Load("a", st.tenants["a"].zoo.largest)))
    st.apply(A.plan_of(A.Load("b", st.tenants["b"].zoo.largest)))
    dead = 1
    st.devices.offline(dead)
    acts, counters, preempted, vacated = drain_plan(st, dead)
    assert counters == {"migrations": 2, "downgrades": 0, "unloads": 0}
    assert preempted == () and vacated == pytest.approx(200.0)
    assert st.simulate(A.ResidencyPlan(acts)) is None
    st.apply(A.ResidencyPlan(acts))
    st.devices.check_invariant()
    assert st.devices.weights["a"][dead] == 0.0
    assert st.devices.weights["b"][dead] == 0.0
    assert sum(st.devices.weights["a"]) == pytest.approx(400.0)
    # Both tenants stay resident at full precision.
    assert st.tenants["a"].loaded.size_mb == 400.0


def test_drain_downgrades_when_survivors_cannot_absorb_full_share():
    # One tenant at 120/chip; survivors have 10 free each (30 total):
    # the 120 share cannot rehome, the 200MB variant's layout-preserving
    # projection (60/chip, freeing 60 on each survivor) can.
    mgr = make_manager(budgets=(130.0,) * N_DEV,
                       a=_zoo("a", [480, 200]))
    st = mgr.state
    st.apply(A.plan_of(A.Load("a", st.tenants["a"].zoo.largest)))
    dead = 0
    st.devices.offline(dead)
    acts, counters, _, _ = drain_plan(st, dead)
    assert counters["downgrades"] == 1 and counters["unloads"] == 0
    assert counters["migrations"] >= 1
    assert st.simulate(A.ResidencyPlan(acts)) is None
    st.apply(A.ResidencyPlan(acts))
    st.devices.check_invariant()
    assert st.tenants["a"].loaded.size_mb == 200.0
    assert st.devices.weights["a"][dead] == 0.0
    assert sum(st.devices.weights["a"]) == pytest.approx(200.0)


def test_drain_unloads_when_nothing_fits():
    # Survivors are full at every variant size: the tenant goes cold.
    mgr = make_manager(budgets=(100.0,) * N_DEV,
                       a=_zoo("a", [400, 399]))
    st = mgr.state
    st.apply(A.plan_of(A.Load("a", st.tenants["a"].zoo.largest)))
    st.devices.offline(2)
    acts, counters, _, _ = drain_plan(st, 2)
    assert counters == {"migrations": 0, "downgrades": 0, "unloads": 1}
    assert st.simulate(A.ResidencyPlan(acts)) is None
    st.apply(A.ResidencyPlan(acts))
    st.devices.check_invariant()
    assert st.tenants["a"].loaded is None
    assert "a" not in st.devices.weights


def test_drain_evicts_kv_pages_homed_on_the_dead_chip():
    mgr = make_manager(budgets=(500.0,) * N_DEV)
    st = mgr.state
    st.kv_pool = KVPagePool(page_mb=1.0, device_pages=(4,) * N_DEV)
    st.apply(A.plan_of(A.Load("a", st.tenants["a"].zoo.largest)))
    # Pin sequences to known chips through the pool's device choice.
    st.apply(A.plan_of(A.ChargeKV("a", 4.0, seq=1, pages=4)))   # chip 0
    st.apply(A.plan_of(A.ChargeKV("a", 4.0, seq=2, pages=4)))   # chip 1
    dead = next(d for d in range(N_DEV)
                if any(pid in range(*_page_range(st.kv_pool, d))
                       for pid in st.kv_pool.tables["a"][2]))
    st.devices.offline(dead)
    st.kv_pool.offline_device(dead)
    acts, _, preempted, _ = drain_plan(st, dead)
    assert ("a", 2) in preempted or ("a", 1) in preempted
    assert st.simulate(A.ResidencyPlan(acts)) is None
    st.apply(A.ResidencyPlan(acts))
    st.kv_pool.check_invariant()
    assert st.kv_pool.seqs_on_device(dead) == []


def _page_range(pool, device):
    start = pool._starts[device]
    return start, start + pool.device_pages[device]


def test_apply_is_all_or_nothing_on_mid_plan_failure():
    mgr = make_manager(budgets=(500.0,) * N_DEV)
    st = mgr.state
    st.apply(A.plan_of(A.Load("a", st.tenants["a"].zoo.largest)))
    st.devices.offline(1)
    acts, _, _, _ = drain_plan(st, 1)
    # Poison the tail: a migration from an empty chip must fail after
    # the genuine drain actions already applied.
    poisoned = A.ResidencyPlan(acts + (A.MigrateShard("a", 1, 0, 999.0),))
    before = ({app: tuple(w) for app, w in st.devices.weights.items()},
              st.used_mb, st.devices.shards_migrated)
    assert st.simulate(poisoned) is not None
    with pytest.raises(A.PlanError):
        st.apply(poisoned)
    after = ({app: tuple(w) for app, w in st.devices.weights.items()},
             st.used_mb, st.devices.shards_migrated)
    assert before == after, "failed plan leaked partial state"
    # The genuine plan still applies cleanly afterwards and reconciles
    # the offline chip with its zeroed budget.
    st.apply(A.ResidencyPlan(acts))
    st.devices.check_invariant()


def test_rebalance_moves_surplus_back_toward_canonical():
    mgr = make_manager(budgets=(500.0,) * N_DEV)
    st = mgr.state
    st.apply(A.plan_of(A.Load("a", st.tenants["a"].zoo.largest)))
    st.devices.offline(1)
    acts, _, _, _ = drain_plan(st, 1)
    st.apply(A.ResidencyPlan(acts))
    st.devices.online(1)
    back = rebalance_plan(st, 1)
    assert back and all(isinstance(a, A.MigrateShard) and a.dst == 1
                        for a in back)
    assert st.simulate(A.ResidencyPlan(back)) is None
    st.apply(A.ResidencyPlan(back))
    st.devices.check_invariant()
    canon = st.devices.split("a", st.tenants["a"].loaded)
    assert st.devices.weights["a"] == pytest.approx(list(canon))


# ---------------------------------------------------------------------------
# The controller in the engine loop (declarative sim stack)
# ---------------------------------------------------------------------------
ELASTIC_TENANTS = ("tinyllama-1.1b", "mamba2-780m")
FAULT = FaultSpec(events=((3000.0, 3, "down"), (9000.0, 3, "up")))


def _run_elastic(fault, continuous=False, requests=30):
    srv = EdgeServer.build(ServingConfig(
        tenants=tuple(TenantSpec(n) for n in ELASTIC_TENANTS),
        executor="sim", policy="iws-bfe", delta_ms=750.0,
        batching=BatchingSpec(max_batch=4, window_ms=20.0,
                              continuous=continuous),
        loader=LoaderSpec(sharded=True, mesh_shape=(N_DEV,)),
        kv_headroom_shape=(2, 12), fault=fault))
    cfgs = {t.name: t.cfg for t in srv.tenants.values()}
    trace, _ = poisson_trace(cfgs, requests_per_app=requests,
                             mean_iat_ms=400.0, seed=7)
    stats = srv.engine.run_trace(trace)
    srv.engine.check_event_invariant()
    events = [(ev.t_ms, str(ev.kind), ev.app, ev.kv_mb, ev.used_mb,
               ev.device_mb, ev.device_budget_mb)
              for ev in srv.engine.events]
    srv.close()
    return stats, events


def test_faulted_run_holds_event_invariant_and_counts_the_cycle():
    stats, events = _run_elastic(FAULT)
    assert stats.chips_lost == 1 and stats.chips_recovered == 1
    assert stats.drain_migrations >= 1
    kinds = [e[1] for e in events]
    assert "chip_down" in kinds and "chip_up" in kinds
    assert "drain" in kinds
    assert kinds.index("chip_down") < kinds.index("drain") \
        < kinds.index("chip_up")
    # The chip_down event snapshots the pre-loss budget; every event
    # after it (until chip_up) shows chip 3 budget 0 and weights 0.
    down = next(i for i, e in enumerate(events) if e[1] == "chip_down")
    up = next(i for i, e in enumerate(events) if e[1] == "chip_up")
    assert events[down][6][3] > 0.0
    for t, kind, app, kv, used, dev, budget in events[down + 1:up]:
        if dev is not None:
            assert budget[3] == 0.0
            assert dev[3] <= A.EPS, (kind, app, dev)


def test_serving_continues_during_drain_and_recovery_restores_warm():
    faulted, _ = _run_elastic(FAULT)
    clean, _ = _run_elastic(None)
    assert faulted.requests == clean.requests, "no request lost to loss"
    assert faulted.weight_failures == 0
    # Recovery restores the pre-loss warm ratio (the drain plan rehomes
    # shards instead of cold-starting tenants; the cycle may cost at
    # most a bounded dip on this trace).
    assert faulted.warm_ratio >= clean.warm_ratio - 0.1
    assert clean.chips_lost is None  # elastic block absent without fault


def test_faulted_sim_run_is_bit_deterministic():
    s1, e1 = _run_elastic(FAULT)
    s2, e2 = _run_elastic(FAULT)
    assert s1 == s2
    assert e1 == e2


def test_continuous_engine_preempts_and_requeues_across_loss():
    stats, events = _run_elastic(FAULT, continuous=True)
    assert stats.chips_lost == 1 and stats.chips_recovered == 1
    assert stats.kv_pages_used == 0, "every sequence drained its pages"
    assert stats.kv_overrelease_mb == 0.0
    kinds = {e[1] for e in events}
    assert {"chip_down", "chip_up", "drain"} <= kinds


def test_stats_to_dict_carries_elastic_block_only_when_configured():
    faulted, _ = _run_elastic(FAULT)
    clean, _ = _run_elastic(None)
    d = faulted.to_dict()
    assert d["chips_lost"] == 1 and d["drain_downgrades"] >= 0
    assert "chips_lost" not in clean.to_dict()
    assert str(EventKind.CHIP_DOWN) == "chip_down"


# ---------------------------------------------------------------------------
# Physical placement (CI test-multidevice: 8 fake CPU devices)
# ---------------------------------------------------------------------------
@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs 8 devices (the CI test-multidevice "
                           "job forces 8 fake CPU devices)")
def test_set_variant_places_real_shards_matching_ledger_fractions():
    """``TenantRuntime.set_variant`` on an attached mesh must put real
    per-chip buffers whose byte fractions match the figure the
    DeviceLedger budgets with — and ``reshard_device_params`` must keep
    them on-mesh."""
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.launch.mesh import make_mesh_compat
    from repro.models import transformer as T
    from repro.serving.server import TenantRuntime

    cfg = get_config("tinyllama-1.1b", reduced=True)
    params = T.init_params(cfg, jax.random.key(0), jnp.float32)
    tr = TenantRuntime("tinyllama-1.1b", cfg, params, precisions=(16, 8))
    mesh = make_mesh_compat((1, 8), ("data", "model"))
    tr.attach_mesh(mesh)
    frac = SH.weight_shard_fraction(
        cfg, SH.LogicalMesh({"data": 1, "model": 8}))
    for bits in (16, 8):
        tr.set_variant(tr.zoo.by_bits(bits))
        per_device = {d.id: 0 for d in mesh.devices.flatten()}
        total = 0
        for leaf in jax.tree.leaves(tr.device_params):
            total += leaf.nbytes
            for sh in leaf.addressable_shards:
                per_device[sh.device.id] += sh.data.nbytes
        assert len(per_device) == 8 and total > 0
        # Host trees are quantized (replicated scale/meta leaves), so
        # per-chip bytes track the unquantized ledger fraction only to a
        # few percent (int8's scales are a larger share of the tree).
        for dev, nbytes in per_device.items():
            assert nbytes / total == pytest.approx(frac, rel=0.06), \
                (bits, dev, nbytes, total)
    tr.reshard_device_params()  # recovery path: same mesh, still placed
    leaf = jax.tree.leaves(tr.device_params)[0]
    assert len(leaf.addressable_shards) == 8


# ---------------------------------------------------------------------------
# Degrade-aware drain ranking + re-promotion (cluster-tier satellites)
# ---------------------------------------------------------------------------
def _ranked_manager():
    """Two equal tenants on a mesh where only ONE dead-chip share can
    rehome intact: 240/chip budgets, both loaded at 400 (100/chip), so
    survivors hold 3x40 free — exactly one share.  Whoever drain_plan
    ranks first migrates intact; the other degrades."""
    mgr = make_manager(budgets=(240.0,) * N_DEV,
                       a=_zoo("a", [400, 200]), b=_zoo("b", [400, 200]))
    st = mgr.state
    st.apply(A.plan_of(A.Load("a", st.tenants["a"].zoo.largest)))
    st.apply(A.plan_of(A.Load("b", st.tenants["b"].zoo.largest)))
    return mgr


@pytest.mark.parametrize("busy,idle", [("a", "b"), ("b", "a")])
def test_drain_ranks_by_accuracy_times_readiness(busy, idle):
    mgr = _ranked_manager()
    st = mgr.state
    # The busy tenant's next request is imminent -> readiness 0 -> it
    # ranks last and eats the downgrade; the idle one (no prediction ->
    # pure accuracy) migrates intact.  Symmetric under the swap, so the
    # order is the score's doing, not the name tie-break.
    st.tenants[busy].predicted_next = 100.0
    st.tenants[idle].predicted_next = None
    st.devices.offline(3)
    acts, counters, _, _ = drain_plan(st, 3, now=100.0)
    assert counters["downgrades"] == 1
    assert st.simulate(A.ResidencyPlan(acts)) is None
    st.apply(A.ResidencyPlan(acts))
    st.devices.check_invariant()
    assert st.tenants[idle].loaded.size_mb == 400.0
    assert st.tenants[busy].loaded.size_mb == 200.0


def test_chip_up_repromotes_demoted_variant():
    # Tight mesh from the downgrade test: the drain demotes 480 -> 200;
    # the chip's return must restore the original variant and count it.
    mgr = make_manager(budgets=(130.0,) * N_DEV,
                       a=_zoo("a", [480, 200]))
    st = mgr.state
    st.apply(A.plan_of(A.Load("a", st.tenants["a"].zoo.largest)))
    ctl = ElasticController(
        FaultSpec(events=((10.0, 0, "down"), (50.0, 0, "up"))), mgr)
    ctl.poll(10.0)
    assert ctl.drain_downgrades == 1
    assert st.tenants["a"].loaded.size_mb == 200.0
    assert ctl.repromotions == 0
    ctl.poll(50.0)
    assert ctl.repromotions == 1
    assert st.tenants["a"].loaded.size_mb == 480.0
    assert not ctl._demoted
    st.devices.check_invariant()
    # Idempotent: a second cycle with nothing demoted re-promotes nothing.
    assert ctl.next_event_ms() == float("inf")


def test_repromotion_dropped_when_capacity_never_returns():
    # The demoting chip comes back while ANOTHER chip is still down, so
    # the original variant's canonical split (120/chip incl. the dead
    # one) cannot fit: the re-promotion is dropped (not retried forever)
    # and the tenant keeps its demoted variant.
    mgr = make_manager(budgets=(130.0,) * N_DEV,
                       a=_zoo("a", [480, 200]))
    st = mgr.state
    st.apply(A.plan_of(A.Load("a", st.tenants["a"].zoo.largest)))
    ctl = ElasticController(
        FaultSpec(events=((10.0, 0, "down"), (20.0, 1, "down"),
                          (50.0, 0, "up"))), mgr)
    ctl.poll(20.0)
    assert st.tenants["a"].loaded.size_mb == 200.0
    ctl.poll(50.0)
    assert ctl.repromotions == 0
    assert not ctl._demoted
    assert st.tenants["a"].loaded.size_mb == 200.0
    st.devices.check_invariant()


def test_fault_prob_validates_and_gates_the_schedule():
    with pytest.raises(ValueError, match="prob"):
        FaultSpec(prob=1.5)
    with pytest.raises(ValueError, match="prob"):
        FaultSpec(prob=-0.1)
    # prob=1.0: every scheduled down fires through the injector's
    # counter-based stream; prob~0: none do (the schedule is armed but
    # the dice never land).
    for prob, lost in ((1.0, 1), (1e-12, 0)):
        mgr = make_manager(budgets=(500.0,) * N_DEV)
        ctl = ElasticController(
            FaultSpec(events=((10.0, 1, "down"),), prob=prob, seed=5),
            mgr)
        ctl.poll(10.0)
        assert ctl.chips_lost == lost, prob


def test_stochastic_fault_run_is_bit_deterministic():
    spec = FaultSpec(events=FAULT.events, prob=0.5, seed=3)
    s1, e1 = _run_elastic(spec)
    s2, e2 = _run_elastic(spec)
    assert s1 == s2 and e1 == e2
    # And the deterministic path (prob=0) is unchanged by the knob:
    # FaultSpec(prob=0.0) equals the legacy spec field for field.
    assert FaultSpec(events=FAULT.events) == FAULT


def test_stats_carry_repromotions_counter():
    stats, _ = _run_elastic(FAULT)
    d = stats.to_dict()
    assert d["repromotions"] >= 0
