"""Tests for the declarative serving API (`repro.serving.api`) and the
policy registry: config round-trip build, registry resolution, the
deprecation shims, background predictor fits, and the batch-aware
procurement plugin beating head-batch planning on a burst trace.
"""
import functools

import numpy as np
import pytest

from repro.configs import get_config
from repro.core import EdgeMultiAI
from repro.core.policies import (BatchAware, DesperationFallback,
                                 Policy, available_policies,
                                 register_policy, resolve_policy)
from repro.serving import (EdgeServer, Request,
                           kv_cache_mb, poisson_trace)
from repro.serving.api import (BatchingSpec, LoaderSpec, PredictorSpec,
                               ServingConfig, SimTenant, TenantSpec)

TENANTS = ("tinyllama-1.1b", "mamba2-780m")


def sim_config(**kw):
    base = dict(
        tenants=tuple(TenantSpec(n) for n in TENANTS),
        policy="iws-bfe", executor="sim", delta_ms=750.0,
        batching=BatchingSpec(max_batch=4, window_ms=20.0),
        kv_headroom_shape=(2, 12))
    base.update(kw)
    return ServingConfig(**base)


def stub_executor(runtime, batch, extra=None):
    return np.zeros((len(batch.requests), batch.max_new), np.int32)


# ---------------------------------------------------------------------------
# ServingConfig: declarative round trip + build wiring
# ---------------------------------------------------------------------------
def test_config_dict_round_trip():
    cfg = sim_config(policy="batch-bfe", budget_mb=12.5)
    assert ServingConfig.from_dict(cfg.to_dict()) == cfg


def test_config_validation():
    with pytest.raises(ValueError, match="at least one"):
        ServingConfig(tenants=())
    with pytest.raises(ValueError, match="duplicate"):
        ServingConfig(tenants=(TenantSpec("a", arch=TENANTS[0]),
                               TenantSpec("a", arch=TENANTS[0])))
    with pytest.raises(KeyError, match="registered policies"):
        sim_config(policy="not-a-policy")
    with pytest.raises(ValueError, match="executor"):
        sim_config(executor="quantum")


def test_build_wires_whole_stack():
    """One build call: tenants registered, predictors installed per spec,
    budget derived with KV headroom, policy resolved through the
    registry, loader + engine attached and started."""
    cfg = sim_config(predictor=PredictorSpec(context=4, hidden=8))
    srv = EdgeServer.build(cfg)
    try:
        assert set(srv.tenants) == set(TENANTS)
        assert all(isinstance(t, SimTenant) for t in srv.tenants.values())
        assert all(t.predictor.context == 4 for t in srv.tenants.values())
        assert srv.manager is not None and srv.engine is not None
        assert srv.loader is not None
        assert srv.manager.policy.name == "iws-bfe"
        assert isinstance(srv.manager.fallback, DesperationFallback)
        # Derived budget: contention plus the (2, 12)-shaped cache.
        kv = max(kv_cache_mb(t.cfg, 2, 12) for t in srv.tenants.values())
        assert srv.budget_mb == pytest.approx(srv.contention_budget(kv))
        total16 = sum(t.zoo.largest.size_mb for t in srv.tenants.values())
        assert total16 > srv.budget_mb, "derived budget forces contention"
    finally:
        srv.close()


def test_sim_executor_run_is_deterministic():
    """The sim-time executor makes a full engine run reproducible
    bit-for-bit: no XLA, no wall clock in the virtual timeline."""
    def one_run():
        srv = EdgeServer.build(sim_config())
        cfgs = {t.name: t.cfg for t in srv.tenants.values()}
        trace, _ = poisson_trace(cfgs, requests_per_app=10,
                                 mean_iat_ms=400.0, seed=0)
        stats = srv.engine.run_trace(trace)
        srv.engine.check_event_invariant()
        done = [r.done_ms for r in srv.engine.results]
        srv.close()
        return stats, done

    (s1, d1), (s2, d2) = one_run(), one_run()
    assert d1 == d2
    assert s1.warm_ratio == s2.warm_ratio
    assert s1.requests == len(d1)


def test_reactive_loader_spec():
    srv = EdgeServer.build(sim_config(loader=LoaderSpec(prefetch=False)))
    try:
        assert srv.loader is None, "prefetch=False => no background loader"
    finally:
        srv.close()


def test_config_expresses_unmanaged_baseline():
    """policy="none" (the paper's no-framework baseline) must be
    declarable through the front door, not just the imperative path."""
    cfg = sim_config(policy="none", budget_mb=100.0)
    assert ServingConfig.from_dict(cfg.to_dict()) == cfg
    srv = EdgeServer.build(cfg)
    try:
        assert srv.manager.policy is None
        assert srv.manager.fallback is None, "baseline: no eviction power"
    finally:
        srv.close()


def test_to_dict_rejects_unregistered_policy_loudly():
    class Anonymous(Policy):
        pass

    cfg = sim_config(policy=Anonymous())
    with pytest.raises(ValueError, match="register_policy"):
        cfg.to_dict()


def test_fit_steps_plumbed_to_background_fit():
    srv = EdgeServer.build(sim_config(
        predictor=PredictorSpec(fit_steps=7)))
    try:
        tr = next(iter(srv.tenants.values()))
        assert tr.predictor.fit_steps == 7
        for _ in range(30):
            tr.predictor.observe(100.0)
        fut = srv.loader.submit_fit(tr.predictor)
        fut.result()
        assert tr.predictor.losses is not None
        assert len(tr.predictor.losses) == 7, "configured steps ran"
    finally:
        srv.close()


# ---------------------------------------------------------------------------
# Policy registry
# ---------------------------------------------------------------------------
def test_registry_has_paper_policies_and_plugins():
    assert {"lfe", "bfe", "ws-bfe", "iws-bfe",
            "batch-bfe", "batch-iws-bfe"} <= set(available_policies())


def test_resolve_policy_unknown_name_is_clear():
    with pytest.raises(KeyError) as ei:
        resolve_policy("wfe")
    msg = str(ei.value)
    assert "wfe" in msg and "iws-bfe" in msg, "error lists what exists"


def test_resolve_policy_accepts_instance_class_and_name():
    inst = resolve_policy("bfe")
    assert resolve_policy(inst) is inst
    assert resolve_policy(type(inst)).name == "bfe"
    with pytest.raises(TypeError):
        resolve_policy(42)


def test_register_policy_plugin_reaches_manager():
    """A user-registered policy resolves by name straight into the
    manager — policy as plugin, not manager special case."""
    @register_policy("test-always-smallest")
    class AlwaysSmallest(Policy):
        def victim_filter(self, state, app, now, *, delta, history):
            return []

        def plan_procure(self, state, app, now, *, delta, history):
            from repro.core.policies import ProcurePlan
            t = state.tenants[app]
            small = t.zoo.smallest
            if state.free_mb + (t.loaded.size_mb if t.loaded else 0.0) \
                    >= small.size_mb:
                return ProcurePlan(app, small)
            return ProcurePlan(app, None)

    from repro.core.model_zoo import ModelVariant, ModelZoo
    zoo = ModelZoo(app_name="a", variants=(
        ModelVariant("a-16", 16, 100.0, 99.0, 10.0),
        ModelVariant("a-8", 8, 50.0, 95.0, 5.0)))
    mgr = EdgeMultiAI({"a": zoo}, budget_mb=500.0,
                      policy="test-always-smallest", delta_ms=10.0)
    adm = mgr.admit_batch("a", now=0.0, kv_mb=1.0)
    assert not adm.failed and adm.bits == 8


def test_batch_aware_wraps_any_policy():
    ba = BatchAware("iws-bfe")
    assert ba.name == "batch-iws-bfe"
    assert ba.inner.name == "iws-bfe"
    from repro.core.policies import DemandContext
    ctx = DemandContext(kv_head_mb=1.0, kv_full_mb=4.0, queue_depth=1,
                        max_batch=4)
    assert ba.demand_charge(ctx) == 4.0
    assert resolve_policy("bfe").demand_charge(ctx) == 1.0


# ---------------------------------------------------------------------------
# Background predictor fits (satellite: ROADMAP open item)
# ---------------------------------------------------------------------------
def test_background_fit_scheduled_and_hit_rate_reported():
    srv = EdgeServer.build(sim_config(
        tenants=(TenantSpec(TENANTS[0]),),
        predictor=PredictorSpec(context=4, hidden=8, min_fit_samples=6,
                                refit_interval=4)))
    cfg = get_config(TENANTS[0], reduced=True)
    rng = np.random.default_rng(0)
    trace = [Request(app=TENANTS[0],
                     prompt=rng.integers(0, cfg.vocab_size, 5)
                     .astype(np.int32),
                     max_new=2, arrival_ms=250.0 * i)
             for i in range(12)]
    stats = srv.engine.run_trace(trace)
    srv.close()  # drains the staging worker: scheduled fits complete
    assert stats.fits_scheduled >= 1, "fit handed to the loader worker"
    tr = srv.tenants[TENANTS[0]]
    assert tr.predictor.fits >= 1, "background fit completed"
    sstats = srv.stats()
    assert 0.0 <= sstats.prediction_hit_rate <= 1.0
    assert sstats.predictor_fits == tr.predictor.fits
    # A steady 250ms cadence: after warmup most arrivals are predicted.
    assert stats.prediction_hit_rate > 0.5


def test_fit_due_schedule():
    from repro.core.predictor import SeriesPredictor
    p = SeriesPredictor(context=4, hidden=8, min_fit_samples=6,
                        refit_interval=4)
    for v in (10.0,) * 5:
        p.observe(v)
    assert not p.fit_due(), "below min_fit_samples"
    p.observe(10.0)
    assert p.fit_due()
    p.fit(steps=5)
    assert not p.fit_due(), "refit only after refit_interval new samples"
    for v in (10.0,) * 4:
        p.observe(v)
    assert p.fit_due()


# ---------------------------------------------------------------------------
# Batch-aware procurement beats head-batch planning on a burst trace
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def _real_zoo(name):
    """The quantized zoo a served tenant will get — sizes come from the
    actual quantized params (seed-independent: shapes decide size), so
    budgets derived here match the built server exactly."""
    import jax
    import jax.numpy as jnp

    from repro.models import transformer as T
    from repro.serving import TenantRuntime
    cfg = get_config(name, reduced=True)
    params = T.init_params(cfg, jax.random.key(0), jnp.float32)
    return TenantRuntime(name, cfg, params).zoo


def _burst_run(policy: str):
    """One cold tenant; a single queued request triggers the demand load
    and a burst fills the batch while the transfer stages."""
    name = TENANTS[0]
    cfg = get_config(name, reduced=True)
    zoo = _real_zoo(name)
    plen, max_new = 6, 4
    kv1 = kv_cache_mb(cfg, 1, plen + max_new)
    kv4 = kv_cache_mb(cfg, 4, plen + max_new)
    bf16, int8 = zoo.by_bits(16).size_mb, zoo.by_bits(8).size_mb
    budget = bf16 + (kv1 + kv4) / 2
    # Premises of the scenario: head-batch planning picks bf16 (fits
    # beside one request's cache), the full batch's cache does not fit
    # beside bf16, and int8 fits beside the full batch's cache.
    assert bf16 + kv1 <= budget < bf16 + kv4
    assert int8 + kv4 <= budget

    srv = EdgeServer.build(ServingConfig(
        tenants=(TenantSpec(name),), budget_mb=budget, policy=policy,
        batching=BatchingSpec(max_batch=4)))
    srv.engine._executor = stub_executor
    rng = np.random.default_rng(1)
    load_ms = zoo.largest.load_ms
    # One request at t=0 stages the demand load; three more land inside
    # the staging interval, so the admitted batch is 4 wide.
    arrivals = [0.0] + [load_ms * f for f in (0.2, 0.4, 0.6)]
    trace = [Request(app=name,
                     prompt=rng.integers(0, cfg.vocab_size, plen)
                     .astype(np.int32),
                     max_new=max_new, arrival_ms=t) for t in arrivals]
    stats = srv.engine.run_trace(trace)
    srv.engine.check_event_invariant()
    srv.close()
    assert stats.requests == 4
    assert all(not r.failed for r in srv.engine.results)
    return stats


def test_batch_aware_avoids_self_downgrade_thrash_under_burst():
    head = _burst_run("bfe")
    aware = _burst_run("batch-bfe")
    # Head-batch planning loads bf16 for the lone queued request, then
    # the 4-wide batch's cache forces an immediate self-downgrade — a
    # wasted large-variant transfer.  Batch-aware plans the full-batch
    # bound and lands on int8 in one transfer.
    assert head.kv_downgrades >= 1
    assert aware.kv_downgrades == 0
    assert aware.warm_ratio >= head.warm_ratio
