"""Background model-loading pipeline tests: in-flight memory charges,
prefetch commit/cancel lifecycle, predictor-driven warm hits in the
engine, and the per-event budget invariant with loads in flight.

Synthetic-zoo tests drive the manager + loader directly (no models, the
no-op stage function); engine tests use real reduced configs with the
stub executor, as in tests/test_engine.py.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import EdgeMultiAI
from repro.core.model_zoo import ModelVariant, ModelZoo
from repro.core.policies import resolve_policy
from repro.core.predictor import SeriesPredictor
from repro.models import transformer as T
from repro.serving import (BackgroundLoader, EdgeServer, Request,
                           poisson_trace)

TENANTS = ["tinyllama-1.1b", "mamba2-780m"]


def _zoo(name, sizes):
    return ModelZoo(app_name=name, variants=tuple(
        ModelVariant(f"{name}-{i}", bits=32 >> i, size_mb=s,
                     accuracy=90.0 - 10 * i, load_ms=s * 2)
        for i, s in enumerate(sizes)))


def make_manager(budget_mb=1000.0, **zoos):
    zoos = zoos or {"a": _zoo("a", [500, 300]), "b": _zoo("b", [400, 200])}
    return EdgeMultiAI(zoos, budget_mb=budget_mb, policy="iws-bfe",
                       delta_ms=10.0)


def stub_executor(runtime, batch, extra=None):
    return np.zeros((len(batch.requests), batch.max_new), np.int32)


def make_server(budget_mb=1e9, **kw):
    srv = EdgeServer(budget_mb=budget_mb, policy="iws-bfe",
                     delta_ms=1000.0, **kw)
    for name in TENANTS:
        cfg = get_config(name, reduced=True)
        srv.register(name, cfg, T.init_params(
            cfg, jax.random.key(hash(name) % 2 ** 31), jnp.float32))
    return srv


# ---------------------------------------------------------------------------
# In-flight charge lifecycle (manager + loader, no models)
# ---------------------------------------------------------------------------
def test_enqueue_charges_inflight_and_commit_releases():
    mgr = make_manager()
    loader = BackgroundLoader(mgr)
    plan = mgr.plan_demand("a", now=0.0)
    ld = loader.enqueue(plan, now_ms=0.0, demand=True)
    assert ld is not None and ld.charge_mb == 500.0
    st = mgr.state
    assert st.tenants["a"].inflight_mb == 500.0
    assert st.inflight_mb == 500.0
    assert st.free_mb == pytest.approx(500.0), "charge claims the pool"
    assert st.tenants["a"].loaded is None, "not committed yet"
    assert loader.reap(ld.ready_ms - 1.0) == []
    recs = loader.reap(ld.ready_ms)
    assert [r.app for r in recs] == ["a"]
    assert st.tenants["a"].loaded.size_mb == 500.0
    assert st.inflight_mb == 0.0, "commit converts the claim to weights"
    assert st.free_mb == pytest.approx(500.0)
    loader.close()


def test_procurement_cannot_double_book_inflight_memory():
    """While a's 500MB prefetch is staging, b's procurement must not
    plan into that memory."""
    mgr = make_manager(budget_mb=800.0)
    loader = BackgroundLoader(mgr)
    loader.enqueue(mgr.plan_demand("a", now=0.0), now_ms=0.0)
    assert mgr.state.free_mb == pytest.approx(300.0)
    plan = resolve_policy("iws-bfe").plan_procure(
        mgr.state, "b", 0.0, delta=10.0, history=10.0)
    assert plan.ok
    assert plan.variant.size_mb <= 300.0, \
        "policy sized b's variant inside the remaining free pool"
    # And the mid-staging tenant is never a victim.
    assert all(ev.app != "a" for ev in plan.evictions)
    loader.close()


def test_wrong_prediction_cancel_releases_charge():
    mgr = make_manager()
    loader = BackgroundLoader(mgr)
    plan = mgr.plan_proactive("a", now=0.0)
    ld = loader.enqueue(plan, now_ms=0.0, predicted_ms=2000.0)
    assert mgr.state.inflight_mb == 500.0
    # Inside the window: nothing to cancel yet.
    loader.cancel_stale(ld.ready_ms + 1.0, delta_ms=50.0,
                        has_queued=lambda a: False)
    assert "a" in loader.inflight
    # Window long past, no request in sight: the guess is wrong.
    n = loader.cancel_stale(3000.0, delta_ms=50.0,
                            has_queued=lambda a: False)
    assert n == 1
    assert loader.prefetch_wasted == 1
    assert mgr.state.inflight_mb == 0.0, "cancelled claim returned"
    assert mgr.state.free_mb == pytest.approx(1000.0)
    assert mgr.state.tenants["a"].loaded is None
    loader.close()


def test_cancel_restores_device_to_accounted_variant():
    """If the wall-clock staging already ran, cancel re-stages whatever
    the accounting says is loaded so device and state agree."""
    staged = []
    mgr = make_manager()
    loader = BackgroundLoader(
        mgr, stage_fn=lambda app, v: staged.append((app, v)))
    ld = loader.enqueue(mgr.plan_proactive("a", 0.0), now_ms=0.0,
                        predicted_ms=10.0)
    ld.future.result()  # wall-clock staging lands
    loader.cancel("a", now_ms=500.0)
    loader.close()  # drain the restore task
    assert staged[-1] == ("a", None), "device restored to unloaded"


def test_enqueue_skips_resident_downgrades_and_duplicates():
    mgr = make_manager()
    loader = BackgroundLoader(mgr)
    big = mgr.state.tenants["a"].zoo.largest
    mgr.state.load("a", big)
    assert loader.enqueue(mgr.plan_proactive("a", 0.0), 0.0) is None
    mgr.state.load("a", None)
    ld = loader.enqueue(mgr.plan_demand("a", 0.0), 0.0)
    assert ld is not None
    assert loader.enqueue(mgr.plan_demand("a", 0.0), 0.0) is None, \
        "plan_demand refuses while mid-staging"
    loader.close()


# ---------------------------------------------------------------------------
# Engine integration (real configs, stub executor)
# ---------------------------------------------------------------------------
def test_predictor_driven_prefetch_produces_warm_hit():
    """Teach the RNN predictor a cadence, evict the tenant, and let the
    prediction-triggered background load restore it before the next
    request: the admission must be a warm prefetch hit."""
    srv = make_server()
    srv.start()
    srv.engine._executor = stub_executor
    app = TENANTS[0]
    cfg = get_config(app, reduced=True)
    rng = np.random.default_rng(0)

    def req(t):
        return Request(app=app, prompt=rng.integers(
            0, cfg.vocab_size, 5).astype(np.int32), max_new=2,
            arrival_ms=t)

    # A regular 1000ms cadence the mean-gap predictor nails.
    for t in (0.0, 1000.0, 2000.0, 3000.0, 4000.0):
        srv.engine.submit(req(t), t)
        batch = srv.engine.batcher.next_batch()
        srv.engine.execute_batch(batch, t)
    # Simulate an eviction between requests (another tenant's pressure).
    srv.manager.state.load(app, None)
    srv.tenants[app].set_variant(None)
    # Next request predicted at ~5000: the trigger fires early enough...
    t_trig = srv.next_prefetch_trigger(3500.0)
    assert 3500.0 < t_trig < 5000.0
    srv.predict_and_preload(t_trig)
    assert app in srv.loader.inflight, "prefetch staged in background"
    srv.engine._reap_loads(t_trig + 1000.0)
    assert srv.manager.state.tenants[app].loaded is not None
    # ... and the predicted request warm-starts.
    srv.engine.submit(req(5000.0), 5000.0)
    batch = srv.engine.batcher.next_batch()
    results, _, toks = srv.engine.execute_batch(batch, 5000.0)
    assert toks is not None and results[0].warm
    assert srv.loader.prefetch_hits == 1
    assert srv.loader.load_overlap_ms >= 0.0
    srv.engine.check_event_invariant()
    srv.close()


def test_demand_load_admits_cold_not_warm():
    """A load triggered by an already-queued request is not a prefetch:
    the batch waited out the transfer and must be recorded cold."""
    srv = make_server()
    srv.start()
    srv.engine._executor = stub_executor
    app = TENANTS[1]
    cfg = get_config(app, reduced=True)
    rng = np.random.default_rng(1)
    trace = [Request(app=app, prompt=rng.integers(
        0, cfg.vocab_size, 5).astype(np.int32), max_new=2,
        arrival_ms=t) for t in (10.0, 4000.0)]
    stats = srv.engine.run_trace(trace)
    assert stats.demand_loads == 1
    assert stats.prefetch_hits == 0
    first, second = sorted(srv.engine.results, key=lambda r: r.arrival_ms)
    assert not first.failed and not first.warm, "waited out its own load"
    assert not second.failed and second.warm, "resident by then"
    srv.close()


def _speculation_fixture(pending_mb):
    """a's 500MB prefetch in flight on an 800MB budget; b has a queued
    request whose demand load is unfundable until speculation yields."""
    mgr = make_manager(budget_mb=800.0)
    srv = make_server()  # engine/batcher shell; manager swapped below
    srv.start()
    srv.engine._executor = stub_executor
    loader = BackgroundLoader(mgr)
    srv.loader.close()  # replace the real loader with the synthetic one
    srv.manager = mgr
    srv.engine.loader = srv.loader = loader
    loader.enqueue(mgr.plan_proactive("a", 0.0), 0.0, predicted_ms=600.0)
    assert mgr.state.free_mb == pytest.approx(300.0)
    mgr.state.pending_mb += pending_mb  # leave < b.smallest free
    assert mgr.plan_demand("b", 0.0) is None

    class FakeTenant:
        cfg = get_config(TENANTS[0], reduced=True)
    srv.tenants["b"] = FakeTenant()
    srv.engine.batcher.submit(
        Request(app="b", prompt=np.arange(4, dtype=np.int32),
                max_new=2, arrival_ms=0.0))
    return mgr, srv, loader


def test_speculation_shrinks_before_cancelling_for_demand():
    """A speculative prefetch's in-flight claim must never starve a real
    queued request — but yielding is graduated: the guess is first
    *shrunk* to its smallest variant (keeping a degraded warm start)
    and only cancelled outright when that still cannot fund demand."""
    mgr, srv, loader = _speculation_fixture(pending_mb=250.0)
    srv.engine._stage_demand_loads(0.0)
    # Shrinking a 500 -> 300 freed 200MB: b's 200MB smallest now fits.
    ld = loader.inflight.get("a")
    assert ld is not None, "shrunk, not cancelled"
    assert ld.variant.size_mb == 300.0
    assert ld.charge_mb == 300.0
    assert loader.prefetch_shrunk == 1
    assert loader.prefetch_wasted == 0
    assert "b" in loader.inflight, "demand load funded"
    assert loader.inflight["b"].demand
    mgr.state.pending_mb -= 250.0
    loader.close()
    srv.close()


def test_speculation_cancelled_when_shrink_is_not_enough():
    """When even the shrunk claim starves the demand load, the guess is
    cancelled outright (shrink first, then cancel)."""
    # pending 450: free after shrink = 800 - 300 - 450 = 50 < 200, so
    # only a full cancel (free 350) funds b's smallest.
    mgr, srv, loader = _speculation_fixture(pending_mb=450.0)
    srv.engine._stage_demand_loads(0.0)
    assert "a" not in loader.inflight, "speculative claim cancelled"
    assert "b" in loader.inflight, "demand load funded"
    assert loader.prefetch_shrunk == 1, "shrink was tried first"
    assert loader.prefetch_wasted == 1
    mgr.state.pending_mb -= 450.0
    loader.close()
    srv.close()


def test_shrink_inflight_lifecycle():
    """shrink_inflight releases the claim difference, restages the
    smaller transfer, and the shrunk load commits/cancels normally."""
    mgr = make_manager()
    loader = BackgroundLoader(mgr)
    ld = loader.enqueue(mgr.plan_proactive("a", 0.0), now_ms=0.0,
                        predicted_ms=2000.0)
    assert ld.charge_mb == 500.0
    small = mgr.state.tenants["a"].zoo.smallest  # 300MB
    out = loader.shrink_inflight("a", small, now_ms=100.0)
    assert out is ld
    assert ld.variant is small and ld.charge_mb == 300.0
    assert ld.t_enqueue_ms == 100.0, \
        "overlap window restarts with the smaller transfer"
    assert ld.ready_ms == pytest.approx(100.0 + small.load_ms)
    assert mgr.state.inflight_mb == pytest.approx(300.0)
    assert mgr.state.free_mb == pytest.approx(700.0)
    # Idempotence/guards: same-or-larger target and demand loads refuse.
    assert loader.shrink_inflight("a", small, 150.0) is None
    assert loader.shrink_inflight("a", None, 150.0) is None
    recs = loader.reap(ld.ready_ms)
    assert [r.bits for r in recs] == [small.bits]
    assert mgr.state.tenants["a"].loaded is small
    assert mgr.state.inflight_mb == 0.0
    assert loader.prefetch_shrunk == 1
    loader.close()


def test_shrink_inflight_refuses_demand_loads():
    mgr = make_manager()
    loader = BackgroundLoader(mgr)
    loader.enqueue(mgr.plan_demand("a", 0.0), 0.0, demand=True)
    small = mgr.state.tenants["a"].zoo.smallest
    assert loader.shrink_inflight("a", small, 10.0) is None, \
        "a demand load's variant was planned against a waiting batch"
    assert loader.prefetch_shrunk == 0
    loader.close()


def test_event_invariant_holds_with_loads_in_flight():
    """The per-event budget invariant (used + in-flight ≤ budget) holds
    through a contended prefetching run, admits balance retires, and no
    KV or in-flight charge leaks."""
    srv = make_server(max_batch=4)
    srv.budget_mb = srv.contention_budget(0.05)
    srv.start()
    srv.engine._executor = stub_executor
    cfgs = {n: get_config(n, reduced=True) for n in TENANTS}
    trace, _ = poisson_trace(cfgs, requests_per_app=15,
                             mean_iat_ms=300.0, seed=3)
    stats = srv.engine.run_trace(trace)
    assert stats.requests == len(trace)
    srv.engine.check_event_invariant()
    kinds = [e.kind for e in srv.engine.events]
    assert kinds.count("admit") == kinds.count("retire")
    assert "prefetch" in kinds or stats.demand_loads > 0
    assert srv.manager.state.kv_mb == 0.0
    assert srv.manager.state.inflight_mb == 0.0, "no stranded claims"
    srv.close()


# ---------------------------------------------------------------------------
# Predictor normalizer fix
# ---------------------------------------------------------------------------
def test_predict_normalizes_by_trailing_context_not_stale_mean():
    """After fit() the history keeps growing; a drifted series must be
    normalized by the trailing context, not the fit-time mean."""
    p = SeriesPredictor(context=8, hidden=16, seed=0)
    for _ in range(40):
        p.observe(100.0)
    loss = p.fit(steps=300)
    assert loss < 0.05
    assert p.predict() == pytest.approx(100.0, rel=0.25)
    # The series shifts scale by 10x after the last fit.
    for _ in range(20):
        p.observe(1000.0)
    assert p.mean == pytest.approx(100.0), "fit-time mean is stale"
    pred = p.predict()
    assert pred == pytest.approx(1000.0, rel=0.35), \
        f"stale normalizer would predict ~100, got {pred}"


def test_predict_untrained_falls_back_to_trailing_mean():
    p = SeriesPredictor(context=4, hidden=8, seed=0)
    for v in (10.0, 20.0, 30.0, 40.0, 50.0, 60.0):
        p.observe(v)
    assert p.losses is None
    assert p.predict() == pytest.approx(np.mean([30.0, 40.0, 50.0, 60.0]))
