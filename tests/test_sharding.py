"""Sharding-rule correctness (pure spec generation — no devices needed)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_NAMES, get_config
from repro.models import transformer as T
from repro.models.config import SHAPE_SPECS


class FakeMesh:
    """Duck-typed mesh: shape mapping + axis names (specs are pure)."""

    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)

    @property
    def size(self):
        n = 1
        for v in self.shape.values():
            n *= v
        return n


MESH = FakeMesh({"data": 16, "model": 16})
MESH_MP = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _axes_of(spec):
    out = []
    for e in spec:
        if e is None:
            continue
        out.extend(e if isinstance(e, tuple) else (e,))
    return out


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_param_specs_divisible(arch):
    """Every sharded ARGUMENT dim must divide exactly (pjit requirement);
    and no mesh axis may appear twice in one spec."""
    from repro.distributed import sharding as SH

    cfg = get_config(arch)
    pa = T.abstract_params(cfg, jnp.bfloat16)
    specs = SH.param_specs(cfg, pa, MESH)

    def check(leaf, spec):
        axes = _axes_of(spec)
        assert len(axes) == len(set(axes)), f"dup axes in {spec}"
        for dim, entry in zip(leaf.shape, spec):
            if entry is None:
                continue
            n = 1
            for a in (entry if isinstance(entry, tuple) else (entry,)):
                n *= MESH.shape[a]
            assert dim % n == 0, (arch, leaf.shape, spec)

    jax.tree.map(check, pa, specs, is_leaf=lambda x: isinstance(x, P))


@pytest.mark.parametrize("arch", ARCH_NAMES)
@pytest.mark.parametrize("shape_name", list(SHAPE_SPECS))
def test_cache_and_batch_specs_divisible(arch, shape_name):
    from repro.distributed import sharding as SH
    from repro.launch.specs import batch_specs_for, decode_specs_for
    from repro.models.config import cell_is_runnable

    if not cell_is_runnable(arch, shape_name):
        pytest.skip("long-context cell skipped for full-attention arch")
    cfg = get_config(arch)
    kind = SHAPE_SPECS[shape_name][2]
    if kind == "decode":
        _, cache = decode_specs_for(cfg, shape_name)
        specs = SH.cache_specs(cfg, cache, MESH)
        tree, spec_tree = cache, specs
    else:
        batch = batch_specs_for(cfg, shape_name, with_labels=kind == "train")
        spec_tree = SH.batch_specs(cfg, batch, MESH)
        tree = batch

    def check(leaf, spec):
        for dim, entry in zip(leaf.shape, spec):
            if entry is None:
                continue
            n = 1
            for a in (entry if isinstance(entry, tuple) else (entry,)):
                n *= MESH.shape[a]
            assert dim % n == 0, (arch, shape_name, leaf.shape, spec)

    jax.tree.map(check, tree, spec_tree,
                 is_leaf=lambda x: isinstance(x, P))


def test_zero1_augments_master_only_free_dims():
    from repro.distributed import sharding as SH
    from repro.training.optim import AdamW
    from repro.training.train_step import abstract_state

    cfg = get_config("yi-6b")
    opt = AdamW(lr=1e-4)
    sa = abstract_state(cfg, opt, dtype=jnp.float32)
    ps = SH.param_specs(cfg, sa.params, MESH)
    ss = SH.state_specs(cfg, sa, MESH, ps, zero1=True)
    wq_spec = ss.params["layers"]["wq"]
    axes = _axes_of(wq_spec)
    assert "data" in axes and "model" in axes
    assert len(axes) == len(set(axes))
    # moments mirror the master
    assert ss.opt.mu["layers"]["wq"] == wq_spec


def test_expert_weights_sharded_on_expert_dim():
    from repro.distributed import sharding as SH

    for arch in ("llama4-scout-17b-a16e", "olmoe-1b-7b"):
        cfg = get_config(arch)
        pa = T.abstract_params(cfg, jnp.bfloat16)
        specs = SH.param_specs(cfg, pa, MESH)
        we_g = specs["layers"]["we_g"]
        assert we_g[1] == "model", (arch, we_g)  # EP over experts


def test_long_context_cache_seq_sharded():
    """long_500k (batch=1) must shard the KV sequence dim."""
    from repro.distributed import sharding as SH
    from repro.launch.specs import decode_specs_for

    cfg = get_config("gemma2-2b")
    _, cache = decode_specs_for(cfg, "long_500k")
    specs = SH.cache_specs(cfg, cache, MESH)
    k_spec = specs["k"]
    assert k_spec[2] is not None, "seq dim must be sharded for batch=1"
