"""End-to-end multi-tenant serving: real models under a memory budget."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.predictor import RequestPredictor
from repro.models import transformer as T
from repro.serving import Batcher, EdgeServer, Request, kv_cache_mb

TENANTS = ["tinyllama-1.1b", "mamba2-780m", "gemma2-2b"]


@pytest.fixture(scope="module")
def server():
    srv = EdgeServer(budget_mb=1e9, policy="iws-bfe", delta_ms=1000.0)
    for name in TENANTS:
        cfg = get_config(name, reduced=True)
        params = T.init_params(
            cfg, jax.random.key(hash(name) % 2 ** 31), jnp.float32)
        srv.register(name, cfg, params)
    # Feasible contention, with headroom for the largest decode cache
    # these tests admit (batch 2, total length 10).
    kv = max(kv_cache_mb(get_config(n, reduced=True), 2, 10)
             for n in TENANTS)
    srv.budget_mb = srv.contention_budget(kv)
    srv.start()
    return srv


def test_zoo_sizes_real(server):
    for name in TENANTS:
        zoo = server.tenants[name].zoo
        assert zoo.largest.bits == 16
        assert zoo.smallest.size_mb < zoo.largest.size_mb * 0.85


def test_budget_contention(server):
    total16 = sum(t.zoo.largest.size_mb for t in server.tenants.values())
    assert total16 > server.budget_mb, "budget must force contention"


def test_serve_empty_prompts_no_crash(server):
    r = server.serve(TENANTS[0], np.zeros((0, 4), np.int32), max_new=2,
                     now_ms=0.0)
    assert not r.failed
    assert r.tokens.shape == (0, 2)


def test_serve_generates_tokens(server):
    cfg = get_config(TENANTS[0], reduced=True)
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, size=(2, 6)).astype(np.int32)
    r = server.serve(TENANTS[0], prompts, max_new=4, now_ms=0.0)
    assert not r.failed
    assert r.tokens.shape == (2, 4)
    assert np.all(r.tokens < cfg.vocab_size)


def test_rotation_under_contention(server):
    """All tenants get served despite the budget fitting ~1 bf16 model."""
    rng = np.random.default_rng(1)
    now = 10_000.0
    for i in range(9):
        name = TENANTS[i % 3]
        cfg = get_config(name, reduced=True)
        prompts = rng.integers(0, cfg.vocab_size, size=(1, 5)).astype(np.int32)
        r = server.serve(name, prompts, max_new=2, now_ms=now)
        assert not r.failed, (name, server.manager.state.used_mb)
        now += 5000.0  # beyond the LRU history window
    stats = server.stats()
    assert stats.resident_mb <= server.budget_mb
    assert stats.fail_ratio == 0.0


def test_manager_accounting_matches_devices(server):
    """Manager's notion of residency agrees with actual device params."""
    st = server.manager.state
    for name, t in server.tenants.items():
        if st.tenants[name].loaded is None:
            assert t.device_params is None
        else:
            assert t.device_params is not None
            assert t.loaded_bits == st.tenants[name].loaded.bits


def test_batcher_right_aligned_padding():
    b = Batcher(max_batch=4, pad_id=0)
    lens = [2, 5, 3]
    for i, n in enumerate(lens):
        b.submit(Request(app="x",
                         prompt=(10 * (i + 1)
                                 + np.arange(n)).astype(np.int32)))
    batch = b.next_batch()
    assert batch.prompts.shape == (3, 5)
    for i, n in enumerate(lens):
        row = batch.prompts[i]
        assert np.all(row[: 5 - n] == 0), "left side must be padding"
        expect = (10 * (i + 1) + np.arange(n)).astype(np.int32)
        assert np.array_equal(row[5 - n:], expect), "prompt right-aligned"


def test_batcher_fifo_within_tenant():
    b = Batcher(max_batch=8)
    reqs = [Request(app="x", prompt=np.arange(3, dtype=np.int32))
            for _ in range(5)]
    for r in reqs:
        b.submit(r)
    batch = b.next_batch()
    assert [r.rid for r in batch.requests] == [r.rid for r in reqs]


def test_batcher_max_batch_splitting():
    b = Batcher(max_batch=3)
    for _ in range(7):
        b.submit(Request(app="x", prompt=np.arange(4, dtype=np.int32)))
    sizes = []
    while (batch := b.next_batch()) is not None:
        sizes.append(len(batch.requests))
    assert sizes == [3, 3, 1]


def test_batcher_largest_queue_first():
    b = Batcher(max_batch=8)
    for app, n in (("small", 2), ("big", 5), ("mid", 3)):
        for _ in range(n):
            b.submit(Request(app=app, prompt=np.arange(3, dtype=np.int32)))
    assert b.next_batch().app == "big"
    assert b.next_batch().app == "mid"
    assert b.next_batch().app == "small"
    assert b.next_batch() is None
    assert b.pending() == 0


def test_batcher_tie_break_oldest_head():
    b = Batcher(max_batch=8)
    b.submit(Request(app="late", prompt=np.arange(3, dtype=np.int32),
                     arrival_ms=200.0))
    b.submit(Request(app="early", prompt=np.arange(3, dtype=np.int32),
                     arrival_ms=100.0))
    # equal queue depth: the tenant whose head waited longest goes first
    assert b.next_batch().app == "early"
    assert b.next_batch().app == "late"


def test_batcher_groups_and_pads():
    b = Batcher(max_batch=3)
    for i in range(5):
        b.submit(Request(app="x", prompt=np.arange(3 + i, dtype=np.int32)))
    batch = b.next_batch()
    assert batch.app == "x"
    assert len(batch.requests) == 3
    assert batch.prompts.shape == (3, 5)  # padded to longest
    # right-aligned: last token of each row is the prompt's last token
    assert batch.prompts[0, -1] == 2
    rest = b.next_batch()
    assert len(rest.requests) == 2
    assert b.next_batch() is None


def test_rnn_predictor_learns_pattern():
    rng = np.random.default_rng(0)
    p = RequestPredictor(context=8, hidden=16, seed=0)
    t = 0.0
    for i in range(160):
        gap = 100.0 if i % 2 == 0 else 300.0
        t += gap + rng.normal(0, 5.0)
        p.observe_request(t)
    loss = p.fit(steps=250)
    assert loss < 0.05
    pred_gap = p.predict()
    assert abs(pred_gap - 100.0) < 60.0  # next gap in the pattern
