"""Unit + property tests for the four eviction policies (paper §III-B).

The property section uses ``hypothesis`` when available; without it the
same invariant checkers run over seeded-numpy random states so the module
always collects and the invariants stay guarded.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # degrade to the seeded-numpy fallback below
    HAVE_HYPOTHESIS = False

from repro.core.memory_state import INF, MemoryState, TenantState
from repro.core.model_zoo import ModelVariant, ModelZoo
from repro.core.policies import kv_headroom_plan, resolve_policy

# The four paper policies (§III-B), resolved through the registry.  Local
# function aliases keep the test bodies reading like the paper's
# pseudocode while exercising the class-based Policy protocol.
PAPER_POLICIES = ("lfe", "bfe", "ws-bfe", "iws-bfe")


def _procure(name):
    def call(state, app, now, *, delta, history=0.0):
        return resolve_policy(name).plan_procure(
            state, app, now, delta=delta, history=history)
    return call


lfe = _procure("lfe")
bfe = _procure("bfe")
ws_bfe = _procure("ws-bfe")
iws_bfe = _procure("iws-bfe")


def zoo(name, sizes, accs=None):
    accs = accs or [90 - 10 * i for i in range(len(sizes))]
    return ModelZoo(
        app_name=name,
        variants=tuple(
            ModelVariant(f"{name}-{i}", bits=32 >> i, size_mb=s,
                         accuracy=a, load_ms=s * 2)
            for i, (s, a) in enumerate(zip(sizes, accs))))


def make_state(budget=1000.0):
    """Three tenants: a (500/300/100), b (400/200/50), c (300/100/30)."""
    st_ = MemoryState(budget_mb=budget, tenants={
        "a": TenantState(zoo=zoo("a", [500, 300, 100])),
        "b": TenantState(zoo=zoo("b", [400, 200, 50])),
        "c": TenantState(zoo=zoo("c", [300, 100, 30])),
    })
    return st_


def apply_plan(state, plan):
    for ev in plan.evictions:
        state.load(ev.app, ev.new)
    state.load(plan.app, plan.variant)


class TestLFE:
    def test_loads_largest_when_memory_free(self):
        s = make_state()
        plan = lfe(s, "a", now=0.0, delta=10.0)
        assert plan.ok and plan.variant.size_mb == 500
        assert plan.evictions == ()

    def test_evicts_largest_first(self):
        s = make_state(budget=900.0)
        s.load("b", s.tenants["b"].zoo.largest)  # 400
        s.load("c", s.tenants["c"].zoo.largest)  # 300
        # b and c are minimalist (no predictions); a requests 500.
        plan = lfe(s, "a", now=0.0, delta=10.0)
        assert plan.ok and plan.variant.size_mb == 500
        assert plan.evictions[0].app == "b"  # largest loaded model first
        assert all(e.new is None for e in plan.evictions)  # full unloads

    def test_downgrades_requester_when_eviction_insufficient(self):
        s = make_state(budget=220.0)
        # nothing loaded; 500 and 300 don't fit; 100 does
        plan = lfe(s, "a", now=0.0, delta=10.0)
        assert plan.ok and plan.variant.size_mb == 100

    def test_fails_when_nothing_fits(self):
        s = make_state(budget=20.0)
        plan = lfe(s, "a", now=0.0, delta=10.0)
        assert not plan.ok


class TestBFE:
    def test_best_fit_prefers_smallest_covering(self):
        s = make_state(budget=1000.0)
        s.load("b", s.tenants["b"].zoo.largest)  # 400
        s.load("c", s.tenants["c"].zoo.largest)  # 300
        # free = 300; a wants 500 -> needs 200 more; c(300) covers with
        # less waste than b(400)
        plan = bfe(s, "a", now=0.0, delta=10.0)
        assert plan.ok
        assert plan.evictions[0].app == "c"


class TestWSBFE:
    def test_downgrade_not_unload(self):
        s = make_state(budget=800.0)
        s.load("b", s.tenants["b"].zoo.largest)  # 400
        s.load("c", s.tenants["c"].zoo.largest)  # 300
        plan = ws_bfe(s, "a", now=0.0, delta=10.0)
        assert plan.ok
        for ev in plan.evictions:
            assert ev.new is not None
            assert ev.new is s.tenants[ev.app].zoo.smallest

    def test_skips_overlapping_windows(self):
        s = make_state(budget=800.0)
        s.load("b", s.tenants["b"].zoo.largest)
        s.load("c", s.tenants["c"].zoo.largest)
        # b's predicted window overlaps the requester's current time
        s.tenants["b"].predicted_next = 5.0
        s.tenants["a"].predicted_next = 5.0
        plan = ws_bfe(s, "a", now=0.0, delta=100.0)
        assert all(ev.app != "b" for ev in plan.evictions)


class TestIWSBFE:
    def test_history_filter(self):
        s = make_state(budget=800.0)
        s.load("b", s.tenants["b"].zoo.largest)
        s.load("c", s.tenants["c"].zoo.largest)
        s.tenants["b"].last_request = -1.0  # requested just now
        plan = iws_bfe(s, "a", now=0.0, delta=10.0, history=100.0)
        assert all(ev.app != "b" for ev in plan.evictions)

    def test_prefers_far_future_victims(self):
        s = make_state(budget=730.0)
        s.load("b", s.tenants["b"].zoo.by_bits(16))  # 200
        s.load("c", s.tenants["c"].zoo.by_bits(16))  # 100
        s.tenants["b"].predicted_next = 10_000.0  # far future
        s.tenants["c"].predicted_next = INF
        s.tenants["b"].last_request = -10_000.0
        s.tenants["c"].last_request = -10_000.0
        # free = 430; a wants 500: scavenging either victim's downgrade
        # suffices (b frees 150, c frees 70 -> only b's suffices); the
        # heap should try the highest-score (c: no prediction => norm 1)
        # first but keep popping until covered.
        plan = iws_bfe(s, "a", now=0.0, delta=10.0, history=100.0)
        assert plan.ok and plan.variant.size_mb == 500

    def test_algorithm1_failure_path(self):
        s = make_state(budget=25.0)
        plan = iws_bfe(s, "a", now=0.0, delta=10.0, history=100.0)
        assert not plan.ok  # Step 17: request fails


class TestKVHeadroom:
    def test_scavenges_victims_not_requester(self):
        s = make_state(budget=800.0)
        s.load("a", s.tenants["a"].zoo.smallest)  # 100
        s.load("b", s.tenants["b"].zoo.largest)   # 400
        s.load("c", s.tenants["c"].zoo.largest)   # 300
        # free = 0; a needs 200MB of KV headroom
        evs = kv_headroom_plan(s, "a", now=0.0, need_mb=200.0, delta=10.0)
        assert evs, "must scavenge"
        assert all(ev.app != "a" for ev in evs)
        assert all(ev.new is s.tenants[ev.app].zoo.smallest for ev in evs)
        assert s.free_mb + sum(ev.freed_mb for ev in evs) >= 200.0

    def test_best_fit_prefers_smallest_sufficient(self):
        s = make_state(budget=700.0)
        s.load("b", s.tenants["b"].zoo.largest)  # 400, scavenge 350
        s.load("c", s.tenants["c"].zoo.largest)  # 300, scavenge 270
        # free = 0; need 100 — c's 270 covers with less waste than b's 350
        evs = kv_headroom_plan(s, "a", now=0.0, need_mb=100.0, delta=10.0)
        assert [ev.app for ev in evs] == ["c"]

    def test_may_be_insufficient(self):
        s = make_state(budget=430.0)
        s.load("b", s.tenants["b"].zoo.largest)  # 400
        evs = kv_headroom_plan(s, "a", now=0.0, need_mb=1000.0, delta=10.0)
        # caller re-checks free_mb: all scavengeable freed, still short
        assert s.free_mb + sum(ev.freed_mb for ev in evs) < 1000.0

    def test_respects_window_and_history_filters(self):
        s = make_state(budget=800.0)
        s.load("b", s.tenants["b"].zoo.largest)
        s.load("c", s.tenants["c"].zoo.largest)
        s.tenants["b"].predicted_next = 5.0
        s.tenants["a"].predicted_next = 5.0  # b overlaps the requester
        s.tenants["c"].last_request = -1.0   # c requested just now
        evs = kv_headroom_plan(s, "a", now=0.0, need_mb=500.0,
                               delta=100.0, history=50.0)
        assert evs == ()

    def test_kv_charge_shrinks_policy_view(self):
        """Policies see free memory net of live KV caches."""
        s = make_state(budget=600.0)
        plan = lfe(s, "a", now=0.0, delta=10.0)
        assert plan.ok and plan.variant.size_mb == 500
        s.reserve_kv("b", 250.0)
        plan = lfe(s, "a", now=0.0, delta=10.0)
        assert plan.ok and plan.variant.size_mb == 300  # 500 no longer fits


# ---------------------------------------------------------------------------
# Random-state invariants: hypothesis properties when available, seeded
# numpy fallback otherwise (same checkers either way).
# ---------------------------------------------------------------------------
def _repair_overcommit(s: MemoryState) -> MemoryState:
    """Repair overcommitted starting states (simulate prior valid history)."""
    while s.used_mb > s.budget_mb:
        loaded = [a for a, t in s.tenants.items() if t.loaded is not None]
        if loaded:
            s.tenants[loaded[0]].loaded = None
        else:
            for t in s.tenants.values():
                t.kv_mb = 0.0
    return s


def _check_policy_invariants(state, policy_name, now, delta, history):
    app = sorted(state.tenants)[0]
    plan = resolve_policy(policy_name).plan_procure(
        state, app, now, delta=delta, history=history)
    if not plan.ok:
        return
    minimalist = set(state.minimalist_set(now, delta))
    for ev in plan.evictions:
        assert ev.app != app, "policy evicted the requester"
        assert ev.app in minimalist, "evicted a maximalist tenant"
        assert state.tenants[ev.app].loaded is not None
        if policy_name == "iws-bfe":
            assert ev.new is state.tenants[ev.app].zoo.smallest
            assert state.tenants[ev.app].last_request <= now - history
    # Enacting the plan must respect the memory budget (the invariant).
    apply_plan(state, plan)  # raises AssertionError on violation
    assert state.loaded_variant(app) is plan.variant


def _check_iws_maximality(state, now, delta):
    """If iWS-BFE picks a non-largest variant, the largest must not fit
    even after downgrading every eligible candidate."""
    from repro.core.policies import _downgrade_candidates, _free_after, \
        Eviction

    app = sorted(state.tenants)[0]
    plan = iws_bfe(state, app, now, delta=delta, history=100.0)
    if not plan.ok:
        return
    largest = state.tenants[app].zoo.largest
    if plan.variant is largest:
        return
    cands = _downgrade_candidates(state, app, now, delta,
                                  require_history=100.0)
    evs = [Eviction(a, state.tenants[a].loaded,
                    state.tenants[a].zoo.smallest) for a in cands]
    assert _free_after(state, app, evs) < largest.size_mb


def _random_state_np(rng: np.random.Generator) -> MemoryState:
    """Seeded-numpy mirror of the hypothesis ``random_state`` strategy."""
    n_apps = int(rng.integers(2, 7))
    budget = float(rng.uniform(50, 3000))
    tenants = {}
    for i in range(n_apps):
        n_var = int(rng.integers(1, 5))
        sizes = sorted(rng.uniform(1, 600, n_var), reverse=True)
        sizes = [float(s) + (n_var - j) for j, s in enumerate(sizes)]
        t = TenantState(zoo=zoo(f"app{i}", sizes))
        if rng.random() < 0.5:
            t.predicted_next = float(rng.uniform(0, 1000))
        if rng.random() < 0.5:
            t.loaded = t.zoo.variants[int(rng.integers(0, n_var))]
        if rng.random() < 0.3:
            t.kv_mb = float(rng.uniform(0, 100))
        t.last_request = float(rng.uniform(-1000, 0))
        t.requests = int(rng.integers(0, 51))
        t.unexpected = int(rng.integers(0, t.requests + 1))
        tenants[f"app{i}"] = t
    return _repair_overcommit(MemoryState(budget_mb=budget, tenants=tenants))


if HAVE_HYPOTHESIS:
    @st.composite
    def random_state(draw):
        n_apps = draw(st.integers(2, 6))
        budget = draw(st.floats(50, 3000))
        tenants = {}
        for i in range(n_apps):
            n_var = draw(st.integers(1, 4))
            sizes = sorted(
                draw(st.lists(st.floats(1, 600), min_size=n_var,
                              max_size=n_var)), reverse=True)
            # strictly decreasing to keep variants distinct
            sizes = [s + (n_var - j) for j, s in enumerate(sizes)]
            t = TenantState(zoo=zoo(f"app{i}", sizes))
            if draw(st.booleans()):
                t.predicted_next = draw(st.floats(0, 1000))
            if draw(st.booleans()):
                idx = draw(st.integers(0, n_var - 1))
                t.loaded = t.zoo.variants[idx]
            if draw(st.booleans()):
                t.kv_mb = draw(st.floats(0, 100))
            t.last_request = draw(st.floats(-1000, 0))
            t.requests = draw(st.integers(0, 50))
            t.unexpected = draw(st.integers(0, t.requests))
            tenants[f"app{i}"] = t
        return _repair_overcommit(
            MemoryState(budget_mb=budget, tenants=tenants))

    @settings(max_examples=200, deadline=None)
    @given(random_state(), st.sampled_from(PAPER_POLICIES),
           st.floats(0, 500), st.floats(1, 200), st.floats(1, 500))
    def test_policy_invariants(state, policy_name, now, delta, history):
        _check_policy_invariants(state, policy_name, now, delta, history)

    @settings(max_examples=100, deadline=None)
    @given(random_state(), st.floats(0, 500), st.floats(1, 200))
    def test_iws_maximality(state, now, delta):
        _check_iws_maximality(state, now, delta)


@pytest.mark.parametrize("seed", range(80))
def test_policy_invariants_seeded(seed):
    rng = np.random.default_rng(seed)
    state = _random_state_np(rng)
    policy_name = PAPER_POLICIES[int(rng.integers(0, len(PAPER_POLICIES)))]
    _check_policy_invariants(
        state, policy_name, now=float(rng.uniform(0, 500)),
        delta=float(rng.uniform(1, 200)),
        history=float(rng.uniform(1, 500)))


@pytest.mark.parametrize("seed", range(40))
def test_iws_maximality_seeded(seed):
    rng = np.random.default_rng(1000 + seed)
    state = _random_state_np(rng)
    _check_iws_maximality(state, now=float(rng.uniform(0, 500)),
                          delta=float(rng.uniform(1, 200)))
