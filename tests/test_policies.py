"""Unit + property tests for the four eviction policies (paper §III-B)."""
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.memory_state import INF, MemoryState, TenantState
from repro.core.model_zoo import ModelVariant, ModelZoo
from repro.core.policies import POLICIES, bfe, iws_bfe, lfe, ws_bfe


def zoo(name, sizes, accs=None):
    accs = accs or [90 - 10 * i for i in range(len(sizes))]
    return ModelZoo(
        app_name=name,
        variants=tuple(
            ModelVariant(f"{name}-{i}", bits=32 >> i, size_mb=s,
                         accuracy=a, load_ms=s * 2)
            for i, (s, a) in enumerate(zip(sizes, accs))))


def make_state(budget=1000.0):
    """Three tenants: a (500/300/100), b (400/200/50), c (300/100/30)."""
    st_ = MemoryState(budget_mb=budget, tenants={
        "a": TenantState(zoo=zoo("a", [500, 300, 100])),
        "b": TenantState(zoo=zoo("b", [400, 200, 50])),
        "c": TenantState(zoo=zoo("c", [300, 100, 30])),
    })
    return st_


def apply_plan(state, plan):
    for ev in plan.evictions:
        state.load(ev.app, ev.new)
    state.load(plan.app, plan.variant)


class TestLFE:
    def test_loads_largest_when_memory_free(self):
        s = make_state()
        plan = lfe(s, "a", now=0.0, delta=10.0)
        assert plan.ok and plan.variant.size_mb == 500
        assert plan.evictions == ()

    def test_evicts_largest_first(self):
        s = make_state(budget=900.0)
        s.load("b", s.tenants["b"].zoo.largest)  # 400
        s.load("c", s.tenants["c"].zoo.largest)  # 300
        # b and c are minimalist (no predictions); a requests 500.
        plan = lfe(s, "a", now=0.0, delta=10.0)
        assert plan.ok and plan.variant.size_mb == 500
        assert plan.evictions[0].app == "b"  # largest loaded model first
        assert all(e.new is None for e in plan.evictions)  # full unloads

    def test_downgrades_requester_when_eviction_insufficient(self):
        s = make_state(budget=220.0)
        # nothing loaded; 500 and 300 don't fit; 100 does
        plan = lfe(s, "a", now=0.0, delta=10.0)
        assert plan.ok and plan.variant.size_mb == 100

    def test_fails_when_nothing_fits(self):
        s = make_state(budget=20.0)
        plan = lfe(s, "a", now=0.0, delta=10.0)
        assert not plan.ok


class TestBFE:
    def test_best_fit_prefers_smallest_covering(self):
        s = make_state(budget=1000.0)
        s.load("b", s.tenants["b"].zoo.largest)  # 400
        s.load("c", s.tenants["c"].zoo.largest)  # 300
        # free = 300; a wants 500 -> needs 200 more; c(300) covers with
        # less waste than b(400)
        plan = bfe(s, "a", now=0.0, delta=10.0)
        assert plan.ok
        assert plan.evictions[0].app == "c"


class TestWSBFE:
    def test_downgrade_not_unload(self):
        s = make_state(budget=800.0)
        s.load("b", s.tenants["b"].zoo.largest)  # 400
        s.load("c", s.tenants["c"].zoo.largest)  # 300
        plan = ws_bfe(s, "a", now=0.0, delta=10.0)
        assert plan.ok
        for ev in plan.evictions:
            assert ev.new is not None
            assert ev.new is s.tenants[ev.app].zoo.smallest

    def test_skips_overlapping_windows(self):
        s = make_state(budget=800.0)
        s.load("b", s.tenants["b"].zoo.largest)
        s.load("c", s.tenants["c"].zoo.largest)
        # b's predicted window overlaps the requester's current time
        s.tenants["b"].predicted_next = 5.0
        s.tenants["a"].predicted_next = 5.0
        plan = ws_bfe(s, "a", now=0.0, delta=100.0)
        assert all(ev.app != "b" for ev in plan.evictions)


class TestIWSBFE:
    def test_history_filter(self):
        s = make_state(budget=800.0)
        s.load("b", s.tenants["b"].zoo.largest)
        s.load("c", s.tenants["c"].zoo.largest)
        s.tenants["b"].last_request = -1.0  # requested just now
        plan = iws_bfe(s, "a", now=0.0, delta=10.0, history=100.0)
        assert all(ev.app != "b" for ev in plan.evictions)

    def test_prefers_far_future_victims(self):
        s = make_state(budget=730.0)
        s.load("b", s.tenants["b"].zoo.by_bits(16))  # 200
        s.load("c", s.tenants["c"].zoo.by_bits(16))  # 100
        s.tenants["b"].predicted_next = 10_000.0  # far future
        s.tenants["c"].predicted_next = INF
        s.tenants["b"].last_request = -10_000.0
        s.tenants["c"].last_request = -10_000.0
        # free = 430; a wants 500: scavenging either victim's downgrade
        # suffices (b frees 150, c frees 70 -> only b's suffices); the
        # heap should try the highest-score (c: no prediction => norm 1)
        # first but keep popping until covered.
        plan = iws_bfe(s, "a", now=0.0, delta=10.0, history=100.0)
        assert plan.ok and plan.variant.size_mb == 500

    def test_algorithm1_failure_path(self):
        s = make_state(budget=25.0)
        plan = iws_bfe(s, "a", now=0.0, delta=10.0, history=100.0)
        assert not plan.ok  # Step 17: request fails


# ---------------------------------------------------------------------------
# Property-based invariants (hypothesis)
# ---------------------------------------------------------------------------
@st.composite
def random_state(draw):
    n_apps = draw(st.integers(2, 6))
    budget = draw(st.floats(50, 3000))
    tenants = {}
    for i in range(n_apps):
        n_var = draw(st.integers(1, 4))
        sizes = sorted(
            draw(st.lists(st.floats(1, 600), min_size=n_var,
                          max_size=n_var)), reverse=True)
        # strictly decreasing to keep variants distinct
        sizes = [s + (n_var - j) for j, s in enumerate(sizes)]
        t = TenantState(zoo=zoo(f"app{i}", sizes))
        if draw(st.booleans()):
            t.predicted_next = draw(st.floats(0, 1000))
        if draw(st.booleans()):
            idx = draw(st.integers(0, n_var - 1))
            t.loaded = t.zoo.variants[idx]
        t.last_request = draw(st.floats(-1000, 0))
        t.requests = draw(st.integers(0, 50))
        t.unexpected = draw(st.integers(0, t.requests))
        tenants[f"app{i}"] = t
    s = MemoryState(budget_mb=budget, tenants=tenants)
    # Repair overcommitted starting states (simulate prior valid history).
    while s.used_mb > s.budget_mb:
        loaded = [a for a, t in tenants.items() if t.loaded is not None]
        s.tenants[loaded[0]].loaded = None
    return s


@settings(max_examples=200, deadline=None)
@given(random_state(), st.sampled_from(list(POLICIES)),
       st.floats(0, 500), st.floats(1, 200), st.floats(1, 500))
def test_policy_invariants(state, policy_name, now, delta, history):
    app = sorted(state.tenants)[0]
    fn = POLICIES[policy_name]
    plan = fn(state, app, now, delta=delta, history=history)
    if not plan.ok:
        return
    minimalist = set(state.minimalist_set(now, delta))
    for ev in plan.evictions:
        assert ev.app != app, "policy evicted the requester"
        assert ev.app in minimalist, "evicted a maximalist tenant"
        assert state.tenants[ev.app].loaded is not None
        if policy_name == "iws-bfe":
            assert ev.new is state.tenants[ev.app].zoo.smallest
            assert state.tenants[ev.app].last_request <= now - history
    # Enacting the plan must respect the memory budget (the invariant).
    apply_plan(state, plan)  # raises AssertionError on violation
    assert state.loaded_variant(app) is plan.variant


@settings(max_examples=100, deadline=None)
@given(random_state(), st.floats(0, 500), st.floats(1, 200))
def test_iws_maximality(state, now, delta):
    """If iWS-BFE picks a non-largest variant, the largest must not fit
    even after downgrading every eligible candidate."""
    from repro.core.policies import _downgrade_candidates, _free_after, \
        Eviction

    app = sorted(state.tenants)[0]
    plan = iws_bfe(state, app, now, delta=delta, history=100.0)
    if not plan.ok:
        return
    largest = state.tenants[app].zoo.largest
    if plan.variant is largest:
        return
    cands = _downgrade_candidates(state, app, now, delta,
                                  require_history=100.0)
    evs = [Eviction(a, state.tenants[a].loaded,
                    state.tenants[a].zoo.smallest) for a in cands]
    assert _free_after(state, app, evs) < largest.size_mb
