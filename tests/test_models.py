"""Per-architecture smoke tests (reduced configs) + model-level invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.models import transformer as T
from repro.training.optim import AdamW
from repro.training.train_step import init_state, make_train_step

KEY = jax.random.key(0)


def make_batch(cfg, B=2, S=16, key=KEY, labels=True):
    shape = (B, S) if cfg.num_codebooks == 1 else (B, S, cfg.num_codebooks)
    tokens = jax.random.randint(key, shape, 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    if labels:
        batch["labels"] = jnp.roll(tokens, -1, axis=1)
    if cfg.frontend == "vision_stub":
        batch["patch_embeds"] = jax.random.normal(
            key, (B, cfg.num_vision_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_forward_and_train_step(arch):
    """One forward + one real train step on CPU: shapes + finiteness."""
    cfg = get_config(arch, reduced=True)
    batch = make_batch(cfg)
    opt = AdamW(lr=1e-3)
    state = init_state(cfg, KEY, opt, dtype=jnp.float32)
    logits = T.forward(cfg, state.params, batch)
    B, S = batch["tokens"].shape[:2]
    S_total = S + cfg.num_meta_tokens + (
        cfg.num_vision_tokens if cfg.frontend == "vision_stub" else 0)
    assert logits.shape == (B, S_total, cfg.num_codebooks, cfg.padded_vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    step = make_train_step(cfg, opt, remat=True, compute_dtype=None)
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    # parameters actually changed
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.abs(a - b).sum()),
                     state.params, new_state.params))
    assert delta > 0.0


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_prefill_decode(arch):
    cfg = get_config(arch, reduced=True)
    batch = make_batch(cfg, labels=False)
    params = T.init_params(cfg, KEY, jnp.float32)
    S = batch["tokens"].shape[1]
    logits, cache = T.prefill(cfg, params, batch, max_len=S + 4)
    tok = T.greedy_token(cfg, logits)
    for _ in range(3):
        logits, cache = T.decode_step(cfg, params, cache, tok)
        tok = T.greedy_token(cfg, logits)
        assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    expected_extra = cfg.num_meta_tokens + (
        cfg.num_vision_tokens if cfg.frontend == "vision_stub" else 0)
    assert int(cache["lengths"][0]) == S + expected_extra + 3


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "mamba2-780m",
                                  "hymba-1.5b", "gemma2-2b",
                                  "musicgen-large"])
def test_decode_matches_forward(arch):
    """Greedy decode logits == teacher-forced full forward logits."""
    cfg = get_config(arch, reduced=True)
    params = T.init_params(cfg, KEY, jnp.float32)
    B, S, S0 = 2, 12, 8
    batch = make_batch(cfg, B=B, S=S, labels=False)
    tokens = batch["tokens"]
    full = T.forward(cfg, params, batch)  # (B, S_total, Kcb, Vp)
    off = full.shape[1] - S
    prefill_batch = dict(batch)
    prefill_batch["tokens"] = tokens[:, :S0]
    lp, cache = T.prefill(cfg, params, prefill_batch, max_len=S + 2,
                          cache_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(
        full[:, off + S0 - 1]), rtol=3e-2, atol=3e-2)
    for i in range(S0, S):
        nxt = tokens[:, i] if cfg.num_codebooks == 1 else tokens[:, i, :]
        lp, cache = T.decode_step(cfg, params, cache, nxt)
        np.testing.assert_allclose(
            np.asarray(lp), np.asarray(full[:, off + i]),
            rtol=3e-2, atol=3e-2)


def test_sliding_window_restricts_context():
    """With a tiny window, distant tokens must not influence logits."""
    cfg = get_config("gemma2-2b", reduced=True)  # window 8, alternating
    params = T.init_params(cfg, KEY, jnp.float32)
    k1, k2 = jax.random.split(KEY)
    t1 = jax.random.randint(k1, (1, 24), 0, cfg.vocab_size)
    t2 = t1.at[0, 0].set((t1[0, 0] + 1) % cfg.vocab_size)
    f1 = T.forward(cfg, params, {"tokens": t1})
    T.forward(cfg, params, {"tokens": t2})
    # Last position: global layers still see token 0 -> logits differ is
    # allowed; but POSITION 1..7 beyond-window influence on local-only...
    # Instead check causality: changing the LAST token must not affect
    # earlier positions.
    t3 = t1.at[0, -1].set((t1[0, -1] + 1) % cfg.vocab_size)
    f3 = T.forward(cfg, params, {"tokens": t3})
    np.testing.assert_allclose(np.asarray(f1[:, :-1]),
                               np.asarray(f3[:, :-1]), rtol=1e-5, atol=1e-5)


def test_meta_tokens_always_visible():
    """hymba's meta tokens must influence positions beyond the window."""
    cfg = get_config("hymba-1.5b", reduced=True)
    params = T.init_params(cfg, KEY, jnp.float32)
    tokens = jax.random.randint(KEY, (1, 20), 0, cfg.vocab_size)
    f1 = T.forward(cfg, params, {"tokens": tokens})
    params2 = dict(params)
    params2["meta"] = params["meta"] + 1.0
    f2 = T.forward(cfg, params2, {"tokens": tokens})
    # far beyond the window of 8: meta change still shifts logits
    assert float(jnp.abs(f1[:, -1] - f2[:, -1]).max()) > 1e-6


def test_moe_ragged_matches_dense():
    cfg = get_config("olmoe-1b-7b", reduced=True)
    params = T.init_params(cfg, KEY, jnp.float32)
    batch = make_batch(cfg, labels=False)
    f_dense = T.forward(cfg, params, batch, moe_impl="dense")
    f_ragged = T.forward(cfg, params, batch, moe_impl="ragged")
    np.testing.assert_allclose(np.asarray(f_dense), np.asarray(f_ragged),
                               rtol=2e-4, atol=2e-4)


def test_vocab_padding_masked():
    """Padded vocab columns never win argmax and don't affect loss."""
    cfg = get_config("granite-3-2b", reduced=True)  # vocab 131 -> pad 144
    assert cfg.padded_vocab > cfg.vocab_size
    params = T.init_params(cfg, KEY, jnp.float32)
    batch = make_batch(cfg)
    logits, _ = T.prefill(cfg, params, batch, max_len=20)
    ids = T.greedy_token(cfg, logits)
    assert np.all(np.asarray(ids) < cfg.vocab_size)
    loss, _ = T.loss_fn(cfg, params, batch, remat=False)
    assert np.isfinite(float(loss))


def test_param_count_matches_init():
    """Config capacity math == actual initialized parameter count."""
    for arch in ARCH_NAMES:
        cfg = get_config(arch, reduced=True)
        params = T.init_params(cfg, KEY, jnp.float32)
        actual = sum(leaf.size for leaf in jax.tree.leaves(params))
        assert actual == cfg.param_count(), arch
