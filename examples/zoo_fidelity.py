"""Model-zoo fidelity explorer: what the paper's Table I/II trade-off
looks like on real LM weights.

For each selected architecture, builds the bf16/int8/int4 zoo, measures
size and top-1 agreement vs the fp32 reference, and times load (host ->
device) vs inference — demonstrating the load >> infer asymmetry that
makes warm starts matter.

    PYTHONPATH=src python examples/zoo_fidelity.py --archs tinyllama-1.1b olmoe-1b-7b
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import transformer as T
from repro.quant.quantize import fidelity, params_nbytes, quantize_params

ap = argparse.ArgumentParser()
ap.add_argument("--archs", nargs="+",
                default=["tinyllama-1.1b", "mamba2-780m", "olmoe-1b-7b"])
args = ap.parse_args()

key = jax.random.key(0)
def fwd(c, p, b):
    return T.forward(c, p, b)[..., 0, :]

for arch in args.archs:
    cfg = get_config(arch, reduced=True)
    params = T.init_params(cfg, key, jnp.float32)
    shape = ((2, 32) if cfg.num_codebooks == 1
             else (2, 32, cfg.num_codebooks))
    batch = {"tokens": jax.random.randint(key, shape, 0, cfg.vocab_size)}
    if cfg.frontend == "vision_stub":
        batch["patch_embeds"] = jax.random.normal(
            key, (2, cfg.num_vision_tokens, cfg.d_model))
    base_bytes = params_nbytes(params)
    print(f"\n=== {arch} ({cfg.param_count():,} params, "
          f"fp32={base_bytes / 2 ** 20:.2f}MB)")
    jitted = jax.jit(lambda p: fwd(cfg, p, batch))
    for bits in (16, 8, 4):
        variant = quantize_params(params, bits=bits, group=32)
        nbytes = params_nbytes(variant)
        host = jax.tree.map(np.asarray, variant)
        t0 = time.perf_counter()
        dev = jax.tree.map(jnp.asarray, host)
        jax.block_until_ready(jax.tree.leaves(dev)[0])
        load_ms = (time.perf_counter() - t0) * 1e3
        out = jitted(dev)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        jax.block_until_ready(jitted(dev))
        infer_ms = (time.perf_counter() - t0) * 1e3
        fid = (dict(top1_agreement=100.0, logit_mse=0.0) if bits == 16
               else fidelity(cfg, params, variant, batch,
                             lambda c, p, b: fwd(c, p, b)))
        print(f"  int{bits:<2} size={nbytes / base_bytes:5.2f}x "
              f"agree={fid['top1_agreement']:5.1f}% "
              f"load={load_ms:6.1f}ms infer={infer_ms:6.1f}ms")
