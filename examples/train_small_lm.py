"""End-to-end training driver: train a reduced LM for a few hundred steps
on CPU with the full production stack — mixed precision, remat, gradient
accumulation, int8+error-feedback gradient compression, async atomic
checkpointing, and two injected node failures that the supervisor
recovers from (bitwise-identically, thanks to the step-indexed pipeline).

    PYTHONPATH=src python examples/train_small_lm.py --steps 200
"""
import argparse
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.distributed.fault_tolerance import FailureInjector, run_supervised
from repro.training.data import DataConfig, SyntheticStream
from repro.training.optim import AdamW, warmup_cosine
from repro.training.train_step import init_state, make_train_step

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="tinyllama-1.1b")
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=64)
args = ap.parse_args()

cfg = get_config(args.arch, reduced=True)
print(f"training {cfg.name}: {cfg.param_count():,} params, "
      f"batch={args.batch} seq={args.seq}")

opt = AdamW(lr=warmup_cosine(3e-3, 20, args.steps), weight_decay=0.01)
step_fn = jax.jit(make_train_step(
    cfg, opt, remat=True, grad_accum=2, compression=True,
    compute_dtype=None))
state = init_state(cfg, jax.random.key(0), opt, compression=True)
ds = SyntheticStream(DataConfig(vocab_size=cfg.vocab_size,
                                seq_len=args.seq,
                                global_batch=args.batch))


def batch_fn(step):
    return {k: jnp.asarray(v) for k, v in ds.batch_at(step).items()}


with tempfile.TemporaryDirectory() as ckpt_dir:
    t0 = time.time()
    report = run_supervised(
        init_state=state, step_fn=step_fn, batch_fn=batch_fn,
        total_steps=args.steps, ckpt_dir=ckpt_dir, ckpt_every=25,
        injector=FailureInjector(
            fail_at_steps=(args.steps // 3, 2 * args.steps // 3)))
    dt = time.time() - t0

print(f"\ndone: {report.steps_completed} steps in {dt:.1f}s "
      f"({report.steps_completed / dt:.2f} steps/s), "
      f"{report.restarts} node failures survived")
print(f"loss: {report.losses[0]:.4f} -> {report.losses[-1]:.4f}")
every = max(len(report.losses) // 10, 1)
print("curve:", " ".join(f"{x:.3f}" for x in report.losses[::every]))
assert report.losses[-1] < report.losses[0], "loss must decrease"
