"""End-to-end driver: the event-driven serving engine running REAL models
under a device memory budget, with background prefetching.

Three LM architectures (reduced configs) are registered as tenants; each
gets a real zoo (bf16 + int8 weight variants built by repro.quant).  A
Poisson per-tenant trace (the simulator's arrival process) drives the
engine: the iWS-BFE policy decides which variant of which tenant stays
resident, every admitted batch's KV cache is charged against the same
budget, int8 variants run through the fused dequant matmul path, and RNN
predictors learn each tenant's cadence and trigger *background* loads —
predicted-next tenants are staged off the hot path by the
BackgroundLoader (watch the ``prefetch``/``load``/``cancel`` events in
the log), cold tenants' demand loads overlap other tenants' execution,
and in-flight loads claim budget so nothing double-books them.

The entire stack comes up from one declarative config —
``EdgeServer.build(ServingConfig(...))`` — which registers the tenants,
installs the predictors, derives the contended budget, resolves the
policy through the registry, and attaches the loader + engine.

    PYTHONPATH=src python examples/multi_tenant_serving.py
"""
from repro.serving import poisson_trace
from repro.serving.api import (BatchingSpec, EdgeServer, ServingConfig,
                               TenantSpec)

TENANTS = ["tinyllama-1.1b", "mamba2-780m", "gemma2-2b"]

server = EdgeServer.build(ServingConfig(
    tenants=tuple(TenantSpec(n) for n in TENANTS),
    policy="iws-bfe",
    delta_ms=1500.0,
    batching=BatchingSpec(max_batch=4, window_ms=100.0),
    # budget_mb=None derives the standard contended budget, with
    # headroom for the largest decode cache this trace admits.
    kv_headroom_shape=(4, 12 + 6)))
cfgs = {}
for name in TENANTS:
    cfgs[name] = server.tenants[name].cfg
    zoo = server.tenants[name].zoo
    print(f"tenant {name:16s} zoo: " + "  ".join(
        f"{v.bits}bit={v.size_mb:.2f}MB" for v in zoo.variants))
print(f"budget: {server.budget_mb:.2f} MB — forces contention\n")

trace, wl = poisson_trace(cfgs, requests_per_app=8, mean_iat_ms=800.0,
                          deviation=0.3, seed=0, max_new=6)
print(f"trace: {len(trace)} requests over {wl.horizon_ms / 1e3:.1f}s "
      f"(virtual), KL={wl.kl:.3f}\n")
stats = server.engine.run_trace(trace)
server.engine.check_event_invariant()

# Each engine event normalizes to a typed AuditEvent (kind, t, app,
# detail) — `ev.audit` — whose __str__ is the canonical log line.
for ev in server.engine.events:
    if ev.kind in ("admit", "reject", "prefetch", "demand", "load",
                   "cancel"):
        print(f"{ev.audit} used={ev.used_mb:5.2f}MB "
              f"inflight={ev.inflight_mb:5.2f}MB free={ev.free_mb:5.2f}MB")

print(f"\nthroughput: {stats.requests_per_sec or 0.0:.2f} req/s   "
      f"kv_rejections={stats.kv_rejections} "
      f"kv_downgrades={stats.kv_downgrades}")
print(f"prefetch pipeline: hits={stats.prefetch_hits} "
      f"wasted={stats.prefetch_wasted} "
      f"demand_loads={stats.demand_loads} "
      f"loads_committed={stats.loads_committed} "
      f"load_overlap={stats.load_overlap_ms:.1f}ms")
print(f"predictors: window_hit_rate={stats.prediction_hit_rate:.2f} "
      f"background_fits_scheduled={stats.fits_scheduled}")
for app, s in stats.per_tenant.items():
    print(f"  {app:16s} n={s['requests']:3d} warm={s['warm_ratio']:.2f} "
          f"fail={s['fail_ratio']:.2f} p50={s['p50_ms']:7.0f}ms "
          f"p95={s['p95_ms']:7.0f}ms p99={s['p99_ms']:7.0f}ms "
          f"batch={s['mean_batch']:.1f}")
st = server.manager.state
print(f"final residency: weights={st.weights_mb:.2f}MB kv={st.kv_mb:.2f}MB "
      f"of {st.budget_mb:.2f}MB")
server.close()
