"""End-to-end driver: Edge-MultiAI serving REAL models under a device
memory budget.

Three LM architectures (reduced configs) are registered as tenants; each
gets a real zoo (bf16 + int8 weight variants built by repro.quant).  A
bursty request trace drives the server: the iWS-BFE policy decides which
variant of which tenant stays resident; int8 variants are served through
the fused dequant matmul path; RNN predictors learn each tenant's cadence
and trigger proactive loads.

    PYTHONPATH=src python examples/multi_tenant_serving.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import transformer as T
from repro.serving import Batcher, MultiTenantServer, Request

TENANTS = ["tinyllama-1.1b", "mamba2-780m", "gemma2-2b"]

server = MultiTenantServer(budget_mb=1e9, policy="iws-bfe",
                           delta_ms=1500.0)
cfgs = {}
for name in TENANTS:
    cfg = get_config(name, reduced=True)
    params = T.init_params(cfg, jax.random.key(hash(name) % 2 ** 31),
                           jnp.float32)
    server.register(name, cfg, params)
    cfgs[name] = cfg
    zoo = server.tenants[name].zoo
    print(f"tenant {name:16s} zoo: " + "  ".join(
        f"{v.bits}bit={v.size_mb:.2f}MB" for v in zoo.variants))
small = sum(t.zoo.smallest.size_mb for t in server.tenants.values())
room = max(t.zoo.largest.size_mb - t.zoo.smallest.size_mb
           for t in server.tenants.values())
server.budget_mb = (small + room) * 1.05  # all-int8 + one bf16 upgrade
server.start()
print(f"budget: {server.budget_mb:.2f} MB — forces contention\n")

rng = np.random.default_rng(0)
batcher = Batcher(max_batch=4)
now = 0.0
for i in range(24):
    # bursty trace: tenants take turns issuing small bursts
    name = TENANTS[(i // 4) % len(TENANTS)]
    cfg = cfgs[name]
    plen = int(rng.integers(4, 10))
    prompt = rng.integers(0, cfg.vocab_size, plen).astype(np.int32)
    batcher.submit(Request(app=name, prompt=prompt, max_new=6,
                           arrival_ms=now))
    now += float(rng.exponential(400.0))
    if batcher.pending() >= 4 or i == 23:
        while (b := batcher.next_batch()) is not None:
            server.predict_and_preload(now)
            r = server.serve(b.app, b.prompts, b.max_new, now_ms=now)
            status = ("FAIL" if r.failed
                      else ("warm" if r.warm else "COLD"))
            print(f"[{now:7.0f}ms] {b.app:16s} batch={len(b.requests)} "
                  f"{status:4s} bits={r.bits} "
                  f"tokens={r.tokens[0][:4].tolist()}... "
                  f"lat={r.latency_s * 1e3:6.0f}ms "
                  f"resident={server.manager.state.used_mb:.2f}MB")

print("\nfinal stats:", server.stats())
