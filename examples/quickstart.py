"""Quickstart: the serving framework in ~30 lines.

One declarative config -> a fully wired multi-tenant edge server on a
4-chip mesh.  The sim-time executor makes this deterministic and
XLA-free (swap ``executor="real"`` to run actual quantized models);
everything else — policy registry, background prefetch pipeline,
per-shard staging under per-device budgets, KV-charged admission — is
exactly the production path.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.serving import poisson_trace
from repro.serving.api import (BatchingSpec, EdgeServer, LoaderSpec,
                               ServingConfig, TenantSpec)

config = ServingConfig(
    # Two LM tenants; each gets a bf16 + int8 model zoo.
    tenants=(TenantSpec("tinyllama-1.1b"), TenantSpec("mamba2-780m")),
    policy="iws-bfe",            # any registered policy: lfe, bfe,
                                 # ws-bfe, iws-bfe, batch-bfe, ...
    delta_ms=750.0,              # prediction-window half-width
    batching=BatchingSpec(max_batch=4, window_ms=20.0),
                                 # BatchingSpec(continuous=True) makes
                                 # the *request* the admission unit:
                                 # each request charges page-rounded KV
                                 # from a KVPagePool, joins/leaves the
                                 # running decode batch per step, and
                                 # frees its pages the step it retires;
                                 # kv_page_mb sets the page size (0 =
                                 # auto: largest tenant's 8-token
                                 # cache).  Adds kv_page_mb/
                                 # kv_pages_total/kv_pages_used/
                                 # kv_preemptions to stats();
                                 # kv_overrelease_mb counts release
                                 # drift in either mode (0.0 when
                                 # accounting is healthy).
    executor="sim",              # deterministic virtual service times
    loader=LoaderSpec(sharded=True, mesh_shape=(4,)),  # 4-way TP mesh:
                                 # weights shard per chip, loads stage
                                 # per shard, budgets ledger per device
    kv_headroom_shape=(2, 12),   # budget headroom for a (2, 12) cache
    # fault=FaultSpec(events=((2000.0, 3, "down"), (6000.0, 3, "up")))
    #                              # elastic mesh: schedule chip loss and
    #                              # recovery on the engine clock.  A
    #                              # "down" event drains chip 3 through
    #                              # one transactional ResidencyPlan
    #                              # (shard migrations toward surviving
    #                              # chips, downgrades where nothing
    #                              # fits, KV-page evictions + sequence
    #                              # preemption for pages homed there)
    #                              # while other tenants keep decoding;
    #                              # "up" rebalances shards back toward
    #                              # the canonical layout.  Requires
    #                              # LoaderSpec(sharded=True); adds
    #                              # chips_lost/chips_recovered/
    #                              # drain_migrations/drain_downgrades
    #                              # to stats() and chip_down/chip_up/
    #                              # drain events to the audit trail.
)                                # budget_mb=None -> derived contention

server = EdgeServer.build(config)          # register + wire + start
ledger = server.manager.state.devices
print(f"budget {server.budget_mb:.2f} MB "
      f"({ledger.n_devices} chips x {ledger.budgets_mb[0]:.2f} MB), "
      f"policy {server.manager.policy.name}")

# A Poisson per-tenant trace drives the engine; the RNN predictors
# learn each cadence and the loader prefetches ahead of requests.
cfgs = {t.name: t.cfg for t in server.tenants.values()}
trace, _ = poisson_trace(cfgs, requests_per_app=20, mean_iat_ms=400.0,
                         seed=0)
# run_trace returns a frozen ServingStats: core fields (requests,
# warm_ratio, kv_* counters, per_tenant percentiles) are always set;
# subsystem blocks (loader pipeline, mesh, paged KV, elastic) are None
# until that subsystem is attached.  stats.to_dict() flattens to the
# historical dict, dropping the unset blocks.
stats = server.engine.run_trace(trace)
server.engine.check_event_invariant()      # budget held at every event
server.close()

print(f"{stats.requests} requests: warm={stats.warm_ratio:.0%} "
      f"prefetch_hits={stats.prefetch_hits} "
      f"demand_loads={stats.demand_loads} "
      f"shards_landed={stats.shards_landed} "
      f"prediction_hit_rate={stats.prediction_hit_rate:.0%}")
