"""Quickstart: the paper in 60 seconds.

Builds the paper's five-application setup (Table II zoos), generates a
workload with 30% prediction deviation, and compares no-policy against
Edge-MultiAI's iWS-BFE — reproducing the headline claims (≈2× multi-
tenancy, ≈60% more warm starts, minimal cold starts).

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.configs.paper_edge import DEFAULT_MEMORY_MB, paper_zoos
from repro.core import generate_workload, simulate

zoos = paper_zoos()
print("Tenants and their model zoos (paper Table II):")
for app, zoo in zoos.items():
    variants = ", ".join(
        f"{v.bits:>2}bit {v.size_mb:6.1f}MB acc={v.accuracy:4.1f}%"
        for v in zoo.variants)
    print(f"  {app:22s} {variants}")
print(f"\nEdge memory budget: {DEFAULT_MEMORY_MB:.0f} MB "
      f"(all-FP32 residency needs "
      f"{sum(z.largest.size_mb for z in zoos.values()):.0f} MB)\n")

wl = generate_workload(list(zoos), requests_per_app=60, deviation=0.3,
                       seed=0)
print(f"Workload: {len(wl.requests)} requests, prediction residuals "
      f"D={wl.delta_D:.0f}ms sigma={wl.delta_sigma:.0f}ms "
      f"KL={wl.kl:.3f}\n")

for policy in ("none", "lfe", "bfe", "ws-bfe", "iws-bfe"):
    res = simulate(zoos, wl, policy=policy, budget_mb=DEFAULT_MEMORY_MB)
    m = res.metrics
    print(f"  {policy:8s} warm={m.warm_ratio:6.1%} "
          f"cold={m.cold_ratio:6.1%} fail={m.fail_ratio:6.1%} "
          f"accuracy={m.mean_accuracy():.3f} "
          f"robustness={m.robustness():.3f}")

base = simulate(zoos, wl, policy="none", budget_mb=DEFAULT_MEMORY_MB)
best = simulate(zoos, wl, policy="iws-bfe", budget_mb=DEFAULT_MEMORY_MB)
gain = best.metrics.warm_ratio / max(base.metrics.warm_ratio, 1e-9)
print(f"\nEdge-MultiAI (iWS-BFE) delivers {gain:.2f}x the warm-start "
      f"rate of an unmanaged edge server.")
