"""Cluster tier: warm-aware routing across a fleet of edge servers.

Three sim-executor EdgeServers come up from ONE declarative document —
``EdgeCluster.build(ClusterConfig(...))`` — and share a single global
virtual clock.  A flash-crowd trace (Poisson baseline per tenant, plus
an *unpredicted* dense burst on tinyllama mid-trace) is routed request
by request: the warm-aware router reads each server's typed
``ServerView`` (which tenants are resident or staging at what variant
accuracy, queue depths — only state a real fleet's stats endpoint would
publish) and keeps every tenant's requests on the box already holding
its weights, spilling to an idle neighbor only once the home queue gets
expensive.  The same trace under round-robin sprays requests
everywhere, so every server churns every zoo — the fleet-wide warm
ratio is the A/B.

Everything is bit-deterministic: same trace + same config → identical
per-server audit trails, so the printed numbers never wobble.

    PYTHONPATH=src python examples/cluster_serving.py
"""
from repro.cluster import ClusterConfig, EdgeCluster, RouterSpec
from repro.core.simulator import generate_flash_crowd
from repro.serving import trace_from_workload
from repro.serving.api import ServingConfig, TenantSpec

TENANTS = ["tinyllama-1.1b", "mamba2-780m", "gemma2-2b"]

base = ServingConfig(
    tenants=tuple(TenantSpec(n) for n in TENANTS),
    policy="bfe",
    executor="sim")

wl = generate_flash_crowd(
    TENANTS, requests_per_app=36, base_iat_ms=8000.0,
    burst_app=TENANTS[0], burst_requests=40, burst_iat_ms=100.0, seed=7)
print(f"flash-crowd trace: {len(wl.requests)} requests over "
      f"{wl.horizon_ms / 1e3:.1f}s (virtual); the {TENANTS[0]} burst "
      f"is absent from the predictions\n")

for router in ("round-robin", "warm-aware"):
    cfg = ClusterConfig.uniform(
        3, base, RouterSpec(name=router, handoff_queue=4))
    cluster = EdgeCluster.build(cfg)
    cfgs = {t.name: t.cfg for t in cluster.servers[0].tenants.values()}
    trace = trace_from_workload(wl, cfgs, seed=3, prompt_len=(8, 9),
                                max_new=4)
    stats = cluster.run_trace(trace)
    cluster.check_event_invariant()
    c = stats.cluster
    print(f"router={router}")
    print(f"  fleet warm_ratio : {stats.warm_ratio:.3f} "
          f"({stats.requests} requests)")
    print(f"  routed/spilled   : {c['routed']}/{c['spilled']} "
          f"(handoffs={c['handoffs']})")
    print(f"  per-server load  : "
          + "  ".join(f"s{i}={n}req warm={w:.3f}"
                      for i, (n, w) in enumerate(
                          zip(c["per_server_requests"],
                              c["per_server_warm_ratio"]))))
    for app, s in sorted(stats.per_tenant.items()):
        print(f"    {app:16s} warm={s['warm_ratio']:.3f} "
              f"requests={s['requests']}")
    cluster.close()
    print()

print("warm-aware keeps each tenant's home server warm; round-robin "
      "spreads the churn.")
