"""Model-zoo builders: produce int8/int4 (and bf16) variants of real
parameter trees — the paper's per-application "precision levels" realized
on actual LM weights.

Representation: a quantized weight is ``{"q": int8 (..., K, N),
"s": f32 (..., K//group, N)}``; dense layers route through the fused
dequant Pallas matmul (``ops.quant_matmul``) at serve time, so the smaller
variant also means proportionally less HBM traffic (the TPU analogue of
the paper's Table I load/inference asymmetry).

1-D parameters (norms, biases, A_log, …) and embedding tables stay in the
base dtype: they are a negligible fraction of bytes and quantizing them
hurts fidelity disproportionately.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.kernels import ops

PyTree = Any

# Tree paths containing these substrings are never quantized.  Depthwise
# conv taps are W×C (a few KB) — not worth the fidelity cost.
_EXCLUDE = ("embed", "meta", "final_norm", "conv")


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def _quantize_leaf(w: jnp.ndarray, bits: int, group: int):
    """Quantize trailing-2D slices of an >=2-D weight."""
    *lead, K, N = w.shape
    w2 = w.reshape(-1, K, N)
    qs, ss = [], []
    for i in range(w2.shape[0]):
        q, s = ops.quantize_weights(w2[i], bits=bits, group=group)
        qs.append(q)
        ss.append(s)
    q = jnp.stack(qs).reshape(*lead, K, N)
    s = jnp.stack(ss).reshape(*lead, ss[0].shape[0], N)
    return {"q": q, "s": s}


def dequantize_leaf(leaf) -> jnp.ndarray:
    if not is_quantized(leaf):
        return leaf
    q, s = leaf["q"], leaf["s"]
    *lead, K, N = q.shape
    G = s.shape[-2]
    group = K // G
    w = q.astype(jnp.float32).reshape(*lead, G, group, N) * s[..., None, :]
    return w.reshape(*lead, K, N)


def is_quantized(leaf) -> bool:
    return isinstance(leaf, dict) and set(leaf) == {"q", "s"}


def quantize_params(params: PyTree, *, bits: int = 8,
                    group: int = 128) -> PyTree:
    """Return the ``bits``-precision zoo variant of a parameter tree."""
    if bits >= 16:
        dtype = jnp.bfloat16 if bits == 16 else jnp.float32
        return jax.tree.map(
            lambda w: w.astype(dtype) if w.ndim >= 2 else w, params)

    def visit(path, w):
        ps = _path_str(path)
        if any(e in ps for e in _EXCLUDE):
            return w
        # Leaves under layers/ carry a stacked leading L dim: true weight
        # matrices there are ndim>=3; elsewhere (head) ndim>=2.
        min_ndim = 3 if ps.startswith("layers") else 2
        if w.ndim < min_ndim:
            return w
        K = w.shape[-2]
        g = group if K % group == 0 else K
        return _quantize_leaf(w, bits, g)

    return jax.tree_util.tree_map_with_path(visit, params)


def dequantize_params(qparams: PyTree) -> PyTree:
    return jax.tree.map(dequantize_leaf, qparams, is_leaf=is_quantized)


def params_nbytes(params: PyTree) -> int:
    total = 0
    for leaf in jax.tree.leaves(params):
        total += leaf.size * leaf.dtype.itemsize
    return total


# ---------------------------------------------------------------------------
# Fidelity: the accuracy proxy for LM-arch zoos (DESIGN.md §2).
# ---------------------------------------------------------------------------
def fidelity(cfg, params_ref: PyTree, qparams: PyTree, batch: dict,
             forward_fn) -> Dict[str, float]:
    """Top-1 agreement and logit MSE of quantized vs reference forward."""
    ref_logits = forward_fn(cfg, params_ref, batch)
    deq = dequantize_params(qparams)
    q_logits = forward_fn(cfg, deq, batch)
    ref_ids = jnp.argmax(ref_logits, -1)
    q_ids = jnp.argmax(q_logits, -1)
    agree = float(jnp.mean((ref_ids == q_ids).astype(jnp.float32)))
    mse = float(jnp.mean((ref_logits - q_logits) ** 2))
    return {"top1_agreement": agree * 100.0, "logit_mse": mse}
