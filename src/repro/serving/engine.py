"""Event-driven multi-tenant serving engine with KV-cache residency.

The seed server handled one request at a time and its decode caches were
invisible to the Edge-MultiAI budget.  This engine closes both gaps:

* **admit → (maybe load/evict) → prefill → decode → retire** as a
  continuous loop pulled from the :class:`~repro.serving.batcher.Batcher`
  (largest-queue-first across tenants, FIFO within a tenant);
* every admitted batch's KV cache is sized from the real decode-cache
  pytree (``transformer.abstract_cache``) and charged to the tenant via
  ``EdgeMultiAI.admit_batch`` — so ``MemoryState.free_mb``, the eviction
  policies, and iWS-BFE procurement all see weights **plus** caches; the
  charge is released when the batch retires;
* a trace-driven load generator reuses the simulator's Poisson
  per-tenant arrivals (``generate_workload``) so the same workloads that
  drive the paper evaluation drive the real models;
* per-tenant latency percentiles and throughput come out of ``stats()``.

Time is virtual (milliseconds, like the simulator) so runs are
reproducible; batch *service* time is the measured wall clock of the real
prefill+decode, folded back into the virtual clock.  ``run_async`` wraps
the loop for asyncio callers.
"""
from __future__ import annotations

import asyncio
import functools
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.manager import BatchAdmission
from repro.core.simulator import Workload, generate_workload
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serving.batcher import Batch, Batcher, Request

MB = 1024 * 1024


@functools.lru_cache(maxsize=1024)
def kv_cache_mb(cfg: ModelConfig, batch: int, max_len: int,
                quantized: bool = False) -> float:
    """Exact decode-cache footprint in MB, from the abstract cache pytree
    (no allocation) — the same shapes ``prefill`` will materialize.
    Memoized: admission sits on the serving hot path and batch shapes
    repeat (ModelConfig is frozen/hashable)."""
    leaves = jax.tree.leaves(
        T.abstract_cache(cfg, batch, max_len, quantized=quantized))
    return sum(int(np.prod(l.shape)) * l.dtype.itemsize
               for l in leaves) / MB


@dataclass
class RequestResult:
    """Per-request outcome with queueing + service latency."""
    rid: int
    app: str
    arrival_ms: float
    start_ms: float
    done_ms: float
    warm: bool
    failed: bool
    bits: Optional[int]
    batch_size: int
    kv_mb: float

    @property
    def latency_ms(self) -> float:
        return self.done_ms - self.arrival_ms


@dataclass
class EngineEvent:
    """Audit-trail entry emitted at every engine state change; the
    invariant tests replay these to check ``used_mb ≤ budget_mb`` at
    every point in the run, not just at the end."""
    t_ms: float
    kind: str  # submit | admit | reject | retire
    app: str
    kv_mb: float
    used_mb: float
    free_mb: float


Executor = Callable[[Any, Batch, Optional[dict]], np.ndarray]


def _default_executor(runtime, batch: Batch,
                      extra: Optional[dict] = None) -> np.ndarray:
    return runtime.generate(batch.prompts, batch.max_new, extra)


class ServingEngine:
    """Pulls batches from the Batcher and drives them through the
    Edge-MultiAI manager with full runtime-memory accounting.

    ``executor`` is injectable so accounting/invariant tests can run the
    full admit/retire protocol without touching XLA.
    """

    def __init__(self, server, *, max_batch: int = 8,
                 batch_window_ms: float = 0.0,
                 executor: Optional[Executor] = None):
        self.server = server
        self.batcher = Batcher(max_batch=max_batch)
        self.max_batch = max_batch
        self.batch_window_ms = batch_window_ms
        self.results: List[RequestResult] = []
        self.events: List[EngineEvent] = []
        self.kv_downgrades = 0  # requester shrank itself to fit its cache
        self.weight_failures = 0  # batches whose weights were unprocurable
        self._executor = executor or _default_executor

    @property
    def kv_rejections(self) -> int:
        """Batches bounced for cache pressure — the manager's counter is
        the single source of truth (it performs the rejection)."""
        mgr = self.server.manager
        return mgr.kv_rejections if mgr else 0

    # ------------------------------------------------------------------
    def _event(self, t_ms: float, kind: str, app: str, kv_mb: float) -> None:
        st = self.server.manager.state
        self.events.append(EngineEvent(
            t_ms, kind, app, kv_mb, st.used_mb, st.free_mb))

    def submit(self, req: Request, now_ms: float) -> None:
        """Enqueue a request; feeds the tenant's RNN arrival predictor."""
        req.arrival_ms = now_ms if req.arrival_ms == 0.0 else req.arrival_ms
        self.server.tenants[req.app].predictor.observe_request(
            req.arrival_ms)
        self.batcher.submit(req)
        self._event(req.arrival_ms, "submit", req.app, 0.0)

    # ------------------------------------------------------------------
    def execute_batch(self, batch: Batch, now_ms: float,
                      extra: Optional[dict] = None
                      ) -> Tuple[List[RequestResult], float,
                                 Optional[np.ndarray]]:
        """One admit→(load/evict)→prefill→decode→retire cycle.

        Returns the per-request results, the measured service time in ms
        (wall clock of the real model execution), and the generated
        tokens (None when the batch was rejected).
        """
        mgr = self.server.manager
        assert mgr is not None, "server.start() before engine use"
        tr = self.server.tenants[batch.app]
        total_len = batch.prompts.shape[1] + batch.max_new
        kv_mb = kv_cache_mb(tr.cfg, len(batch.requests), total_len)
        adm: BatchAdmission = mgr.admit_batch(batch.app, now_ms, kv_mb)
        if adm.self_downgraded:
            self.kv_downgrades += 1
        if adm.failed:
            if not adm.kv_rejected:
                self.weight_failures += 1
            self._event(now_ms, "reject", batch.app, kv_mb)
            # A rejected request was never served: not warm, failed.
            results = [
                RequestResult(r.rid, batch.app, r.arrival_ms, now_ms,
                              now_ms, False, True, None,
                              len(batch.requests), 0.0)
                for r in batch.requests]
            self.results.extend(results)
            return results, 0.0, None
        self._event(now_ms, "admit", batch.app, adm.kv_mb)
        t0 = time.monotonic()
        try:
            tokens = self._executor(tr, batch, extra)
        except BaseException:
            # Execution crashed (XLA OOM, bad inputs): release the cache
            # charge so it doesn't leak, balance the audit trail, and
            # record the requests as failed so callers that catch the
            # exception and keep serving don't lose them from stats.
            service_ms = (time.monotonic() - t0) * 1e3
            done_ms = now_ms + service_ms
            mgr.release_kv(batch.app, adm.kv_mb)
            self._event(done_ms, "retire", batch.app, -adm.kv_mb)
            self.results.extend(
                RequestResult(r.rid, batch.app, r.arrival_ms, now_ms,
                              done_ms, False, True, None,
                              len(batch.requests), 0.0)
                for r in batch.requests)
            raise
        service_ms = (time.monotonic() - t0) * 1e3
        done_ms = now_ms + service_ms
        mgr.release_kv(batch.app, adm.kv_mb)
        self._event(done_ms, "retire", batch.app, -adm.kv_mb)
        results = [
            RequestResult(r.rid, batch.app, r.arrival_ms, now_ms, done_ms,
                          adm.warm, False, adm.bits, len(batch.requests),
                          adm.kv_mb)
            for r in batch.requests]
        self.results.extend(results)
        return results, service_ms, tokens

    # ------------------------------------------------------------------
    def run_trace(self, requests: Sequence[Request]) -> dict:
        """Closed-loop trace replay: arrivals enter the batcher at their
        trace timestamps; the single engine pulls the next batch whenever
        it is idle, waiting out the batching window when the queue is
        short and another arrival is imminent."""
        pending = sorted(requests, key=lambda r: r.arrival_ms)
        i, n, now = 0, len(pending), 0.0
        while i < n or self.batcher.pending():
            if not self.batcher.pending():
                now = max(now, pending[i].arrival_ms)
            while i < n and pending[i].arrival_ms <= now:
                self.submit(pending[i], pending[i].arrival_ms)
                i += 1
            # Hold a short batch for an imminent arrival (amortization).
            if (self.batcher.pending() < self.max_batch and i < n
                    and pending[i].arrival_ms <= now + self.batch_window_ms):
                now = pending[i].arrival_ms
                continue
            self.server.predict_and_preload(now)
            batch = self.batcher.next_batch()
            _, service_ms, _ = self.execute_batch(batch, now)
            now += service_ms
        return self.stats()

    async def run_async(self, requests: Sequence[Request]) -> dict:
        """Asyncio entry point: replays the trace off the event loop."""
        return await asyncio.to_thread(self.run_trace, requests)

    # ------------------------------------------------------------------
    def stats(self) -> dict:
        """Aggregate + per-tenant latency percentiles and throughput."""
        out: dict = {
            "requests": len(self.results),
            "kv_downgrades": self.kv_downgrades,
            "kv_rejections": self.kv_rejections,
            "weight_failures": self.weight_failures,
            "per_tenant": {},
        }
        if not self.results:
            return out
        span_ms = (max(r.done_ms for r in self.results)
                   - min(r.arrival_ms for r in self.results))
        out["requests_per_sec"] = (
            len(self.results) / (span_ms / 1e3) if span_ms > 0 else 0.0)
        for app in sorted({r.app for r in self.results}):
            rs = [r for r in self.results if r.app == app]
            ok = [r.latency_ms for r in rs if not r.failed]
            lat = (dict(zip(
                ("p50_ms", "p95_ms", "p99_ms"),
                (float(x) for x in np.percentile(ok, (50, 95, 99)))))
                if ok else {"p50_ms": float("inf"),
                            "p95_ms": float("inf"),
                            "p99_ms": float("inf")})
            t_span = (max(r.done_ms for r in rs)
                      - min(r.arrival_ms for r in rs))
            out["per_tenant"][app] = {
                "requests": len(rs),
                "warm_ratio": sum(r.warm for r in rs) / len(rs),
                "fail_ratio": sum(r.failed for r in rs) / len(rs),
                "mean_batch": float(np.mean([r.batch_size for r in rs])),
                "throughput_rps": (len(rs) / (t_span / 1e3)
                                   if t_span > 0 else 0.0),
                **lat,
            }
        return out

    def check_event_invariant(self, budget_mb: Optional[float] = None
                              ) -> None:
        """Every recorded event must respect the memory budget."""
        budget = (budget_mb if budget_mb is not None
                  else self.server.manager.state.budget_mb)
        for ev in self.events:
            if ev.used_mb > budget + 1e-6:
                raise AssertionError(
                    f"budget exceeded at t={ev.t_ms:.1f}ms "
                    f"({ev.kind} {ev.app}): {ev.used_mb:.2f}MB "
                    f"> {budget:.2f}MB")


# ---------------------------------------------------------------------------
# Trace-driven load generation (reuses the simulator's arrival process)
# ---------------------------------------------------------------------------
def trace_from_workload(wl: Workload, cfgs: Dict[str, ModelConfig], *,
                        seed: int = 0, prompt_len: Tuple[int, int] = (4, 12),
                        max_new: int = 8) -> List[Request]:
    """Materialize a simulator :class:`Workload` as real serving requests:
    same Poisson per-tenant timestamps, random prompts per tenant vocab."""
    rng = np.random.default_rng(seed)
    reqs = []
    for t, app in wl.requests:
        plen = int(rng.integers(*prompt_len))
        prompt = rng.integers(
            0, cfgs[app].vocab_size, plen).astype(np.int32)
        reqs.append(Request(app=app, prompt=prompt, max_new=max_new,
                            arrival_ms=t))
    return reqs


def poisson_trace(cfgs: Dict[str, ModelConfig], *,
                  requests_per_app: int = 20,
                  mean_iat_ms: float = 2000.0,
                  deviation: float = 0.3,
                  seed: int = 0,
                  max_new: int = 8) -> Tuple[List[Request], Workload]:
    """Convenience: generate_workload → requests, returning both so the
    caller can feed predictions to the manager if desired."""
    wl = generate_workload(list(cfgs), requests_per_app=requests_per_app,
                           mean_iat_ms=mean_iat_ms, deviation=deviation,
                           seed=seed)
    return trace_from_workload(wl, cfgs, seed=seed, max_new=max_new), wl
