"""Event-driven multi-tenant serving engine with KV-cache residency.

The seed server handled one request at a time and its decode caches were
invisible to the Edge-MultiAI budget.  This engine closes both gaps:

* **admit → (maybe load/evict) → prefill → decode → retire** as a
  continuous loop pulled from the :class:`~repro.serving.batcher.Batcher`
  (largest-queue-first across tenants, FIFO within a tenant);
* every admitted batch's KV cache is sized from the real decode-cache
  pytree (``transformer.abstract_cache``) and charged to the tenant via
  ``EdgeMultiAI.admit_batch`` — so ``MemoryState.free_mb``, the eviction
  policies, and iWS-BFE procurement all see weights **plus** caches; the
  charge is released when the batch retires;
* a trace-driven load generator reuses the simulator's Poisson
  per-tenant arrivals (``generate_workload``) so the same workloads that
  drive the paper evaluation drive the real models;
* per-tenant latency percentiles and throughput come out of ``stats()``.

Time is virtual (milliseconds, like the simulator) so runs are
reproducible; batch *service* time is the measured wall clock of the real
prefill+decode — or a deterministic virtual time when the tenant's
executor supplies one — folded back into the virtual clock.  ``run_async``
wraps the loop for asyncio callers.

The engine is written against three structural protocols rather than the
concrete serving classes: :class:`ServingHost` (what it needs from the
tenant registry/facade), :class:`TenantExecutor` (one tenant's config,
zoo, predictor, and execution), and :class:`LoaderChannel` (the
background staging pipeline).  ``EdgeServer``/``TenantRuntime``/
``BackgroundLoader`` are the production implementations; the sim-time
executor (``repro.serving.api.SimTenant``) drops in for deterministic
tests with zero XLA.
"""
from __future__ import annotations

import asyncio
import functools
import itertools
import math
import time
from collections import deque
from dataclasses import dataclass
from typing import (Any, Callable, Deque, Dict, List, Mapping, Optional,
                    Protocol, Sequence, Tuple)

import jax
import numpy as np

from repro.core import actions as RA
from repro.core.manager import LOAD_OVER_INFER, BatchAdmission
from repro.core.policies import DemandContext, ProcurePlan
from repro.core.simulator import Workload, generate_workload
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serving.batcher import Batch, Batcher, Request
from repro.serving.stats import AuditEvent, EventKind, ServingStats

MB = 1024 * 1024


# ---------------------------------------------------------------------------
# Structural protocols: the engine's entire view of the serving stack
# ---------------------------------------------------------------------------
class TenantExecutor(Protocol):
    """One tenant, as the engine sees it: enough to size caches, charge
    load penalties, feed the arrival predictor, and run a batch.
    ``execute`` returns the generated tokens plus an optional *virtual*
    service time in ms — ``None`` means "time me by wall clock" (the real
    XLA runtime), a number means deterministic sim time."""

    cfg: ModelConfig
    zoo: Any  # ModelZoo
    predictor: Any  # RequestPredictor

    def execute(self, batch: Batch, extra: Optional[dict] = None
                ) -> Tuple[np.ndarray, Optional[float]]: ...


class LoaderChannel(Protocol):
    """The background staging pipeline, as the engine drives it.

    ``execute`` is the residency-IR entry point: the engine (and the
    host's prefetch hook) compile policy plans to
    :class:`~repro.core.actions.ResidencyPlan` groups, the channel
    applies each group atomically through ``MemoryState.apply`` and
    translates the actions to its physical stage ops; ``on_action``
    fires per action as its effect lands (a staged load's at commit).
    ``enqueue`` remains the ProcurePlan-shaped wrapper."""

    inflight: Mapping[str, Any]
    on_event: Optional[Callable[[float, str, str, float], None]]
    prefetch_hits: int
    prefetch_wasted: int
    prefetch_shrunk: int
    demand_loads: int
    loads_committed: int
    load_overlap_ms: float
    fits_scheduled: int

    def execute(self, plan: RA.ResidencyPlan, now_ms: float, *,
                demand: bool = ..., predicted_ms: float = ...,
                on_action: Optional[Callable[[RA.Action, float], None]]
                = ...) -> Any: ...
    def enqueue(self, plan: ProcurePlan, now_ms: float, *,
                demand: bool = ..., predicted_ms: float = ...) -> Any: ...
    def reap(self, now_ms: float) -> List[Any]: ...
    def cancel(self, app: str, now_ms: float) -> Any: ...
    def shrink_inflight(self, app: str, variant: Any,
                        now_ms: float) -> Any: ...
    def cancel_stale(self, now_ms: float,
                     delta_ms: "float | Callable[[str], float]",
                     has_queued: Callable[[str], bool]) -> int: ...
    def peek_use(self, app: str) -> Any: ...
    def take_use(self, app: str, warm: bool) -> Any: ...
    def earliest_ready(self) -> float: ...
    def close(self) -> None: ...


class ServingHost(Protocol):
    """What the engine needs from the tenant registry/facade — the
    manager for admission accounting, the tenant executors, and the
    predictor-driven prefetch hooks.  ``EdgeServer`` is the production
    implementation."""

    manager: Any  # EdgeMultiAI
    tenants: Mapping[str, TenantExecutor]

    def predict_and_preload(self, now_ms: float) -> None: ...
    def next_prefetch_trigger(self, now_ms: float) -> float: ...


@functools.lru_cache(maxsize=1024)
def kv_cache_mb(cfg: ModelConfig, batch: int, max_len: int,
                quantized: bool = False) -> float:
    """Exact decode-cache footprint in MB, from the abstract cache pytree
    (no allocation) — the same shapes ``prefill`` will materialize.
    Memoized: admission sits on the serving hot path and batch shapes
    repeat (ModelConfig is frozen/hashable)."""
    leaves = jax.tree.leaves(
        T.abstract_cache(cfg, batch, max_len, quantized=quantized))
    return sum(int(np.prod(leaf.shape)) * leaf.dtype.itemsize
               for leaf in leaves) / MB


@dataclass
class RequestResult:
    """Per-request outcome with queueing + service latency."""
    rid: int
    app: str
    arrival_ms: float
    start_ms: float
    done_ms: float
    warm: bool
    failed: bool
    bits: Optional[int]
    batch_size: int
    kv_mb: float

    @property
    def latency_ms(self) -> float:
        return self.done_ms - self.arrival_ms


@dataclass
class EngineEvent:
    """Audit-trail entry emitted at every engine state change; the
    invariant tests replay these to check ``used_mb + inflight_mb ≤
    budget_mb`` at every point in the run, not just at the end — and,
    on a sharded mesh, per-device ``weights + claims ≤ chip budget``."""
    t_ms: float
    kind: EventKind
    app: str
    kv_mb: float
    used_mb: float
    free_mb: float
    inflight_mb: float = 0.0  # background-load claims at event time
    # Per-device weights + in-flight claims when a DeviceLedger is
    # installed (sharded mesh); None on single-device runs.
    device_mb: Optional[Tuple[float, ...]] = None
    # Per-device budgets *at event time*: chip loss/recovery changes the
    # ledger mid-run, so the invariant check compares each event against
    # the budgets that held when it fired, not today's.
    device_budget_mb: Optional[Tuple[float, ...]] = None

    @property
    def audit(self) -> AuditEvent:
        """The normalized audit record (kind/time/tenant/MB delta)."""
        return AuditEvent(self.kind, self.t_ms, self.app, self.kv_mb)


Executor = Callable[[Any, Batch, Optional[dict]], np.ndarray]

# One full-batch service span covers the default request's decode budget
# (max_new=8), so a single continuous-batching decode step is the
# variant's service time divided by this.
STEPS_PER_SERVICE = 8.0


@dataclass(eq=False)
class _ActiveSeq:
    """One request mid-decode in the continuous batch: its admission
    outcome, its page-rounded KV charge, and its step progress.
    ``eq=False``: membership and removal are by identity — field
    equality would ``==``-broadcast the request's ndarray prompt."""
    req: Request
    start_ms: float
    warm: bool
    bits: Optional[int]
    kv_mb: float
    batch_size: int  # active set size at admission (stats)
    steps_done: int = 0


class ServingEngine:
    """Pulls batches from the Batcher and drives them through the
    Edge-MultiAI manager with full runtime-memory accounting.

    ``host`` is anything satisfying :class:`ServingHost`; per-batch
    execution goes through each tenant's :class:`TenantExecutor`.  The
    legacy ``executor`` callable ``(runtime, batch, extra) -> tokens``
    remains injectable (it overrides the protocol path) so
    accounting/invariant tests can run the full admit/retire protocol
    without touching XLA.
    """

    def __init__(self, host: ServingHost, *, max_batch: int = 8,
                 batch_window_ms: float = 0.0,
                 executor: Optional[Executor] = None,
                 loader: Optional[LoaderChannel] = None,
                 continuous: bool = False,
                 audit: str = "full",
                 scheduler: str = "indexed"):
        if audit not in ("full", "counters"):
            raise ValueError(
                f"audit must be 'full' or 'counters', got {audit!r}")
        if scheduler not in ("indexed", "linear"):
            raise ValueError(
                f"scheduler must be 'indexed' or 'linear', got "
                f"{scheduler!r}")
        self.host = host
        self.batcher = Batcher(max_batch=max_batch)
        self.max_batch = max_batch
        self.batch_window_ms = batch_window_ms
        # Audit level: "full" records an EngineEvent (with device/usage
        # snapshots) at every state change — required by the invariant
        # tests and the default everywhere; "counters" keeps only the
        # event count, for large-scale replays where the per-event
        # snapshots dominate the hot path.
        self.audit = audit
        # Scheduler: "indexed" (default) answers "when does the next
        # thing happen" from incremental structures (loader readiness
        # heap, memoized prediction triggers, online overlap
        # accounting); "linear" is the retained pre-refactor reference
        # that rescans on every idle step.  Both produce bit-identical
        # audit trails and stats — proven by
        # tests/test_engine_equivalence.py.
        self.scheduler = scheduler
        self.indexed = scheduler == "indexed"
        # Continuous batching: the admission unit is the request, not the
        # batch — requests join/leave the running decode per step and
        # charge/free page-granular KV (requires a KVPagePool on the
        # state; installed by EdgeServer.start when the BatchingSpec
        # asks for it).
        self.continuous = continuous
        self.results: List[RequestResult] = []
        self.events: List[EngineEvent] = []
        self.events_emitted = 0  # total, counted even under audit="counters"
        self.warm_served = 0  # incremental Σ r.warm over self.results
        self.kv_downgrades = 0  # requester shrank itself to fit its cache
        self.weight_failures = 0  # batches whose weights were unprocurable
        self._now = 0.0  # loop clock (audit events outside execute paths)
        # Maintenance-skip validity (continuous loop, indexed host):
        # True only while NOTHING invalidating happened since the last
        # executed maintenance pass — no arrival, no load commit, no
        # admission, no retirement.  Together with the host's
        # ``maint_valid_ms`` horizon it lets the loop skip maintenance
        # calls that are provably identical no-ops.
        self._maint_clean = False
        # None => route through TenantExecutor.execute (the protocol
        # path); a callable overrides it (legacy injection point).
        self._executor = executor
        # Background loading pipeline (None = reactive PR-1 behavior:
        # every load is enacted synchronously inside the admit path and
        # charges the loop clock).
        self.loader = loader
        if loader is not None:
            loader.on_event = self._loader_event
            # Select the loader's readiness heap over its linear scan
            # (both return the identical min; protocol fakes that lack
            # the attribute simply keep scanning).
            try:
                loader.indexed_ready = self.indexed
            except AttributeError:
                pass
        # Elastic mesh controller (chip loss & recovery); installed by
        # EdgeServer.start when the config carries a FaultSpec.  Polled
        # in the maintenance pass and folded into the idle wake-up.
        self.elastic = None
        # Execution spans (start, end, app) inside the current loader
        # window — used to measure how much of each load was hidden
        # behind other tenants' prefill/decode.  Spans append in loop
        # order, so their end times are monotone non-decreasing and the
        # prune in _reap_loads is a prefix popleft.
        self._spans: Deque[Tuple[float, float, str]] = deque()
        # Cluster-tier local clock: where cluster_advance left this
        # server's loop (a batch may have run past the last horizon).
        self._cluster_now = 0.0

    @property
    def audit_trail(self) -> List[AuditEvent]:
        """Every event as a normalized :class:`AuditEvent` record."""
        return [ev.audit for ev in self.events]

    @property
    def server(self) -> ServingHost:
        """Deprecated alias for :attr:`host` (pre-protocol name)."""
        return self.host

    @property
    def kv_rejections(self) -> int:
        """Batches bounced for cache pressure — the manager's counter is
        the single source of truth (it performs the rejection)."""
        mgr = self.host.manager
        return mgr.kv_rejections if mgr else 0

    # ------------------------------------------------------------------
    def _event(self, t_ms: float, kind: str, app: str, kv_mb: float) -> None:
        self.events_emitted += 1
        if self.audit != "full":
            return  # counters level: count the event, skip the snapshot
        st = self.host.manager.state
        self.events.append(EngineEvent(
            t_ms, EventKind(kind), app, kv_mb, st.used_mb, st.free_mb,
            st.inflight_mb,
            device_mb=(st.devices.device_used()
                       if st.devices is not None else None),
            device_budget_mb=(st.devices.budgets_mb
                              if st.devices is not None else None)))

    def _loader_event(self, t_ms: float, kind: str, app: str,
                      mb: float) -> None:
        """Mirror loader lifecycle transitions into the audit trail."""
        self._event(t_ms, kind, app, mb)

    def _wire_audit(self) -> None:
        """Route the state's KV over-release audit hook into the event
        log (timing is loop-clock granular)."""
        mgr = self.host.manager
        if mgr is not None and mgr.state.on_audit is None:
            mgr.state.on_audit = (
                lambda kind, app, mb: self._event(self._now, kind, app, mb))

    def submit(self, req: Request, now_ms: float) -> None:
        """Enqueue a request; feeds the tenant's RNN arrival predictor."""
        req.arrival_ms = now_ms if req.arrival_ms == 0.0 else req.arrival_ms
        self._maint_clean = False  # new arrival: predictions shift
        self.host.tenants[req.app].predictor.observe_request(
            req.arrival_ms)
        self.batcher.submit(req)
        self._event(req.arrival_ms, "submit", req.app, 0.0)

    # ------------------------------------------------------------------
    def execute_batch(self, batch: Batch, now_ms: float,
                      extra: Optional[dict] = None, *,
                      charge_load: bool = False
                      ) -> Tuple[List[RequestResult], float,
                                 Optional[np.ndarray]]:
        """One admit→(load/evict)→prefill→decode→retire cycle.

        Returns the per-request results, the service time in ms (wall
        clock of the real model execution, plus the variant's load time
        when ``charge_load`` is set and the admit cold-loaded — the
        reactive engine's synchronous load stalls the whole loop, and
        the virtual clock must say so), and the generated tokens (None
        when the batch was rejected).

        When a background loader is attached, a batch whose weights were
        staged by a demand-triggered load is admitted ``demand_cold``:
        the request waited out the transfer, so the serve is a cold
        start even though the weights are resident by admission time.
        """
        mgr = self.host.manager
        assert mgr is not None, "server.start() before engine use"
        self._wire_audit()
        self._now = now_ms
        tr = self.host.tenants[batch.app]
        total_len = batch.prompts.shape[1] + batch.max_new
        kv_mb = kv_cache_mb(tr.cfg, len(batch.requests), total_len)
        if self.loader is not None:
            # Sync callers (serve()) don't defer on the loader the way
            # run_trace does: commit whatever is virtually complete, and
            # if this tenant still has a load mid-flight, release its
            # claim and procure synchronously — otherwise an admission-
            # path upgrade double-tracks the staged variant and the
            # in-flight charge leaks forever.
            self._reap_loads(now_ms)
            if batch.app in self.loader.inflight:
                self.loader.cancel(batch.app, now_ms)
        staged = (self.loader.peek_use(batch.app)
                  if self.loader is not None else None)
        adm: BatchAdmission = mgr.admit_batch(
            batch.app, now_ms, kv_mb,
            demand_cold=staged.demand if staged is not None else False)
        if adm.self_downgraded:
            self.kv_downgrades += 1
        if adm.failed:
            if staged is not None:
                # Consume the staged-load record even on rejection — left
                # behind it would mark the tenant's *next* (genuinely
                # warm) admission demand-cold.
                self.loader.take_use(batch.app, False)
            if not adm.kv_rejected:
                self.weight_failures += 1
            self._event(now_ms, "reject", batch.app, kv_mb)
            # A rejected request was never served: not warm, failed.
            results = [
                RequestResult(r.rid, batch.app, r.arrival_ms, now_ms,
                              now_ms, False, True, None,
                              len(batch.requests), 0.0)
                for r in batch.requests]
            self.results.extend(results)
            return results, 0.0, None
        if staged is not None:
            self.loader.take_use(batch.app, adm.warm)
        # A cold serve whose load happened synchronously inside
        # admit_batch (reactive mode, or a loader-mode admission that
        # slipped past demand staging — e.g. its plan was unfundable and
        # desperation loaded on the spot) stalled the loop thread for
        # the transfer, so the virtual clock is charged for it.  A
        # demand-staged cold (``staged``) already paid in queue time.
        sync_cold = charge_load or (self.loader is not None
                                    and staged is None)
        load_pen_ms = (tr.zoo.by_bits(adm.bits).load_ms
                       if sync_cold and not adm.warm else 0.0)
        self._event(now_ms, "admit", batch.app, adm.kv_mb)
        t0 = time.monotonic()
        virtual_ms: Optional[float] = None
        try:
            if self._executor is not None:  # legacy injected callable
                tokens = self._executor(tr, batch, extra)
            else:  # TenantExecutor protocol: tokens + optional sim time
                tokens, virtual_ms = tr.execute(batch, extra)
        except BaseException:
            # Execution crashed (XLA OOM, bad inputs): release the cache
            # charge so it doesn't leak, balance the audit trail, and
            # record the requests as failed so callers that catch the
            # exception and keep serving don't lose them from stats.
            service_ms = (time.monotonic() - t0) * 1e3
            done_ms = now_ms + service_ms
            mgr.release_kv(batch.app, adm.kv_mb)
            self._event(done_ms, "retire", batch.app, -adm.kv_mb)
            self.results.extend(
                RequestResult(r.rid, batch.app, r.arrival_ms, now_ms,
                              done_ms, False, True, None,
                              len(batch.requests), 0.0)
                for r in batch.requests)
            raise
        service_ms = (virtual_ms if virtual_ms is not None
                      else (time.monotonic() - t0) * 1e3) + load_pen_ms
        # Per-request retirement: a short request finishes — and returns
        # its share of the cache — when *its* decode budget is spent, not
        # when the batch's longest request retires.  The decode itself
        # still runs to batch.max_new (padding is compute); the memory
        # charge does not.  Shares release in finish order; the longest
        # request carries the float residue so the batch drains to
        # exactly zero, and its release is the batch's "retire" event
        # (earlier ones are "free_kv") so admits and retires stay 1:1
        # in the audit trail.
        B = len(batch.requests)
        decode_ms = service_ms - load_pen_ms
        order = sorted(range(B),
                       key=lambda j: (batch.requests[j].max_new, j))
        results: List[Optional[RequestResult]] = [None] * B
        released = 0.0
        for pos, j in enumerate(order):
            r = batch.requests[j]
            frac = r.max_new / batch.max_new if batch.max_new > 0 else 1.0
            r_done = now_ms + load_pen_ms + decode_ms * frac
            last = pos == B - 1
            share = (max(0.0, adm.kv_mb - released) if last
                     else adm.kv_mb / B)
            released += share
            mgr.release_kv(batch.app, share)
            self._event(r_done, "retire" if last else "free_kv",
                        batch.app, -share)
            results[j] = RequestResult(
                r.rid, batch.app, r.arrival_ms, now_ms, r_done,
                adm.warm, False, adm.bits, B, share)
        if adm.warm:
            self.warm_served += B
        self.results.extend(results)
        return results, service_ms, tokens

    # ------------------------------------------------------------------
    def _stage_demand_loads(self, now: float) -> None:
        """Cold tenants with queued work get their load staged off the
        loop: plan a variant (with the waiting batch's cache need as a
        planning charge) and hand it to the background loader.  The
        batch itself stays queued — ``run_trace`` skips the tenant until
        the load commits, while everyone else keeps prefilling/decoding.
        If no variant fits, the batch is admitted anyway so the failure
        is counted the normal way."""
        mgr = self.host.manager
        # queued_apps() is a live keys view (no per-step copy); nothing
        # in this loop inserts or drops queue keys, so iterating it
        # directly is safe.
        for app in self.batcher.queued_apps():
            if app in self.loader.inflight:
                continue
            if mgr.state.tenants[app].loaded is not None:
                continue
            q = list(itertools.islice(self.batcher.queues[app],
                                      self.max_batch))
            total_len = (max(len(r.prompt) for r in q)
                         + max(r.max_new for r in q))
            cfg = self.host.tenants[app].cfg
            # Head batch as queued right now, plus the full-batch bound a
            # burst could fill in before the load commits — the policy's
            # demand_charge hook picks which one to plan around.
            demand = DemandContext(
                kv_head_mb=kv_cache_mb(cfg, len(q), total_len),
                kv_full_mb=kv_cache_mb(cfg, self.max_batch, total_len),
                queue_depth=self.batcher.queued(app),
                max_batch=self.max_batch)
            plan = mgr.plan_demand(app, now, demand=demand)
            if plan is None:
                # Speculation yields to demand — but gradually: first
                # shrink predictor-driven prefetches to their smallest
                # variant (the guess keeps its warm start, degraded, and
                # most of the claim comes back), then cancel outright
                # (least-credible prediction first) until the real
                # request's load becomes fundable — speculative claims
                # must never starve actual queued work.
                def guesses():
                    return sorted(
                        (a for a, ld in self.loader.inflight.items()
                         if not ld.demand),
                        key=lambda a: -self.loader.inflight[a]
                        .predicted_ms)
                for guess in guesses():
                    small = mgr.state.tenants[guess].zoo.smallest
                    if self.loader.shrink_inflight(guess, small,
                                                   now) is None:
                        continue
                    plan = mgr.plan_demand(app, now, demand=demand)
                    if plan is not None:
                        break
                if plan is None:
                    for guess in guesses():
                        self.loader.cancel(guess, now)
                        plan = mgr.plan_demand(app, now, demand=demand)
                        if plan is not None:
                            break
            if plan is not None:
                # Compile the policy's plan to the residency IR and hand
                # it to the channel: evictions + the staged load commit
                # as one atomic group (a stale plan enacts *nothing*).
                self.loader.execute(
                    RA.ResidencyPlan(RA.procure_actions(plan, staged=True)),
                    now, demand=True)

    def _note_span(self, t0: float, t1: float, app: str) -> None:
        """Record one retired execution span; on the indexed path, also
        fold it into every in-flight load's online overlap accumulator.
        The accumulator adds the identical per-interval contributions,
        in the identical span order, that the reap-time scan would sum
        — same float additions, bit-identical ``load_overlap_ms``."""
        self._spans.append((t0, t1, app))
        if not self.indexed or self.loader is None:
            return
        for ld in self.loader.inflight.values():
            # Protocol fakes without the accumulator fields simply keep
            # the reap-time scan (their records carry no busy values).
            if (ld.app == app or not getattr(ld, "staging", False)
                    or not hasattr(ld, "ol_key")):
                continue
            key = (ld.t_enqueue_ms, ld.ready_ms)
            if ld.ol_key != key:
                # First span since this load's window was (re)opened:
                # no earlier span can intersect it (spans retire with
                # end ≤ the loop clock that opened the window), so the
                # accumulator starts at zero.
                shards = getattr(ld, "shards", None)
                ld.ol_key = key
                ld.ol_ivals = ([(sh.t_start_ms, sh.ready_ms)
                                for sh in shards] if shards else [key])
                ld.ol_busy = [0.0] * len(ld.ol_ivals)
            for k, (a0, a1) in enumerate(ld.ol_ivals):
                if t1 > a0 and t0 < a1:
                    ld.ol_busy[k] += min(t1, a1) - max(t0, a0)

    def _reap_loads(self, now: float) -> None:
        """Commit loads whose virtual transfer has finished and measure
        how much of each load interval was hidden behind *other*
        tenants' execution — the paper's overlap claim, quantified.
        Sharded loads measure per shard interval (which also credits the
        landed shards of a cancelled load: that transfer was real and
        really was hidden); single-stream loads over the whole load.

        A record carrying online-accumulated busy values (indexed
        scheduler) skips the span scan; records without them (linear
        reference path, protocol fakes, loads that saw no spans) measure
        by scanning the retained spans exactly as before."""
        for rec in self.loader.reap(now):
            self._maint_clean = False  # a commit changed residency
            intervals = (rec.shard_intervals
                         or ((rec.t_enqueue_ms, rec.t_ready_ms,
                              rec.load_ms),))
            busies = getattr(rec, "overlap_busy", None)
            overlap = 0.0
            if busies is not None:
                for (t0, t1, cap), busy in zip(intervals, busies):
                    overlap += min(busy, cap)
            else:
                for t0, t1, cap in intervals:
                    busy = sum(min(e, t1) - max(s, t0)
                               for s, e, a in self._spans
                               if a != rec.app and e > t0 and s < t1)
                    overlap += min(busy, cap)
            rec.overlap_ms = overlap
            self.loader.load_overlap_ms += rec.overlap_ms
        horizon = min((ld.t_enqueue_ms
                       for ld in self.loader.inflight.values()),
                      default=now)
        # Span ends are monotone (appended in loop order), so pruning
        # everything that ended at/before the horizon is a prefix pop.
        spans = self._spans
        while spans and spans[0][1] <= horizon:
            spans.popleft()

    def run_trace(self, requests: Sequence[Request]) -> dict:
        """Closed-loop trace replay: arrivals enter the batcher at their
        trace timestamps; the single engine pulls the next batch whenever
        it is idle, waiting out the batching window when the queue is
        short and another arrival is imminent.

        With a background loader attached (the default via
        ``EdgeServer``), no weight transfer ever blocks the loop:
        predicted-next tenants are prefetched ahead of their requests,
        cold tenants' demand loads stage while other tenants execute,
        and a tenant is only deferred until its own load commits.
        Without a loader this is the reactive PR-1 engine — every cold
        load happens synchronously inside the admit path and is charged
        to the loop clock, stalling every queued tenant behind it.

        With ``continuous=True`` the batch-scalar loop is replaced by
        :meth:`_run_continuous`: requests join and leave the running
        decode batch per step against the paged KV pool.
        """
        self._wire_audit()
        if self.continuous:
            return self._run_continuous(requests)
        pending = sorted(requests, key=lambda r: r.arrival_ms)
        i, n, now = 0, len(pending), 0.0
        while i < n or self.batcher.pending():
            if not self.batcher.pending():
                t_next = pending[i].arrival_ms if i < n else math.inf
                if self.loader is not None:
                    # Idle wake-ups: a pending load commit, or a tenant's
                    # prefetch trigger (t_pred − Δ − θ) — sleeping past
                    # either would turn a hideable load into a stall.
                    t_next = min(t_next, self.loader.earliest_ready(),
                                 self.host.next_prefetch_trigger(now))
                if self.elastic is not None:
                    # A scheduled chip fault wakes the loop even when it
                    # is otherwise idle — drains fire at their instant.
                    t_next = min(t_next, self.elastic.next_event_ms())
                now = max(now, t_next)
            while i < n and pending[i].arrival_ms <= now:
                self.submit(pending[i], pending[i].arrival_ms)
                i += 1
            # Hold a short batch for an imminent arrival (amortization).
            if (self.batcher.pending() < self.max_batch and i < n
                    and pending[i].arrival_ms <= now + self.batch_window_ms):
                now = pending[i].arrival_ms
                continue
            if self.loader is not None:
                self._reap_loads(now)
                if self.elastic is not None:
                    self._now = now
                    self.elastic.poll(now)
                self.host.predict_and_preload(now)
                self._stage_demand_loads(now)
                batch = self.batcher.next_batch(
                    exclude=self.loader.inflight)
                if batch is None:
                    # Every queued tenant is awaiting its own load (or
                    # nothing is queued at all): jump to the earliest
                    # commit or the next arrival — the loop idles, it
                    # does not block on a transfer.
                    t_next = self.loader.earliest_ready()
                    if i < n:
                        t_next = min(t_next, pending[i].arrival_ms)
                    if self.elastic is not None:
                        t_next = min(t_next,
                                     self.elastic.next_event_ms())
                    if t_next is not math.inf:
                        now = max(now, t_next)
                        continue
                    break
            else:
                batch = self.batcher.next_batch()
            t0 = now
            _, service_ms, _ = self.execute_batch(
                batch, now, charge_load=self.loader is None)
            now += service_ms
            self._note_span(t0, now, batch.app)
        if self.loader is not None:
            # Trace drained: commit whatever is still staging so the
            # audit trail balances and residency reflects the weights.
            self._reap_loads(math.inf)
        return self.stats()

    # ------------------------------------------------------------------
    # Cluster tier: the shared-clock protocol EdgeCluster drives
    # ------------------------------------------------------------------
    def cluster_submit(self, req: Request) -> None:
        """Cluster-tier entry: enqueue a routed request at its own
        arrival timestamp.  The cluster loop owns the global clock and
        pumps arrivals itself, so unlike :meth:`run_trace` there is no
        trace replay here — one call per routed request.  The local
        clock advances to the arrival (an idle server was simply idle
        until now; a busy one is already past it), so queued work never
        executes before it arrived."""
        self.submit(req, req.arrival_ms)
        self._cluster_now = max(self._cluster_now, req.arrival_ms)

    def cluster_advance(self, horizon_ms: float) -> float:
        """Run this server's loop up to — exclusive of — ``horizon_ms``.

        The same cycle as :meth:`run_trace` (maintenance pass, pull a
        batch, execute, advance the local clock by its service time),
        except arrivals come from :meth:`cluster_submit` between calls
        instead of an internal trace.  Only work *starting* strictly
        before the horizon runs, so a request routed at ``t`` by the
        cluster loop is visible before any same-instant batch is pulled
        — the exact submit-before-batch ordering ``run_trace`` has for
        same-timestamp arrivals.  The local clock may end past the
        horizon (a batch's service time is indivisible); it never ends
        before a completed horizon.

        Returns this server's next internal event time (queued work's
        resume instant, a pending load commit, a prefetch trigger, or a
        scheduled chip fault) — ``math.inf`` when fully drained.  The
        cluster loop folds these into its global clock.
        """
        self._wire_audit()
        now = self._cluster_now
        while True:
            if not self.batcher.pending():
                t_next = math.inf
                if self.loader is not None:
                    t_next = min(self.loader.earliest_ready(),
                                 self.host.next_prefetch_trigger(now))
                if self.elastic is not None:
                    t_next = min(t_next, self.elastic.next_event_ms())
                if not t_next < horizon_ms:
                    break
                now = max(now, t_next)
            elif not now < horizon_ms:
                t_next = now  # runnable work at/after the horizon
                break
            if self.loader is not None:
                self._reap_loads(now)
            if self.elastic is not None:
                self._now = now
                self.elastic.poll(now)
            if self.loader is not None:
                self.host.predict_and_preload(now)
                self._stage_demand_loads(now)
                batch = self.batcher.next_batch(
                    exclude=self.loader.inflight)
            else:
                batch = self.batcher.next_batch()
            if batch is None:
                if not self.batcher.pending():
                    continue  # maintenance consumed the wake-up;
                    # recompute the idle candidates from the top
                # Every queued tenant is awaiting its own load.
                t_next = math.inf
                if self.loader is not None:
                    t_next = self.loader.earliest_ready()
                if self.elastic is not None:
                    t_next = min(t_next, self.elastic.next_event_ms())
                if not t_next < horizon_ms:
                    break
                now = max(now, t_next)
                continue
            t0 = now
            _, service_ms, _ = self.execute_batch(
                batch, now, charge_load=self.loader is None)
            now += service_ms
            self._note_span(t0, now, batch.app)
        self._cluster_now = now
        return t_next

    def cluster_finish(self) -> None:
        """Terminal pass once the cluster loop drained every server:
        commit whatever is still staging so the audit trail balances."""
        if self.loader is not None:
            self._reap_loads(math.inf)

    # ------------------------------------------------------------------
    # Continuous batching: the request is the admission unit
    # ------------------------------------------------------------------
    def _step_ms(self, app: str, n_active: int) -> float:
        """One decode step's virtual time for ``app``'s active set: the
        loaded variant's service span over the nominal decode budget.
        A tenant executor may override by exposing ``step_ms``."""
        tr = self.host.tenants[app]
        step = getattr(tr, "step_ms", None)
        if callable(step):
            return step(n_active)
        loaded = self.host.manager.state.tenants[app].loaded
        base = loaded.load_ms / LOAD_OVER_INFER if loaded else 1.0
        return max(base / STEPS_PER_SERVICE, 1e-6)

    def _requeue_preempted(self, active: Dict[str, List[_ActiveSeq]],
                           now: float) -> None:
        """Sequences whose pages were evicted as admission victims lose
        their decode progress and go back to the head of their queue
        (their pages are already freed by the manager's plan)."""
        for vapp, seq in self.host.manager.take_preempted():
            seqs = active.get(vapp, [])
            victim = next((s for s in seqs if s.req.rid == seq), None)
            if victim is None:
                continue
            seqs.remove(victim)
            self._event(now, "preempt", vapp, -victim.kv_mb)
            self.batcher.queues[vapp].appendleft(victim.req)

    def _join_requests(self, active: Dict[str, List[_ActiveSeq]],
                       now: float) -> float:
        """Admit queued requests into the running decode batch, FIFO per
        tenant, until each tenant's active set is full or an admission
        fails.  Each request charges its own page-rounded KV need; a
        rejected request is dropped and counted like a rejected batch.
        Returns the (possibly advanced) loop clock — a synchronous cold
        load inside an admit stalls the loop, exactly like the reactive
        batch engine."""
        mgr = self.host.manager
        pool = mgr.state.kv_pool
        inflight = self.loader.inflight if self.loader is not None else {}
        if self.batcher.queues:
            # Queued work may admit (memory mutates) or stay queued
            # (skip is blocked anyway): conservatively invalidate.
            self._maint_clean = False
        # Snapshot, not the live view: _requeue_preempted below can
        # insert brand-new queue keys mid-iteration (a preempted victim
        # whose tenant had drained its queue), which would blow up a
        # live keys-view iteration.
        for app in list(self.batcher.queued_apps()):
            if app in inflight:
                continue  # weights mid-staging: join after the commit
            tr = self.host.tenants[app]
            while (self.batcher.queues.get(app)
                   and len(active.setdefault(app, [])) < self.max_batch):
                req = self.batcher.queues[app][0]
                raw = kv_cache_mb(tr.cfg, 1, len(req.prompt) + req.max_new)
                need = (pool.pages_for(raw) * pool.page_mb
                        if pool is not None else raw)
                staged = (self.loader.peek_use(app)
                          if self.loader is not None else None)
                adm = mgr.admit_batch(
                    app, now, need,
                    demand_cold=staged.demand if staged is not None
                    else False,
                    seq=req.rid if pool is not None else None)
                # Admission may have preempted other tenants' sequences
                # (cold-page victims): drop them from the active sets
                # and requeue before touching this queue further.
                self._requeue_preempted(active, now)
                if adm.self_downgraded:
                    self.kv_downgrades += 1
                if adm.failed:
                    if staged is not None:
                        self.loader.take_use(app, False)
                    if not adm.kv_rejected:
                        self.weight_failures += 1
                    self.batcher.queues[app].popleft()
                    self._event(now, "reject", app, need)
                    self.results.append(RequestResult(
                        req.rid, app, req.arrival_ms, now, now, False,
                        True, None, len(active[app]), 0.0))
                    continue
                if staged is not None:
                    self.loader.take_use(app, adm.warm)
                if not adm.warm and (self.loader is None
                                     or staged is None):
                    # Synchronous cold load inside the admit: the loop
                    # clock pays for the transfer (reactive semantics).
                    now += tr.zoo.by_bits(adm.bits).load_ms
                self.batcher.queues[app].popleft()
                self._event(now, "admit", app, adm.kv_mb)
                active[app].append(_ActiveSeq(
                    req=req, start_ms=now, warm=adm.warm, bits=adm.bits,
                    kv_mb=adm.kv_mb, batch_size=len(active[app]) + 1))
            if not self.batcher.queues.get(app):
                self.batcher.queues.pop(app, None)
        return now

    def _retire_seq(self, s: _ActiveSeq, now: float) -> None:
        """A sequence finished its decode budget: free its pages *now*
        (not when the batch's longest request retires — there is no
        batch anymore) and record the result."""
        mgr = self.host.manager
        pool = mgr.state.kv_pool
        self._maint_clean = False  # the freed cache changes free_mb
        mgr.release_kv(s.req.app, s.kv_mb,
                       seq=s.req.rid if pool is not None else None)
        self._event(now, "retire", s.req.app, -s.kv_mb)
        self.warm_served += s.warm
        self.results.append(RequestResult(
            s.req.rid, s.req.app, s.req.arrival_ms, s.start_ms, now,
            s.warm, False, s.bits, s.batch_size, s.kv_mb))

    def _run_continuous(self, requests: Sequence[Request]) -> dict:
        """Continuous-batching trace replay.  Per iteration: pump due
        arrivals, run the loader maintenance hooks, join queued requests
        into the active sets (request-granular admission against free KV
        pages), then run ONE decode step for the tenant with the largest
        active set — sequences whose budget is spent retire and free
        their pages immediately, so the next join admits against the
        reclaimed pages mid-"batch".  Virtual-time, deterministic."""
        pending = sorted(requests, key=lambda r: r.arrival_ms)
        i, n, now = 0, len(pending), 0.0
        active: Dict[str, List[_ActiveSeq]] = {}
        while (i < n or self.batcher.pending()
               or any(active.values())):
            self._now = now
            while i < n and pending[i].arrival_ms <= now:
                self.submit(pending[i], pending[i].arrival_ms)
                i += 1
            if self.loader is not None:
                self._reap_loads(now)
                if self.elastic is not None:
                    self.elastic.poll(now)
                    self._requeue_preempted(active, now)
                # Maintenance skip: the host's last fully-skipped pass
                # published a horizon (``maint_valid_ms``) before which
                # its decisions cannot flip.  If nothing invalidating
                # happened since (``_maint_clean``), no work is queued
                # or staging, fits land synchronously (no background
                # thread can mutate a predictor mid-skip), and no
                # elastic controller can fire, the call is provably the
                # identical no-op — don't make it.
                host = self.host
                if not (self._maint_clean and self.elastic is None
                        and now < getattr(host, "maint_valid_ms",
                                          -math.inf)
                        and getattr(host, "sync_predictor_fits", False)
                        and not self.batcher.queues
                        and not self.loader.inflight):
                    host.predict_and_preload(now)
                    self._maint_clean = True
                self._stage_demand_loads(now)
            now = self._join_requests(active, now)
            apps = [a for a in sorted(active) if active[a]]
            if not apps:
                # Nothing decoding: jump to the next arrival, the
                # earliest load commit, or a prefetch trigger.
                t_next = pending[i].arrival_ms if i < n else math.inf
                if self.loader is not None:
                    t_next = min(t_next, self.loader.earliest_ready(),
                                 self.host.next_prefetch_trigger(now))
                if self.elastic is not None:
                    t_next = min(t_next, self.elastic.next_event_ms())
                if t_next is math.inf:
                    break
                now = max(now, t_next)
                continue
            app = max(apps, key=lambda a: (
                len(active[a]),
                -min(s.start_ms for s in active[a]), a))
            t0 = now
            now += self._step_ms(app, len(active[app]))
            self._note_span(t0, now, app)
            finished = []
            for s in active[app]:
                s.steps_done += 1
                if s.steps_done >= s.req.max_new:
                    finished.append(s)
            if finished:
                # Identity, not equality: _ActiveSeq carries the request
                # (whose prompt is an ndarray — == broadcasts).
                gone = {id(s) for s in finished}
                active[app] = [s for s in active[app]
                               if id(s) not in gone]
                for s in finished:
                    self._retire_seq(s, now)
        if self.loader is not None:
            self._reap_loads(math.inf)
        return self.stats()

    async def run_async(self, requests: Sequence[Request]) -> dict:
        """Asyncio entry point: replays the trace off the event loop."""
        return await asyncio.to_thread(self.run_trace, requests)

    # ------------------------------------------------------------------
    def stats(self) -> ServingStats:
        """Aggregate + per-tenant latency percentiles and throughput,
        plus the prefetch pipeline's hit/waste/overlap counters, as a
        typed :class:`~repro.serving.stats.ServingStats` (fields of
        unattached subsystems stay ``None`` and drop out of
        ``to_dict()``)."""
        st = self.host.manager.state
        tens = st.tenants.values()
        total_req = sum(t.requests for t in tens)
        kw: dict = {
            "requests": len(self.results),
            "kv_downgrades": self.kv_downgrades,
            "kv_rejections": self.kv_rejections,
            "weight_failures": self.weight_failures,
            # Clamped KV over-release drift (0.0 in a healthy run; the
            # strict_kv flag turns any drift into a hard failure).
            "kv_overrelease_mb": st.kv_overrelease_mb,
            # Fraction of batch admissions arriving inside a predicted
            # window (the manager's on_request unit — one count per
            # admitted batch, not per request) — the live measure of
            # predictor leverage.
            "prediction_hit_rate": (
                sum(t.requests - t.unexpected for t in tens) / total_req
                if total_req else 0.0),
            "per_tenant": {},
            "warm_ratio": 0.0,
        }
        if self.loader is not None:
            kw.update(
                prefetch_hits=self.loader.prefetch_hits,
                prefetch_wasted=self.loader.prefetch_wasted,
                prefetch_shrunk=self.loader.prefetch_shrunk,
                demand_loads=self.loader.demand_loads,
                loads_committed=self.loader.loads_committed,
                load_overlap_ms=self.loader.load_overlap_ms,
                fits_scheduled=self.loader.fits_scheduled)
            shards = getattr(self.loader, "shards_landed", None)
            if shards is not None:
                kw["shards_landed"] = shards
            # Wire accounting (getattr: protocol fakes may predate it).
            wire = getattr(self.loader, "wire_mb_staged", None)
            if wire is not None:
                kw["wire_mb_staged"] = wire
                kw["inplace_downgrades"] = getattr(
                    self.loader, "inplace_downgrades", 0)
        devices = st.devices
        if devices is not None:
            # Cross-device victim migrations (admission + loader paths;
            # the ledger counts them where the moves commit).
            kw["shards_migrated"] = devices.shards_migrated
        if st.kv_pool is not None:
            kw.update(
                kv_page_mb=st.kv_pool.page_mb,
                kv_pages_total=st.kv_pool.n_pages,
                kv_pages_used=st.kv_pool.used_pages,
                kv_preemptions=self.host.manager.kv_preemptions)
        if self.elastic is not None:
            kw.update(
                chips_lost=self.elastic.chips_lost,
                chips_recovered=self.elastic.chips_recovered,
                drain_migrations=self.elastic.drain_migrations,
                drain_downgrades=self.elastic.drain_downgrades,
                repromotions=self.elastic.repromotions)
        if not self.results:
            return ServingStats(**kw)
        # One pass over results: warm count, the global trace span, and
        # the per-tenant buckets all come out of a single walk instead
        # of a fresh min/max/filter scan per aggregate and per tenant.
        warm = 0
        origin = math.inf
        t_end = -math.inf
        by_app: Dict[str, List[RequestResult]] = {}
        for r in self.results:
            warm += r.warm
            origin = min(origin, r.arrival_ms)
            t_end = max(t_end, r.done_ms)
            by_app.setdefault(r.app, []).append(r)
        kw["warm_ratio"] = warm / len(self.results)
        span_ms = t_end - origin
        kw["requests_per_sec"] = (
            len(self.results) / (span_ms / 1e3) if span_ms > 0 else 0.0)
        for app in sorted(by_app):
            rs = by_app[app]
            ok = [r.latency_ms for r in rs if not r.failed]
            lat = (dict(zip(
                ("p50_ms", "p95_ms", "p99_ms"),
                (float(x) for x in np.percentile(ok, (50, 95, 99)))))
                if ok else {"p50_ms": float("inf"),
                            "p95_ms": float("inf"),
                            "p99_ms": float("inf")})
            t_span = (max(r.done_ms for r in rs)
                      - min(r.arrival_ms for r in rs))
            kw["per_tenant"][app] = {
                "requests": len(rs),
                "warm_ratio": sum(r.warm for r in rs) / len(rs),
                "fail_ratio": sum(r.failed for r in rs) / len(rs),
                "mean_batch": float(np.mean([r.batch_size for r in rs])),
                "throughput_rps": (len(rs) / (t_span / 1e3)
                                   if t_span > 0 else 0.0),
                **lat,
            }
        return ServingStats(**kw)

    def check_event_invariant(self, budget_mb: Optional[float] = None
                              ) -> None:
        """Every recorded event must respect the memory budget —
        committed memory *and* in-flight background-load claims; on a
        sharded mesh, every chip's weights + shard claims must respect
        the per-device budget *that held at event time* (chip loss and
        recovery change the ledger mid-run)."""
        if self.audit != "full":
            raise RuntimeError(
                "check_event_invariant needs audit='full' (per-event "
                f"usage snapshots); this engine runs audit={self.audit!r}")
        budget = (budget_mb if budget_mb is not None
                  else self.host.manager.state.budget_mb)
        for ev in self.events:
            if ev.used_mb + ev.inflight_mb > budget + 1e-6:
                raise AssertionError(
                    f"budget exceeded at t={ev.t_ms:.1f}ms "
                    f"({ev.kind} {ev.app}): {ev.used_mb:.2f}MB "
                    f"+ {ev.inflight_mb:.2f}MB in-flight "
                    f"> {budget:.2f}MB")
            if ev.device_mb is None or ev.device_budget_mb is None:
                continue
            for d, mb in enumerate(ev.device_mb):
                if mb > ev.device_budget_mb[d] + 1e-6:
                    raise AssertionError(
                        f"device {d} over budget at t={ev.t_ms:.1f}ms "
                        f"({ev.kind} {ev.app}): {mb:.2f}MB "
                        f"> {ev.device_budget_mb[d]:.2f}MB")


# ---------------------------------------------------------------------------
# Trace-driven load generation (reuses the simulator's arrival process)
# ---------------------------------------------------------------------------
def trace_from_workload(wl: Workload, cfgs: Dict[str, ModelConfig], *,
                        seed: int = 0, prompt_len: Tuple[int, int] = (4, 12),
                        max_new: int = 8) -> List[Request]:
    """Materialize a simulator :class:`Workload` as real serving requests:
    same Poisson per-tenant timestamps, random prompts per tenant vocab."""
    rng = np.random.default_rng(seed)
    reqs = []
    for t, app in wl.requests:
        plen = int(rng.integers(*prompt_len))
        prompt = rng.integers(
            0, cfgs[app].vocab_size, plen).astype(np.int32)
        reqs.append(Request(app=app, prompt=prompt, max_new=max_new,
                            arrival_ms=t))
    return reqs


def fast_trace_from_workload(wl: Workload, cfgs: Dict[str, ModelConfig],
                             *, seed: int = 0,
                             prompt_len: Tuple[int, int] = (4, 12),
                             max_new: int = 8) -> List[Request]:
    """Vectorized materializer for large replays: one batched draw for
    every prompt length, prompt arrays shared from a per-(app, length)
    pool.  The sim executor's virtual service time reads only the
    prompt *length*, so sharing the array is behaviour-identical there;
    don't use this with the real executor, where token content reaches
    the model.  Draw order differs from :func:`trace_from_workload`
    (whose per-request order is contractual), so this is a separate
    entry point, not a fast path inside it."""
    rng = np.random.default_rng(seed)
    lens = rng.integers(*prompt_len, size=len(wl.requests))
    pool: Dict[Tuple[str, int], np.ndarray] = {}
    reqs = []
    for (t, app), plen in zip(wl.requests, lens):
        key = (app, int(plen))
        prompt = pool.get(key)
        if prompt is None:
            prompt = pool[key] = rng.integers(
                0, cfgs[app].vocab_size, int(plen)).astype(np.int32)
        reqs.append(Request(app=app, prompt=prompt, max_new=max_new,
                            arrival_ms=t))
    return reqs


def poisson_trace(cfgs: Dict[str, ModelConfig], *,
                  requests_per_app: int = 20,
                  mean_iat_ms: float = 2000.0,
                  deviation: float = 0.3,
                  seed: int = 0,
                  prompt_len: Tuple[int, int] = (4, 12),
                  max_new: int = 8) -> Tuple[List[Request], Workload]:
    """Convenience: generate_workload → requests, returning both so the
    caller can feed predictions to the manager if desired."""
    wl = generate_workload(list(cfgs), requests_per_app=requests_per_app,
                           mean_iat_ms=mean_iat_ms, deviation=deviation,
                           seed=seed)
    return trace_from_workload(wl, cfgs, seed=seed,
                               prompt_len=prompt_len, max_new=max_new), wl
