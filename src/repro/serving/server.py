"""Multi-tenant serving runtime: Edge-MultiAI managing *real* JAX models.

This is where the paper's framework meets actual weights: each tenant is an
LM architecture with a real zoo (bf16 / int8 / int4 variants built by
``repro.quant``), "storage" is host RAM (numpy), "memory" is the device
budget tracked in MB of true buffer bytes, and load/evict callbacks move
weights with ``jax.device_put``.  The manager decides *which variant is
resident when*; serving runs true prefill/decode steps with whatever is
loaded (quantized variants run through the fused dequant matmul path).
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import actions as RA
from repro.core.manager import EdgeMultiAI
from repro.core.policies import Policy
from repro.core.model_zoo import ModelVariant, ModelZoo
from repro.core.predictor import RequestPredictor
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.quant.quantize import params_nbytes, quantize_params

MB = 1024 * 1024


@functools.partial(jax.jit, static_argnames=("cfg", "max_new", "max_len"))
def _generate_tokens(cfg: ModelConfig, params, prompts: jnp.ndarray, *,
                     max_new: int, max_len: int) -> jnp.ndarray:
    """Fused greedy decode: prefill + ``max_new − 1`` scanned decode
    steps in one XLA program (cache shapes are static — prefill pads to
    ``max_len``), so serving cost is one dispatch per batch instead of
    hundreds of eager ops per token."""
    logits, cache = T.prefill(cfg, params, {"tokens": prompts},
                              max_len=max_len)
    tok = T.greedy_token(cfg, logits)

    def step(carry, _):
        prev, c = carry
        lg, c2 = T.decode_step(cfg, params, c, prev)
        # Keep the carry type stable: some archs (Mamba conv state)
        # decode in f32 while prefill emits the storage dtype.
        c2 = jax.tree.map(lambda new, old: new.astype(old.dtype), c2, c)
        nxt = T.greedy_token(cfg, lg)
        return (nxt, c2), nxt

    if max_new == 1:
        return tok[:, None]
    _, rest = jax.lax.scan(step, (tok, cache), None, length=max_new - 1)
    return jnp.concatenate([tok[:, None], jnp.moveaxis(rest, 0, 1)],
                           axis=1)


@dataclass
class ServeResult:
    app: str
    tokens: np.ndarray
    warm: bool
    failed: bool
    bits: Optional[int]
    latency_s: float
    redispatched: bool = False


class TenantRuntime:
    """One application: config + host-side zoo + device-side loaded params.

    The production implementation of the engine's ``TenantExecutor``
    protocol — :meth:`execute` runs the real fused prefill+decode and is
    timed by wall clock (it returns no virtual service time)."""

    def __init__(self, name: str, cfg: ModelConfig, params,
                 precisions: Tuple[int, ...] = (16, 8),
                 predictor: Optional[RequestPredictor] = None):
        self.name = name
        self.cfg = cfg
        # Host "storage": every zoo variant, kept off-device as numpy.
        self.host: Dict[int, Any] = {}
        sizes: Dict[int, float] = {}
        for bits in precisions:
            variant = quantize_params(params, bits=bits, group=32)
            self.host[bits] = jax.tree.map(np.asarray, variant)
            sizes[bits] = params_nbytes(variant) / MB
        self.zoo = ModelZoo(
            app_name=name,
            variants=tuple(
                ModelVariant(
                    name=f"{name}-{b}bit", bits=b, size_mb=sizes[b],
                    accuracy={16: 100.0, 8: 97.0, 4: 85.0}.get(b, 90.0),
                    load_ms=max(sizes[b], 0.01))
                for b in precisions))
        self.device_params: Optional[Any] = None
        self.loaded_bits: Optional[int] = None
        self.predictor = predictor or RequestPredictor(context=8, hidden=16)
        self._decode = None  # jitted per (bits)
        # Physical placement (sharded mesh): when a mesh is attached,
        # set_variant device_puts each leaf with a NamedSharding from
        # the real partition specs, so per-chip buffer bytes track the
        # DeviceLedger's shard fractions.  None = single-device asarray.
        self.mesh = None
        self._specs: Dict[int, Any] = {}  # per-bits PartitionSpec trees

    def attach_mesh(self, mesh) -> None:
        """Route weight placement through ``jax.device_put`` +
        ``NamedSharding`` on ``mesh``; a variant already resident is
        re-placed so its buffers match the specs immediately."""
        self.mesh = mesh
        self._specs.clear()
        if self.loaded_bits is not None:
            bits, self.loaded_bits = self.loaded_bits, None
            self.set_variant(self.zoo.by_bits(bits))

    def _spec_tree(self, bits: int):
        specs = self._specs.get(bits)
        if specs is None:
            from repro.distributed import sharding as SH
            specs = SH.param_specs(self.cfg, self.host[bits], self.mesh,
                                   fsdp=False)
            self._specs[bits] = specs
        return specs

    def reshard_device_params(self) -> None:
        """Elastic recovery: re-place the resident variant's buffers on
        the attached mesh (``distributed.elastic.reshard``) after the
        ledger layout changed.  No-op off-mesh or when nothing is
        loaded."""
        if self.mesh is None or self.loaded_bits is None:
            return
        from repro.distributed.elastic import reshard
        self.device_params = reshard(
            self.device_params, self._spec_tree(self.loaded_bits),
            self.mesh)

    # -- loader callback target -------------------------------------------
    def set_variant(self, variant: Optional[ModelVariant]) -> None:
        if variant is None:
            self.device_params = None
            self.loaded_bits = None
            return
        if variant.bits == self.loaded_bits:
            return
        host_tree = self.host[variant.bits]
        if self.mesh is not None:
            from repro.distributed import sharding as SH
            self.device_params = jax.device_put(
                host_tree,
                SH.named(self.mesh, self._spec_tree(variant.bits)))
        else:
            self.device_params = jax.tree.map(jnp.asarray, host_tree)
        self.loaded_bits = variant.bits

    def generate(self, prompts: np.ndarray, max_new: int,
                 extra: Optional[dict] = None) -> np.ndarray:
        """Greedy-decode ``max_new`` tokens for a batch of prompts.

        The no-extras path runs one fused, jitted prefill+scan-decode —
        the seed's eager per-op dispatch made every batch cost seconds
        on CPU, which both swamped the serving benchmark and hid the
        load/infer asymmetry the framework exists to exploit.  Batches
        with extra modality inputs keep the eager path."""
        assert self.device_params is not None, f"{self.name}: not loaded"
        cfg, params = self.cfg, self.device_params
        S = prompts.shape[1]
        if not extra:
            return np.asarray(_generate_tokens(
                cfg, params, jnp.asarray(prompts), max_new=max_new,
                max_len=S + max_new))
        batch = {"tokens": jnp.asarray(prompts)}
        batch.update({k: jnp.asarray(v) for k, v in extra.items()})
        logits, cache = T.prefill(cfg, params, batch, max_len=S + max_new)
        toks = [T.greedy_token(cfg, logits)]
        for _ in range(max_new - 1):
            logits, cache = T.decode_step(cfg, params, cache, toks[-1])
            toks.append(T.greedy_token(cfg, logits))
        return np.stack([np.asarray(t) for t in toks], axis=1)

    # -- TenantExecutor protocol ------------------------------------------
    def execute(self, batch, extra: Optional[dict] = None
                ) -> Tuple[np.ndarray, Optional[float]]:
        """Run one batch; wall-clock timed (no virtual service time)."""
        return self.generate(batch.prompts, batch.max_new, extra), None


class EdgeServer:
    """The end-to-end system: Edge-MultiAI + real tenants + batching.

    This object is the *tenant registry and facade* (the engine's
    ``ServingHost``): ``serve()`` keeps its one-call API but delegates
    every admit/execute/retire cycle to the :class:`ServingEngine`, which
    also charges each batch's KV cache against the memory budget.

    The declarative front door is :meth:`build` — one call that resolves
    a :class:`~repro.serving.api.ServingConfig` into a fully wired,
    started server (tenants registered, policy resolved through the
    registry, loader and engine attached, budget derived).  The
    imperative ``__init__`` / ``register`` / ``start`` path underneath
    stays public for callers that need custom params or executors.
    """

    def __init__(self, budget_mb: float, policy="iws-bfe",
                 delta_ms: float = 500.0, straggler_deadline_s: float = 30.0,
                 max_batch: int = 8, batch_window_ms: float = 0.0,
                 prefetch: bool = True, history_ms: float = 3000.0,
                 fallback="desperation",
                 sharded_mesh: Optional[Tuple[int, ...]] = None,
                 device_budget_mb: "Optional[float | Tuple[float, ...]]"
                 = None,
                 migrate: bool = True,
                 compress: Optional[str] = None,
                 adaptive_delta: bool = False,
                 continuous: bool = False,
                 kv_page_mb: float = 0.0,
                 fault=None,
                 audit: str = "full",
                 scheduler: str = "indexed"):
        self.tenants: Dict[str, Any] = {}  # TenantExecutor implementations
        self.budget_mb = budget_mb
        self.policy = policy
        self.fallback = fallback
        self.delta_ms = delta_ms
        self.history_ms = history_ms
        # Sharded multi-device serving: a mesh shape ((8,) = 8-way tensor
        # parallel) swaps the loader for the per-shard staging channel
        # and installs per-device budget ledgers; None = single device.
        self.sharded_mesh = (tuple(sharded_mesh)
                             if sharded_mesh is not None else None)
        # One float = uniform per-chip budgets; a tuple gives per-chip
        # (skewed) budgets — the regime cross-device victim migration
        # exists for.  None derives a uniform budget covering the worst
        # tenant's replication overhead.
        self.device_budget_mb = (tuple(device_budget_mb)
                                 if isinstance(device_budget_mb,
                                               (tuple, list))
                                 else device_budget_mb)
        self.migrate = migrate
        # Quantize-on-the-wire staging ("int8" or None): both loader
        # channels ship compressed bytes host→chip and dequantize on
        # land, shrinking every load's virtual transfer time by the
        # wire ratio while residency accounting is unchanged.
        self.compress = compress
        self.adaptive_delta = adaptive_delta
        # Continuous batching: requests join/leave the running decode
        # batch per step, and KV is charged page-granularly through a
        # KVPagePool sized at start().  kv_page_mb=0 derives the page
        # size from the largest tenant's 8-token decode cache.
        self.continuous = continuous
        self.kv_page_mb = kv_page_mb
        # Chip fault schedule (a serving.elastic.FaultSpec): start()
        # installs an ElasticController that fires chip-down drain plans
        # and chip-up rebalances on the engine clock.
        self.fault = fault
        # Engine fast-path knobs (see ServingEngine): audit level and
        # event-scheduling mode.  scheduler="indexed" also memoizes the
        # per-tenant prediction triggers here (the predictors' forward
        # pass re-materializes full arrival history on every call).
        self.audit = audit
        self.scheduler = scheduler
        self._tpred_memo: Dict[str, Tuple[tuple, float]] = {}
        # Horizon before which a repeat of the last maintenance pass is
        # provably the identical no-op (every tenant took the indexed
        # fast skip).  The engine's continuous loop consults it — see
        # predict_and_preload; -inf means "never skip".
        self.maint_valid_ms = float("-inf")
        self.manager: Optional[EdgeMultiAI] = None
        self.engine = None  # type: Optional["ServingEngine"]
        self.loader = None  # type: Optional["BackgroundLoader"]
        self.elastic = None  # type: Optional["ElasticController"]
        self.physical_mesh = None  # real per-shard placement (sharded)
        self.prefetch = prefetch
        self.max_batch = max_batch
        self.batch_window_ms = batch_window_ms
        self.straggler_deadline_s = straggler_deadline_s
        self.redispatch_count = 0
        self.results: List[ServeResult] = []
        # Sim-executor builds set this: background fits complete before
        # the next prediction so virtual-time runs stay bit-deterministic
        # (a wall-clock fit racing the virtual clock would flip
        # predictions at a nondeterministic timestamp).
        self.sync_predictor_fits = False

    @classmethod
    def build(cls, config) -> "EdgeServer":
        """Resolve a :class:`repro.serving.api.ServingConfig` into a
        started server — the single wiring point every benchmark,
        example, and launcher goes through."""
        from repro.serving.api import build_server  # local: avoids cycle
        return build_server(config, cls=cls)

    def register(self, name: str, cfg: ModelConfig, params,
                 precisions: Tuple[int, ...] = (16, 8),
                 predictor: Optional[RequestPredictor] = None) -> None:
        """Register a real-model tenant (host-side zoo built from
        ``params`` by quantization)."""
        self.tenants[name] = TenantRuntime(name, cfg, params, precisions,
                                           predictor=predictor)

    def register_tenant(self, name: str, tenant) -> None:
        """Register any ``TenantExecutor`` implementation — e.g. the
        sim-time executor (:class:`repro.serving.api.SimTenant`) for
        deterministic, XLA-free tests."""
        self.tenants[name] = tenant

    def contention_budget(self, kv_headroom_mb: float = 0.0) -> float:
        """Standard contended budget over the registered tenants: every
        tenant resident at its smallest variant, plus room to upgrade the
        widest zoo to full precision, 5% slack, and explicit headroom for
        KV caches (which are charged against the budget too).  All-bf16
        residency stays impossible."""
        small = sum(t.zoo.smallest.size_mb for t in self.tenants.values())
        room = max(t.zoo.largest.size_mb - t.zoo.smallest.size_mb
                   for t in self.tenants.values())
        return (small + room) * 1.05 + kv_headroom_mb

    def start(self) -> None:
        from repro.serving.engine import ServingEngine
        from repro.serving.loader import BackgroundLoader

        zoos = {n: t.zoo for n, t in self.tenants.items()}

        def stage(app: str, variant: Optional[ModelVariant]) -> None:
            self.tenants[app].set_variant(variant)

        def loader_cb(app: str, variant: Optional[ModelVariant]) -> None:
            # Synchronous (admission-path) weight moves ride the same
            # single-worker staging channel as background loads, so
            # device mutations land in the order their accounting did.
            if self.loader is not None:
                self.loader.stage_sync(app, variant)
            else:
                stage(app, variant)

        self.manager = EdgeMultiAI(
            zoos, self.budget_mb, policy=self.policy,
            delta_ms=self.delta_ms, history_ms=self.history_ms,
            loader=loader_cb, fallback=self.fallback,
            adaptive_delta=self.adaptive_delta, migrate=self.migrate)
        if self.sharded_mesh is not None:
            if not self.prefetch:
                raise ValueError(
                    "sharded serving requires the background loader "
                    "(prefetch=True): the reactive engine has no "
                    "staging channel to decompose per shard")
            self.manager.state.devices = self._device_ledger()
            from repro.serving.sharded_loader import ShardedLoaderChannel
            self.loader = ShardedLoaderChannel(
                self.manager,
                n_devices=self.manager.state.devices.n_devices,
                stage_fn=stage, migrate=self.migrate,
                compress=self.compress)
            self._attach_physical_mesh()
        else:
            self.loader = (BackgroundLoader(self.manager, stage_fn=stage,
                                            compress=self.compress)
                           if self.prefetch else None)
        if self.loader is not None:
            # Admission-path migrations land in the same audit trail as
            # loader-path ones (the engine mirrors loader events).
            self.manager.on_migrate = (
                lambda t, app, mb: self.loader._emit(t, "migrate",
                                                     app, mb))
        if self.continuous:
            self._install_kv_pool()
        self.engine = ServingEngine(
            self, max_batch=self.max_batch,
            batch_window_ms=self.batch_window_ms, loader=self.loader,
            continuous=self.continuous, audit=self.audit,
            scheduler=self.scheduler)
        if self.fault is not None:
            from repro.serving.elastic import ElasticController
            ctrl = ElasticController(self.fault, self.manager,
                                     loader=self.loader)
            # chip_down/chip_up/drain ride the loader's event hook into
            # the engine's audit trail, like migrations do.
            ctrl.on_event = (
                lambda t, kind, app, mb: self.loader._emit(t, kind,
                                                           app, mb))
            ctrl.on_reshard = self._reshard_tenant
            self.elastic = ctrl
            self.engine.elastic = ctrl

    def _attach_physical_mesh(self) -> None:
        """True per-shard placement for real-model tenants: build the
        physical mesh matching the ledger's logical one and route every
        ``set_variant`` through ``NamedSharding`` device_puts.  Skipped
        when the process has fewer devices than the mesh asks for (sim
        builds, plain CPU) — the ledger stays the accounting authority
        either way."""
        shape = self.sharded_mesh
        n = 1
        for s in shape:
            n *= s
        if jax.device_count() < n:
            return
        from repro.launch.mesh import make_mesh_compat
        dims = (1, shape[0]) if len(shape) == 1 else tuple(shape)
        self.physical_mesh = make_mesh_compat(dims, ("data", "model"))
        for tr in self.tenants.values():
            if hasattr(tr, "attach_mesh"):
                tr.attach_mesh(self.physical_mesh)

    def _reshard_tenant(self, app: str) -> None:
        """Elastic-plan hook: re-place a tenant's resident buffers after
        a drain/rebalance changed its layout (real runtimes on a mesh;
        no-op for sim executors)."""
        tr = self.tenants[app]
        if hasattr(tr, "reshard_device_params"):
            tr.reshard_device_params()

    def _install_kv_pool(self) -> None:
        """Size and attach the paged-KV pool for continuous batching.

        Page size defaults to the largest tenant's 8-token decode cache
        (so one page ~ one short burst of decoding for the heaviest
        model); the whole budget is divided into pages because KV shares
        the same ledger as weights — a page the pool holds is memory a
        weight load cannot claim, and simulate/apply validates both the
        same way.  Under a sharded mesh the pages are partitioned across
        chips proportional to each chip's ledger budget."""
        from repro.core.memory_state import KVPagePool
        from repro.serving.engine import kv_cache_mb

        page_mb = self.kv_page_mb or max(
            kv_cache_mb(t.cfg, 1, 8) for t in self.tenants.values())
        n_pages = int(self.budget_mb // page_mb)
        if n_pages < 1:
            raise ValueError(
                f"kv_page_mb={page_mb:.1f} exceeds the whole budget "
                f"({self.budget_mb:.1f} MB): no page fits")
        dev = self.manager.state.devices
        if dev is not None:
            total = sum(dev.budgets_mb)
            counts = [int(n_pages * b / total) for b in dev.budgets_mb]
            counts[0] += n_pages - sum(counts)  # remainder to chip 0
            self.manager.state.kv_pool = KVPagePool(
                page_mb, device_pages=tuple(counts))
        else:
            self.manager.state.kv_pool = KVPagePool(page_mb, n_pages)

    def _device_ledger(self):
        """Per-device budgets + spec-derived shard splits for the mesh.

        Each tenant's per-chip fraction comes from the real partition
        rules (``weight_shard_fraction`` — replicated leaves included),
        so the ledger budgets what a chip actually holds.  The default
        per-device budget covers the worst tenant's replication overhead
        over the even ``budget/n`` split: anything fundable globally is
        then fundable per-chip, and tighter (explicit) budgets surface
        as clean whole-load failures in the sharded loader."""
        from repro.core.memory_state import DeviceLedger
        from repro.distributed import sharding as SH

        mesh = SH.serving_mesh(self.sharded_mesh)
        n = mesh.size
        fracs = {name: SH.weight_shard_fraction(t.cfg, mesh)
                 for name, t in self.tenants.items()}
        if isinstance(self.device_budget_mb, tuple):
            # Per-chip (skewed) budgets: the migration regime — one
            # tight chip while neighbors keep slack.
            if len(self.device_budget_mb) != n:
                raise ValueError(
                    f"{len(self.device_budget_mb)} device budgets for "
                    f"a {n}-chip mesh")
            budgets = self.device_budget_mb
        else:
            per_dev = (self.device_budget_mb
                       if self.device_budget_mb is not None
                       else self.budget_mb / n * max(
                           f * n for f in fracs.values()))
            budgets = (per_dev,) * n
        return DeviceLedger(
            budgets,
            split_fn=lambda app, v: SH.variant_shard_mb(
                v.size_mb, n, fracs[app]))

    def close(self) -> None:
        """Drain and shut down the background staging worker."""
        if self.loader is not None:
            self.loader.close()

    # ------------------------------------------------------------------
    def _predict_time(self, name: str, predictor) -> float:
        """``predictor.predict_next_time()``, memoized on the indexed
        scheduler.  The prediction is a pure function of the predictor's
        observable state — arrival history (appends only), trained
        params (change only when ``fits`` increments), and the last
        arrival — so caching on that key returns the identical float
        while skipping the O(history) forward pass the linear path runs
        once per tenant per maintenance pass."""
        if self.scheduler != "indexed":
            return predictor.predict_next_time()
        key = (len(predictor.history), predictor.fits,
               predictor.last_time)
        hit = self._tpred_memo.get(name)
        if hit is not None and hit[0] == key:
            return hit[1]
        t = predictor.predict_next_time()
        self._tpred_memo[name] = (key, t)
        return t

    def predict_and_preload(self, now_ms: float) -> None:
        """Drive the RNN request predictors -> proactive loads.

        With the background loader attached, predicted-next tenants get
        their iWS-BFE-chosen variant *enqueued* for staging instead of
        loaded on the caller's thread, and prefetches whose predicted
        window expired without a request are cancelled (releasing their
        in-flight memory claim).  Without a loader this is the PR-1
        synchronous proactive load.

        This is also where the RNNs get *trained*: a predictor with
        enough fresh inter-arrival history (``fit_due``) is handed to
        the loader's background fit worker — the live path runs on the
        mean-gap fallback until the first fit lands, then on the
        trained RNN, and never blocks on training."""
        # Indexed fast path: when a tenant's memoized prediction is
        # current and no fit is due, its pass can only end in "do
        # nothing" — prove it with cheap reads and skip the planner.
        # Soundness: (a) the prediction is rewritten so state matches
        # the linear pass even when the memo was filled by
        # ``next_prefetch_trigger``; (b) Δ is recomputed fresh when
        # adaptive (it drifts with arrival residuals); (c) outside
        # [t_pred−Δ−θ, t_pred+Δ] nothing fires, and inside it a tenant
        # with queued requests is demand-loaded, never prefetched —
        # both exactly the linear conditions; (d) for the
        # un-overridden base ``plan_prefetch`` hook the eviction-free
        # surplus decision is replicated verbatim against a pass-level
        # ``free_mb`` (one budget sum per pass, dropped whenever a
        # full pass may have mutated the state).  A custom policy hook
        # gets no structural credit — the full pass runs so its plan
        # is actually consulted.  This loop is the engine's hottest
        # code (once per tenant per event-loop iteration), hence the
        # hoisted locals and the inlined window/fit/hook checks.
        mgr = self.manager
        fast = self.scheduler == "indexed" and self.loader is not None
        free_mb = None  # one budget sum per pass; reset on mutation
        # Skip horizon accounting: while every tenant takes the fast
        # skip, the pass decisions can only flip at the earliest
        # still-ahead window opening (t_pred − Δ − θ) — tenants already
        # in or past their window stay no-ops until an arrival, fit, or
        # memory mutation, all of which reset the engine's clean flag.
        valid = float("inf")
        all_skipped = fast
        if fast:
            memo = self._tpred_memo
            tstates = mgr.state.tenants
            queues = (self.engine.batcher.queues
                      if self.engine is not None else None)
            delta_const = None if mgr.adaptive_delta else mgr.delta
            policy = mgr.policy
            base_hook = (policy is not None and
                         type(policy).plan_prefetch is Policy.plan_prefetch)
        for name, tr in self.tenants.items():
            if fast:
                p = tr.predictor
                hit = memo.get(name)
                n_hist = len(p.history)
                if (hit is not None
                        and hit[0] == (n_hist, p.fits, p.last_time)
                        # fit_due is False while the history is short
                        # (n < max(min_fit_samples, context+2)); only
                        # past that must the refit cadence be asked.
                        and (n_hist < p.min_fit_samples
                             or n_hist < p.context + 2
                             or not p.fit_due())):
                    t_pred = hit[1]
                    t = tstates[name]
                    t.predicted_next = t_pred  # == set_prediction
                    delta = (delta_const if delta_const is not None
                             else mgr.delta_for(name))
                    largest = t.zoo.variants[0]  # zoo sorts desc
                    start = t_pred - delta - largest.load_ms
                    if now_ms < start:  # ahead of the window
                        if start < valid:
                            valid = start
                        continue
                    if now_ms > t_pred + delta:  # window passed
                        continue
                    if queues is not None and queues.get(name):
                        continue  # queued: demand path, not prefetch
                    if policy is None:
                        continue  # manager.plan_prefetch is None
                    if base_hook:
                        if (t.loaded is largest
                                or t.inflight_mb > 0.0):
                            continue  # the hook's two early outs
                        if free_mb is None:
                            free_mb = mgr.state.free_mb
                        cur = t.loaded.size_mb if t.loaded else 0.0
                        planless = True
                        for v in t.zoo.variants:  # mirror the hook
                            if t.loaded is not None \
                                    and v.size_mb <= cur:
                                break
                            if v.size_mb - cur <= free_mb:
                                planless = False  # hook would plan
                                break
                        if planless:
                            continue
                    # In-window, unqueued, and the hook might plan:
                    # fall through to the full pass below.
            # The full pass may mutate the memory state (stage a load,
            # reserve a claim): drop the pass-level free_mb cache, and
            # give the engine no skip credit for this pass.
            all_skipped = False
            free_mb = None
            if self.loader is not None and tr.predictor.fit_due():
                fut = self.loader.submit_fit(tr.predictor)
                if fut is not None and self.sync_predictor_fits:
                    fut.result()  # lands at this exact virtual instant
            t_pred = self._predict_time(name, tr.predictor)
            self.manager.set_prediction(name, t_pred)
            theta = tr.zoo.largest.load_ms
            # Per-tenant Δ: the configured constant, or the residual-
            # adapted window when ``adaptive_delta`` is on.
            delta = self.manager.delta_for(name)
            in_window = (t_pred - delta - theta <= now_ms
                         <= t_pred + delta)
            if self.loader is None:
                if t_pred - delta - theta <= now_ms:
                    self.manager.proactive_load(name, now_ms)
            elif in_window:
                # Only prefetch inside the predicted window: past its
                # far edge the prediction is already wrong, and a stale-
                # cancelled prefetch must not immediately re-enqueue.
                if (self.engine is None
                        or self.engine.batcher.queued(name) == 0):
                    # A tenant with requests already queued is not a
                    # prefetch target — its load is demand-triggered
                    # (the engine stages it and admits the batch cold);
                    # calling it a prefetch would count a request that
                    # waited out the transfer as a warm start.
                    plan = self.manager.plan_prefetch(name, now_ms)
                    if plan is not None:
                        self.loader.execute(
                            RA.ResidencyPlan(
                                RA.procure_actions(plan, staged=True)),
                            now_ms, predicted_ms=t_pred)
        self.maint_valid_ms = valid if all_skipped else float("-inf")
        if (self.loader is not None and self.engine is not None
                and self.loader.inflight):  # nothing staged: no-op
            # Per-tenant Δ so staleness agrees with the (possibly
            # adaptive) window that justified the prefetch.
            self.loader.cancel_stale(
                now_ms, self.manager.delta_for,
                has_queued=lambda a: self.engine.batcher.queued(a) > 0)

    def next_prefetch_trigger(self, now_ms: float) -> float:
        """Earliest *future* t_pred − Δ − θ across tenants that could use
        a proactive load: the engine's idle path wakes here, otherwise a
        drained queue would sleep straight through its prefetch window
        and every load would degenerate to demand-time."""
        out = float("inf")
        for name, tr in self.tenants.items():
            t = self.manager.state.tenants[name]
            if t.loaded is t.zoo.largest or t.inflight_mb > 0.0:
                continue
            trig = (self._predict_time(name, tr.predictor)
                    - self.manager.delta_for(name)
                    - tr.zoo.largest.load_ms)
            if now_ms < trig < out:
                out = trig
        return out

    def serve(self, app: str, prompts: np.ndarray, max_new: int = 8,
              now_ms: Optional[float] = None,
              extra: Optional[dict] = None) -> ServeResult:
        """Synchronous one-batch API, delegating to the engine: the batch
        is admitted with its KV cache charged against the budget and the
        charge released on retirement."""
        assert self.manager is not None, "call start() first"
        from repro.serving.batcher import Batch, Request

        now_ms = time.monotonic() * 1e3 if now_ms is None else now_ms
        tr = self.tenants[app]
        prompts = np.asarray(prompts, np.int32)
        if len(prompts) == 0:  # nothing to admit, nothing to charge
            return self._record(ServeResult(
                app, np.zeros((0, max_new), np.int32), False, False,
                tr.loaded_bits, 0.0))
        tr.predictor.observe_request(now_ms)
        reqs = [self.engine.batcher.assign(
            Request(app=app, prompt=prompts[i], max_new=max_new,
                    arrival_ms=now_ms)) for i in range(len(prompts))]
        batch = Batch(app, reqs, prompts, max_new)
        results, service_ms, toks = self.engine.execute_batch(
            batch, now_ms, extra=extra)
        warm = results[0].warm
        if toks is None:
            return self._record(ServeResult(
                app, np.zeros((len(prompts), 0), np.int32), warm, True,
                None, service_ms / 1e3))
        elapsed = service_ms / 1e3
        redis = False
        if elapsed > self.straggler_deadline_s:
            # Straggler mitigation: on a real fleet this re-dispatches to
            # the replica pod (the multi-pod mesh's second pod); here we
            # count and serve locally.
            self.redispatch_count += 1
            redis = True
        return self._record(ServeResult(
            app, toks, warm, False, tr.loaded_bits, elapsed, redis))

    def _record(self, r: ServeResult) -> ServeResult:
        self.results.append(r)
        return r

    # ------------------------------------------------------------------
    def stats(self) -> "ServingStats":
        """The engine's typed :class:`~repro.serving.stats.ServingStats`
        with the server-level gauges filled in (residency, latency,
        redispatch, predictor fits, adaptive windows, device ledger).
        All request counts are per *request* (the engine's unit), so the
        top-level ratios and the per-tenant breakdown describe the same
        population — a multi-row serve() batch counts once per row."""
        import dataclasses

        from repro.serving.stats import ServingStats

        eng_results = self.engine.results if self.engine else []
        if not eng_results:  # serve() always routes through the engine
            return ServingStats()
        n = len(eng_results)
        ok = [r.latency_ms for r in eng_results if not r.failed]
        extra: dict = {
            "redispatched": self.redispatch_count,
            "resident_mb": self.manager.state.used_mb,
            "weights_mb": self.manager.state.weights_mb,
            "kv_mb": self.manager.state.kv_mb,
            "requests": n,
            "warm_ratio": sum(r.warm for r in eng_results) / n,
            "fail_ratio": sum(r.failed for r in eng_results) / n,
            "mean_latency_s": (float(np.mean(ok)) / 1e3 if ok
                               else float("inf")),
            # Completed background predictor fits (the hit rate itself
            # comes from the engine view).
            "predictor_fits": sum(
                getattr(t.predictor, "fits", 0)
                for t in self.tenants.values()),
        }
        if self.adaptive_delta:
            # The residual-adapted prediction windows, per tenant.
            extra["delta_ms"] = {name: self.manager.delta_for(name)
                                 for name in self.tenants}
        if self.manager.state.devices is not None:
            led = self.manager.state.devices
            extra["device_used_mb"] = led.device_used()
            extra["device_budget_mb"] = led.budgets_mb
        return dataclasses.replace(self.engine.stats(), **extra)
