"""Multi-tenant serving runtime: Edge-MultiAI managing *real* JAX models.

This is where the paper's framework meets actual weights: each tenant is an
LM architecture with a real zoo (bf16 / int8 / int4 variants built by
``repro.quant``), "storage" is host RAM (numpy), "memory" is the device
budget tracked in MB of true buffer bytes, and load/evict callbacks move
weights with ``jax.device_put``.  The manager decides *which variant is
resident when*; serving runs true prefill/decode steps with whatever is
loaded (quantized variants run through the fused dequant matmul path).
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.manager import EdgeMultiAI
from repro.core.model_zoo import ModelVariant, ModelZoo
from repro.core.predictor import RequestPredictor
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.quant.quantize import params_nbytes, quantize_params

MB = 1024 * 1024


@dataclass
class ServeResult:
    app: str
    tokens: np.ndarray
    warm: bool
    failed: bool
    bits: Optional[int]
    latency_s: float
    redispatched: bool = False


class TenantRuntime:
    """One application: config + host-side zoo + device-side loaded params."""

    def __init__(self, name: str, cfg: ModelConfig, params,
                 precisions: Tuple[int, ...] = (16, 8)):
        self.name = name
        self.cfg = cfg
        # Host "storage": every zoo variant, kept off-device as numpy.
        self.host: Dict[int, Any] = {}
        sizes: Dict[int, float] = {}
        for bits in precisions:
            variant = quantize_params(params, bits=bits, group=32)
            self.host[bits] = jax.tree.map(np.asarray, variant)
            sizes[bits] = params_nbytes(variant) / MB
        self.zoo = ModelZoo(
            app_name=name,
            variants=tuple(
                ModelVariant(
                    name=f"{name}-{b}bit", bits=b, size_mb=sizes[b],
                    accuracy={16: 100.0, 8: 97.0, 4: 85.0}.get(b, 90.0),
                    load_ms=max(sizes[b], 0.01))
                for b in precisions))
        self.device_params: Optional[Any] = None
        self.loaded_bits: Optional[int] = None
        self.predictor = RequestPredictor(context=8, hidden=16)
        self._decode = None  # jitted per (bits)

    # -- loader callback target -------------------------------------------
    def set_variant(self, variant: Optional[ModelVariant]) -> None:
        if variant is None:
            self.device_params = None
            self.loaded_bits = None
            return
        if variant.bits == self.loaded_bits:
            return
        host_tree = self.host[variant.bits]
        self.device_params = jax.tree.map(jnp.asarray, host_tree)
        self.loaded_bits = variant.bits

    def generate(self, prompts: np.ndarray, max_new: int,
                 extra: Optional[dict] = None) -> np.ndarray:
        """Greedy-decode ``max_new`` tokens for a batch of prompts."""
        assert self.device_params is not None, f"{self.name}: not loaded"
        cfg, params = self.cfg, self.device_params
        batch = {"tokens": jnp.asarray(prompts)}
        if extra:
            batch.update({k: jnp.asarray(v) for k, v in extra.items()})
        S = prompts.shape[1]
        logits, cache = T.prefill(cfg, params, batch, max_len=S + max_new)
        toks = [T.greedy_token(cfg, logits)]
        for _ in range(max_new - 1):
            logits, cache = T.decode_step(cfg, params, cache, toks[-1])
            toks.append(T.greedy_token(cfg, logits))
        return np.stack([np.asarray(t) for t in toks], axis=1)


class MultiTenantServer:
    """The end-to-end system: Edge-MultiAI + real tenants + batching.

    Since the engine refactor this object is the *tenant registry and
    facade*: ``serve()`` keeps its one-call API but delegates every
    admit/execute/retire cycle to the :class:`ServingEngine`, which also
    charges each batch's KV cache against the memory budget."""

    def __init__(self, budget_mb: float, policy: str = "iws-bfe",
                 delta_ms: float = 500.0, straggler_deadline_s: float = 30.0,
                 max_batch: int = 8, batch_window_ms: float = 0.0):
        self.tenants: Dict[str, TenantRuntime] = {}
        self.budget_mb = budget_mb
        self.policy = policy
        self.delta_ms = delta_ms
        self.manager: Optional[EdgeMultiAI] = None
        self.engine = None  # type: Optional["ServingEngine"]
        self.max_batch = max_batch
        self.batch_window_ms = batch_window_ms
        self.straggler_deadline_s = straggler_deadline_s
        self.redispatch_count = 0
        self.results: List[ServeResult] = []

    def register(self, name: str, cfg: ModelConfig, params,
                 precisions: Tuple[int, ...] = (16, 8)) -> None:
        self.tenants[name] = TenantRuntime(name, cfg, params, precisions)

    def contention_budget(self, kv_headroom_mb: float = 0.0) -> float:
        """Standard contended budget over the registered tenants: every
        tenant resident at its smallest variant, plus room to upgrade the
        widest zoo to full precision, 5% slack, and explicit headroom for
        KV caches (which are charged against the budget too).  All-bf16
        residency stays impossible."""
        small = sum(t.zoo.smallest.size_mb for t in self.tenants.values())
        room = max(t.zoo.largest.size_mb - t.zoo.smallest.size_mb
                   for t in self.tenants.values())
        return (small + room) * 1.05 + kv_headroom_mb

    def start(self) -> None:
        from repro.serving.engine import ServingEngine

        zoos = {n: t.zoo for n, t in self.tenants.items()}

        def loader(app: str, variant: Optional[ModelVariant]) -> None:
            self.tenants[app].set_variant(variant)

        self.manager = EdgeMultiAI(
            zoos, self.budget_mb, policy=self.policy,
            delta_ms=self.delta_ms, loader=loader)
        self.engine = ServingEngine(
            self, max_batch=self.max_batch,
            batch_window_ms=self.batch_window_ms)

    # ------------------------------------------------------------------
    def predict_and_preload(self, now_ms: float) -> None:
        """Drive the RNN request predictors -> proactive loads."""
        for name, tr in self.tenants.items():
            t_pred = tr.predictor.predict_next_time()
            self.manager.set_prediction(name, t_pred)
            theta = tr.zoo.largest.load_ms
            if t_pred - self.delta_ms - theta <= now_ms:
                self.manager.proactive_load(name, now_ms)

    def serve(self, app: str, prompts: np.ndarray, max_new: int = 8,
              now_ms: Optional[float] = None,
              extra: Optional[dict] = None) -> ServeResult:
        """Synchronous one-batch API, delegating to the engine: the batch
        is admitted with its KV cache charged against the budget and the
        charge released on retirement."""
        assert self.manager is not None, "call start() first"
        from repro.serving.batcher import Batch, Request

        now_ms = time.monotonic() * 1e3 if now_ms is None else now_ms
        tr = self.tenants[app]
        prompts = np.asarray(prompts, np.int32)
        if len(prompts) == 0:  # nothing to admit, nothing to charge
            return self._record(ServeResult(
                app, np.zeros((0, max_new), np.int32), False, False,
                tr.loaded_bits, 0.0))
        tr.predictor.observe_request(now_ms)
        reqs = [Request(app=app, prompt=prompts[i], max_new=max_new,
                        arrival_ms=now_ms) for i in range(len(prompts))]
        batch = Batch(app, reqs, prompts, max_new)
        results, service_ms, toks = self.engine.execute_batch(
            batch, now_ms, extra=extra)
        warm = results[0].warm
        if toks is None:
            return self._record(ServeResult(
                app, np.zeros((len(prompts), 0), np.int32), warm, True,
                None, service_ms / 1e3))
        elapsed = service_ms / 1e3
        redis = False
        if elapsed > self.straggler_deadline_s:
            # Straggler mitigation: on a real fleet this re-dispatches to
            # the replica pod (the multi-pod mesh's second pod); here we
            # count and serve locally.
            self.redispatch_count += 1
            redis = True
        return self._record(ServeResult(
            app, toks, warm, False, tr.loaded_bits, elapsed, redis))

    def _record(self, r: ServeResult) -> ServeResult:
        self.results.append(r)
        return r

    # ------------------------------------------------------------------
    def stats(self) -> dict:
        """Aggregate stats plus the engine's per-tenant latency
        percentiles, throughput, and KV-pressure counters.  All request
        counts are per *request* (the engine's unit), so the top-level
        ratios and the per-tenant breakdown describe the same population
        — a multi-row serve() batch counts once per row."""
        eng_results = self.engine.results if self.engine else []
        if not eng_results:  # serve() always routes through the engine
            return {}
        n = len(eng_results)
        ok = [r.latency_ms for r in eng_results if not r.failed]
        eng = self.engine.stats()
        out = {
            "redispatched": self.redispatch_count,
            "resident_mb": self.manager.state.used_mb,
            "weights_mb": self.manager.state.weights_mb,
            "kv_mb": self.manager.state.kv_mb,
            "requests": n,
            "warm_ratio": sum(r.warm for r in eng_results) / n,
            "fail_ratio": sum(r.failed for r in eng_results) / n,
            "mean_latency_s": (float(np.mean(ok)) / 1e3 if ok
                               else float("inf")),
            "per_tenant": eng["per_tenant"],
            "kv_downgrades": eng["kv_downgrades"],
            "kv_rejections": eng["kv_rejections"],
            "weight_failures": eng["weight_failures"],
        }
        if "requests_per_sec" in eng:
            out["requests_per_sec"] = eng["requests_per_sec"]
        return out
