"""Multi-tenant serving runtime: Edge-MultiAI managing *real* JAX models.

This is where the paper's framework meets actual weights: each tenant is an
LM architecture with a real zoo (bf16 / int8 / int4 variants built by
``repro.quant``), "storage" is host RAM (numpy), "memory" is the device
budget tracked in MB of true buffer bytes, and load/evict callbacks move
weights with ``jax.device_put``.  The manager decides *which variant is
resident when*; serving runs true prefill/decode steps with whatever is
loaded (quantized variants run through the fused dequant matmul path).
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.manager import EdgeMultiAI
from repro.core.model_zoo import ModelVariant, ModelZoo
from repro.core.predictor import RequestPredictor
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.quant.quantize import params_nbytes, quantize_params

MB = 1024 * 1024


@dataclass
class ServeResult:
    app: str
    tokens: np.ndarray
    warm: bool
    failed: bool
    bits: Optional[int]
    latency_s: float
    redispatched: bool = False


class TenantRuntime:
    """One application: config + host-side zoo + device-side loaded params."""

    def __init__(self, name: str, cfg: ModelConfig, params,
                 precisions: Tuple[int, ...] = (16, 8)):
        self.name = name
        self.cfg = cfg
        # Host "storage": every zoo variant, kept off-device as numpy.
        self.host: Dict[int, Any] = {}
        sizes: Dict[int, float] = {}
        for bits in precisions:
            variant = quantize_params(params, bits=bits, group=32)
            self.host[bits] = jax.tree.map(np.asarray, variant)
            sizes[bits] = params_nbytes(variant) / MB
        self.zoo = ModelZoo(
            app_name=name,
            variants=tuple(
                ModelVariant(
                    name=f"{name}-{b}bit", bits=b, size_mb=sizes[b],
                    accuracy={16: 100.0, 8: 97.0, 4: 85.0}.get(b, 90.0),
                    load_ms=max(sizes[b], 0.01))
                for b in precisions))
        self.device_params: Optional[Any] = None
        self.loaded_bits: Optional[int] = None
        self.predictor = RequestPredictor(context=8, hidden=16)
        self._decode = None  # jitted per (bits)

    # -- loader callback target -------------------------------------------
    def set_variant(self, variant: Optional[ModelVariant]) -> None:
        if variant is None:
            self.device_params = None
            self.loaded_bits = None
            return
        if variant.bits == self.loaded_bits:
            return
        host_tree = self.host[variant.bits]
        self.device_params = jax.tree.map(jnp.asarray, host_tree)
        self.loaded_bits = variant.bits

    def generate(self, prompts: np.ndarray, max_new: int,
                 extra: Optional[dict] = None) -> np.ndarray:
        """Greedy-decode ``max_new`` tokens for a batch of prompts."""
        assert self.device_params is not None, f"{self.name}: not loaded"
        cfg, params = self.cfg, self.device_params
        batch = {"tokens": jnp.asarray(prompts)}
        if extra:
            batch.update({k: jnp.asarray(v) for k, v in extra.items()})
        S = prompts.shape[1]
        logits, cache = T.prefill(cfg, params, batch, max_len=S + max_new)
        toks = [T.greedy_token(cfg, logits)]
        for _ in range(max_new - 1):
            logits, cache = T.decode_step(cfg, params, cache, toks[-1])
            toks.append(T.greedy_token(cfg, logits))
        return np.stack([np.asarray(t) for t in toks], axis=1)


class MultiTenantServer:
    """The end-to-end system: Edge-MultiAI + real tenants + batching."""

    def __init__(self, budget_mb: float, policy: str = "iws-bfe",
                 delta_ms: float = 500.0, straggler_deadline_s: float = 30.0):
        self.tenants: Dict[str, TenantRuntime] = {}
        self.budget_mb = budget_mb
        self.policy = policy
        self.delta_ms = delta_ms
        self.manager: Optional[EdgeMultiAI] = None
        self.straggler_deadline_s = straggler_deadline_s
        self.redispatch_count = 0
        self.results: List[ServeResult] = []

    def register(self, name: str, cfg: ModelConfig, params,
                 precisions: Tuple[int, ...] = (16, 8)) -> None:
        self.tenants[name] = TenantRuntime(name, cfg, params, precisions)

    def start(self) -> None:
        zoos = {n: t.zoo for n, t in self.tenants.items()}

        def loader(app: str, variant: Optional[ModelVariant]) -> None:
            self.tenants[app].set_variant(variant)

        self.manager = EdgeMultiAI(
            zoos, self.budget_mb, policy=self.policy,
            delta_ms=self.delta_ms, loader=loader)

    # ------------------------------------------------------------------
    def predict_and_preload(self, now_ms: float) -> None:
        """Drive the RNN request predictors -> proactive loads."""
        for name, tr in self.tenants.items():
            t_pred = tr.predictor.predict_next_time()
            self.manager.set_prediction(name, t_pred)
            theta = tr.zoo.largest.load_ms
            if t_pred - self.delta_ms - theta <= now_ms:
                self.manager.proactive_load(name, now_ms)

    def serve(self, app: str, prompts: np.ndarray, max_new: int = 8,
              now_ms: Optional[float] = None,
              extra: Optional[dict] = None) -> ServeResult:
        assert self.manager is not None, "call start() first"
        now_ms = time.monotonic() * 1e3 if now_ms is None else now_ms
        tr = self.tenants[app]
        tr.predictor.observe_request(now_ms)
        rec = self.manager.on_request(app, now_ms)
        t0 = time.monotonic()
        if rec.failed:
            return self._record(ServeResult(
                app, np.zeros((len(prompts), 0), np.int32), rec.warm, True,
                None, time.monotonic() - t0))
        toks = tr.generate(prompts, max_new, extra)
        elapsed = time.monotonic() - t0
        redis = False
        if elapsed > self.straggler_deadline_s:
            # Straggler mitigation: on a real fleet this re-dispatches to
            # the replica pod (the multi-pod mesh's second pod); here we
            # count and serve locally.
            self.redispatch_count += 1
            redis = True
        return self._record(ServeResult(
            app, toks, rec.warm, False, tr.loaded_bits, elapsed, redis))

    def _record(self, r: ServeResult) -> ServeResult:
        self.results.append(r)
        return r

    # ------------------------------------------------------------------
    def stats(self) -> dict:
        n = len(self.results)
        if not n:
            return {}
        return {
            "requests": n,
            "warm_ratio": sum(r.warm for r in self.results) / n,
            "fail_ratio": sum(r.failed for r in self.results) / n,
            "mean_latency_s": float(np.mean(
                [r.latency_s for r in self.results if not r.failed])),
            "redispatched": self.redispatch_count,
            "resident_mb": self.manager.state.used_mb,
        }
