"""Mesh-aware sharded loader: per-shard staging of tenant weights across
a multi-chip edge box, behind the same :class:`LoaderChannel` protocol.

On a single device the background loader hides one tenant's weight
transfer behind the other tenants' execution.  On a multi-chip box the
transfer itself decomposes: tensor parallelism places a *shard* of every
variant on each chip (``repro.distributed.sharding`` — replicated leaves
included, so a shard is ``weight_shard_fraction``, not ``1/n``), and the
loader stages one shard per device stream.  What that buys, concretely:

* **Per-shard virtual progress.**  The host→device link is shared, so
  shard ``k``'s transfer occupies the virtual slot ``[t + Σ_{j<k} ms_j,
  t + Σ_{j≤k} ms_j]`` — the *total* load time matches the single-stream
  loader (same bytes through the same link; the per-device streams
  overlap only the wall-clock device writes).  But progress is now
  observable per shard: each shard lands at its own schedule point, and
  ``load_overlap_ms`` is measured per shard — a load cancelled with 3 of
  8 shards landed still hid 3 shards of real transfer behind execution,
  and is credited for exactly that (the single-stream loader credits a
  cancelled load nothing).

* **Whole-load claims, per-shard release.**  ``enqueue`` charges the
  load's full marginal footprint once (global ``inflight_mb`` plus one
  claim per device in the :class:`~repro.core.memory_state.DeviceLedger`);
  ``cancel`` walks the shards in device order releasing each claim —
  the accounting a cross-device victim-migration pass will need.

* **Per-device budgets.**  A shard that does not fit on its chip fails
  the whole load *cleanly* (no claims land, ``enqueue`` returns None),
  which routes the tenant through the existing admission downgrade /
  desperation path — exactly how an unfundable single-device load fails.

Physical staging: per-shard ops ride worker-per-device pools (the
"per-chip DMA streams"); the whole-variant commit move rides the base
class's single staging channel, so device mutations keep landing in
accounting order.  The default per-shard op is a no-op hook —
``TenantRuntime.set_variant`` still moves whole variants at commit; true
per-shard ``device_put`` placement for the real executor is a ROADMAP
follow-on.
"""
from __future__ import annotations

import dataclasses
import math
from concurrent.futures import CancelledError, Future, ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Tuple

from repro.core import actions as A
from repro.core.model_zoo import ModelVariant
from repro.serving.loader import (ActionHook, BackgroundLoader,
                                  InflightLoad, LoadRecord)

INF = math.inf

# (app, variant_or_None, device, n_devices) — the per-device stream op.
ShardStageFn = Callable[[str, Optional[ModelVariant], int, int], None]


@dataclass
class ShardStage:
    """One device's slice of an in-flight sharded load."""
    device: int
    mb: float  # resident MB this shard adds on its device
    claim_mb: float  # per-device in-flight claim (marginal over loaded)
    global_mb: float  # this shard's slice of the global inflight charge
    load_ms: float  # virtual transfer time of this shard
    t_start_ms: float  # when this shard's slot on the host link opens
    ready_ms: float  # t_start + load_ms
    landed: bool = False
    future: Optional[Future] = None  # the wall-clock per-device stream op


@dataclass
class ShardedInflightLoad(InflightLoad):
    """An :class:`InflightLoad` decomposed into per-device shard stages
    (``ready_ms`` is the last shard's landing)."""
    shards: List[ShardStage] = field(default_factory=list)

    @property
    def cancelled(self) -> bool:
        """Gates the commit move on the staging channel (read from the
        worker thread; the action-record state machine is the truth)."""
        return self.state == "cancelled"

    @property
    def shard_claims(self) -> Tuple[float, ...]:
        return tuple(sh.claim_mb for sh in self.shards)


class ShardedLoaderChannel(BackgroundLoader):
    """Stages tenant weights shard-by-shard across a device mesh.

    Drop-in :class:`LoaderChannel`: the engine drives it exactly like
    :class:`BackgroundLoader`.  ``shard_fn(app, variant)`` maps a variant
    to per-device resident MB; it defaults to the manager state's
    :class:`DeviceLedger` split (when one is installed) or an even
    ``1/n`` split.  ``stage_shard_fn`` is the per-device stream op.

    ``migrate=True`` (default) arms **cross-device victim migration**:
    when one chip's ledger budget blocks a load while neighbors have
    room, :func:`repro.core.actions.plan_migration` emits
    ``MigrateShard`` actions that move a resident victim's shards to the
    free chips, and the whole group — moves, evictions, staged load —
    commits as one atomic plan instead of failing the load into the
    downgrade path.  ``migrate=False`` is the PR-4 behaviour (one
    overfull chip fails the whole load cleanly).
    """

    def __init__(self, manager, n_devices: int = 8, *,
                 stage_fn=None,
                 shard_fn: Optional[Callable[
                     [str, ModelVariant], Tuple[float, ...]]] = None,
                 stage_shard_fn: Optional[ShardStageFn] = None,
                 migrate: bool = True,
                 compress: Optional[str] = None):
        if n_devices < 1:
            raise ValueError(f"n_devices must be >= 1, got {n_devices}")
        super().__init__(manager, stage_fn=stage_fn, compress=compress)
        self.n_devices = n_devices
        self.migrate = migrate
        self._shard_fn = shard_fn
        self._stage_shard_fn = stage_shard_fn or (
            lambda app, variant, device, n: None)
        self._device_pools = [
            ThreadPoolExecutor(max_workers=1,
                               thread_name_prefix=f"shard-dev{d}")
            for d in range(n_devices)]
        # Landed shards of cancelled loads, queued for the engine's
        # overlap measurement at the next reap (their transfer was real
        # and really was hidden — the honest half of a wasted prefetch).
        self._partials: List[LoadRecord] = []
        # Shard schedules built at concretize time, carried to _perform
        # keyed by the concrete Load action (one execute() at a time on
        # the engine thread; cleared after every execute).
        self._staged_shards: dict = {}
        self.shards_landed = 0

    def execute(self, rplan: A.ResidencyPlan, now_ms: float, *,
                demand: bool = False, predicted_ms: float = INF,
                on_action: Optional[ActionHook] = None):
        try:
            return super().execute(rplan, now_ms, demand=demand,
                                   predicted_ms=predicted_ms,
                                   on_action=on_action)
        finally:
            self._staged_shards.clear()  # drop leftovers of failed plans

    # -- shard geometry --------------------------------------------------
    def _split_mb(self, app: str, variant: Optional[ModelVariant]
                  ) -> Tuple[float, ...]:
        if variant is None:
            return (0.0,) * self.n_devices
        ledger = self.manager.state.devices
        if self._shard_fn is not None:
            return tuple(self._shard_fn(app, variant))
        if ledger is not None:
            return ledger.split(app, variant)
        return tuple(variant.size_mb / self.n_devices
                     for _ in range(self.n_devices))

    def _build_shards(self, app: str, variant: ModelVariant,
                      now_ms: float, charge_mb: float
                      ) -> List[ShardStage]:
        """Decompose one load: per-device resident MB and claims, plus
        the shared-host-link virtual schedule (cumulative slots summing
        to exactly ``variant.load_ms``).  With a ledger installed the
        target layout is the *projection* of the tenant's actual
        holdings (a migrated layout persists through the reload) and the
        claims are marginal over those holdings — so the reserve checks
        validate exactly what the commit will place per chip."""
        loaded = self.manager.state.tenants[app].loaded
        ledger = self.manager.state.devices
        if ledger is not None and self._shard_fn is None:
            shards_mb = ledger.projected(app, variant)
            cur_mb = ledger.held(app, loaded)
        else:
            shards_mb = self._split_mb(app, variant)
            cur_mb = self._split_mb(app, loaded)
        total = sum(shards_mb)
        # Shared host link: the cumulative slots sum to exactly the
        # *wire* transfer time (compressed bytes under compress="int8").
        wire_ms = self._wire_ms(variant)
        out: List[ShardStage] = []
        t_cursor, global_left = now_ms, charge_mb
        for d, mb in enumerate(shards_mb):
            frac = mb / total if total else 0.0
            ms = wire_ms * frac
            gmb = (global_left if d == self.n_devices - 1
                   else charge_mb * frac)
            global_left -= gmb
            out.append(ShardStage(
                device=d, mb=mb,
                claim_mb=max(0.0, mb - cur_mb[d]),
                global_mb=gmb, load_ms=ms,
                t_start_ms=t_cursor, ready_ms=t_cursor + ms))
            t_cursor += ms
        return out

    def _dispatch(self, app: str, variant: ModelVariant,
                  shards: List[ShardStage],
                  ld: "ShardedInflightLoad") -> Future:
        """Queue the per-device stream ops and the gated whole-variant
        commit move (same single staging channel as every other device
        mutation, so commits land in accounting order)."""
        for sh in shards:
            sh.future = self._device_pools[sh.device].submit(
                self._stage_shard_fn, app, variant, sh.device,
                self.n_devices)

        def commit_move():
            for sh in shards:
                try:
                    if sh.future is not None:
                        sh.future.result()
                except CancelledError:
                    pass
            if not ld.cancelled:
                self._stage_fn(app, variant)

        return self._pool.submit(commit_move)

    def _track_load(self, app: str, variant: ModelVariant, now_ms: float,
                    charge: float, shards: List[ShardStage], *,
                    demand: bool, predicted_ms: float,
                    on_action: Optional[ActionHook] = None
                    ) -> ShardedInflightLoad:
        """Track an already-*applied* staged load (claims reserved by the
        plan applier) and dispatch its shard stages."""
        ld = ShardedInflightLoad(
            app=app, variant=variant, t_enqueue_ms=now_ms,
            ready_ms=shards[-1].ready_ms if shards else now_ms,
            charge_mb=charge, demand=demand, predicted_ms=predicted_ms,
            future=None, shards=shards, on_action=on_action)
        ld.future = self._dispatch(app, variant, shards, ld)
        self.inflight[app] = ld
        self._ready.push(ld.ready_ms, (app, ld))
        return ld

    # -- plan translation -------------------------------------------------
    def _concretize(self, rplan: A.ResidencyPlan, now_ms: float
                    ) -> Optional[A.ResidencyPlan]:
        """Resolve staged loads to concrete per-device shard claims; when
        a chip's budget blocks the plan and migration is armed, prepend
        the :func:`~repro.core.actions.plan_migration` moves so the whole
        group commits atomically.  Returns None when the plan is a no-op
        or remains unfundable — the tenant then rides the existing
        admission downgrade/desperation path, exactly like PR 4."""
        rplan = super()._concretize(rplan, now_ms)
        if rplan is None:
            return None
        state = self.manager.state
        acts, load = [], None
        for act in rplan:
            if isinstance(act, A.Load) and act.staged:
                shards = self._build_shards(act.app, act.variant, now_ms,
                                            act.claim_mb)
                act = dataclasses.replace(
                    act, shard_claims=tuple(sh.claim_mb for sh in shards))
                self._staged_shards[id(act)] = shards
                load = act
            acts.append(act)
        out = A.ResidencyPlan(tuple(acts))
        if state.simulate(out) is None:
            return out
        if not self.migrate or load is None or state.devices is None:
            return None
        # One chip over budget while neighbors idle: move a resident
        # victim's shards to the free chips instead of failing the load.
        # Victims the plan itself evicts are pinned (their downgrade
        # re-derives the canonical split, which would undo the move).
        evicted = tuple(a.app for a in out
                        if isinstance(a, (A.Unload, A.Downgrade)))
        moves = A.plan_migration(state, load.app, load.shard_claims,
                                 exclude=evicted)
        if moves is None:
            return None
        out = A.ResidencyPlan(moves + out.actions)
        return out if state.simulate(out) is None else None

    def _perform(self, act: A.Action, now_ms: float, *, demand: bool,
                 predicted_ms: float,
                 on_action: Optional[ActionHook]
                 ) -> Optional[ShardedInflightLoad]:
        if isinstance(act, A.Load) and act.staged:
            # The schedule built at concretize time (pre-apply holdings)
            # — its claims are exactly what the applier reserved.
            shards = self._staged_shards.pop(id(act), None)
            if shards is None:  # direct _perform use (tests/tools)
                shards = self._build_shards(act.app, act.variant, now_ms,
                                            act.claim_mb)
                for sh, claim in zip(shards, act.shard_claims or ()):
                    sh.claim_mb = claim
            ld = self._track_load(act.app, act.variant, now_ms,
                                  act.claim_mb, shards, demand=demand,
                                  predicted_ms=predicted_ms,
                                  on_action=on_action)
            self.wire_mb_staged += (act.variant.size_mb
                                    * self.wire_ratio(act.variant))
            if demand:
                self.demand_loads += 1
            self._emit(now_ms, "demand" if demand else "prefetch",
                       act.app, act.claim_mb)
            return ld
        if isinstance(act, A.MigrateShard):
            # Physical per-device streams: re-stage the victim's shard
            # on both chips (a no-op for the default hook; real
            # per-shard device_put is the ROADMAP follow-on — the
            # commit-time whole-variant move already converges).
            loaded = self.manager.state.tenants[act.app].loaded
            for dev in (act.src, act.dst):
                self._device_pools[dev].submit(
                    self._stage_shard_fn, act.app, loaded, dev,
                    self.n_devices)
            self._emit(now_ms, "migrate", act.app, act.mb)
            if on_action is not None:
                on_action(act, now_ms)
            return None
        return super()._perform(act, now_ms, demand=demand,
                                predicted_ms=predicted_ms,
                                on_action=on_action)

    def earliest_ready(self) -> float:
        """The next *commit* (last shard of the soonest-completing load)
        — deliberately the same wake semantics as the single-stream
        loader: nothing is actionable at an intermediate shard landing,
        and waking the engine there would shift prefetch enqueue times
        off the single-stream schedule (the A/B must differ only in the
        staging accounting).  Shard landings themselves are timestamped
        from the virtual schedule, so reaping them lazily at the next
        natural wake is exact.  A commit's ``ready_ms`` is fixed at
        track time (shrinks retire the old record and track a new one),
        so the base class's readiness heap covers this channel with the
        same validity predicate."""
        if self.indexed_ready:
            return self._ready.peek(self._ready_live)
        return min((ld.ready_ms for ld in self.inflight.values()),
                   default=INF)

    def reap(self, now_ms: float) -> List[LoadRecord]:
        """Land every shard whose virtual slot has passed; commit loads
        whose last shard landed.  Also drains the partial records of
        cancelled loads so the engine credits their landed shards'
        overlap."""
        out: List[LoadRecord] = self._partials
        self._partials = []
        state = self.manager.state
        for app in list(self.inflight):
            ld = self.inflight[app]
            for sh in ld.shards:
                if not sh.landed and sh.ready_ms <= now_ms:
                    sh.landed = True
                    self.shards_landed += 1
            if not all(sh.landed for sh in ld.shards):
                continue
            if not ld.staging:  # a stale record cannot commit twice
                del self.inflight[app]
                continue
            del self.inflight[app]
            ld.future.result()  # wall-clock commit move absorbed here
            # Claims convert to committed weights in one transaction;
            # the applier walks the shard claims in device order.
            commit = A.Load(app, ld.variant, claim_mb=ld.charge_mb,
                            shard_claims=ld.shard_claims)
            state.apply(A.ResidencyPlan((commit,)))
            ld.state = "committed"
            rec = LoadRecord(
                app=app, bits=ld.variant.bits,
                # Sum of the shard slots = the wire transfer time.
                load_ms=sum(sh.load_ms for sh in ld.shards),
                t_enqueue_ms=ld.t_enqueue_ms, t_ready_ms=ld.ready_ms,
                demand=ld.demand,
                shard_intervals=tuple(
                    (sh.t_start_ms, sh.ready_ms, sh.load_ms)
                    for sh in ld.shards),
                overlap_busy=ld.ol_take())
            self._committed[app] = rec
            self.history.append(rec)
            self.loads_committed += 1
            self._emit(ld.ready_ms, "load", app, ld.variant.size_mb)
            if ld.on_action is not None:
                ld.on_action(commit, ld.ready_ms)
            out.append(rec)
        return out

    def _release_load(self, ld: ShardedInflightLoad) -> bool:
        """Release a load's claims (shard-by-shard, device order, via the
        plan applier) and restore any device whose stream op already
        ran.  Guarded by the action-record state machine: a record that
        already committed or cancelled — e.g. the old record of a shrink
        whose shards are mid-release — returns False and releases
        *nothing*, so the claims now owned by the replacement load can
        never be double-released."""
        if not ld.staging:
            return False
        ld.state = "cancelled"  # one-way, before any release lands
        state = self.manager.state
        state.apply(A.ResidencyPlan((
            A.CancelPrefetch(ld.app, ld.charge_mb, ld.shard_claims),)))
        loaded = state.tenants[ld.app].loaded
        for sh in ld.shards:
            if sh.future is not None and not sh.future.cancel():
                self._device_pools[sh.device].submit(
                    self._stage_shard_fn, ld.app, loaded, sh.device,
                    self.n_devices)
        if not ld.future.cancel():
            # The commit move may already be past its gate: queue a
            # whole-variant restore behind it on the staging channel.
            self.stage(ld.app, loaded)
        return True

    def _queue_partial(self, ld: ShardedInflightLoad) -> None:
        """Queue the honest credit for an abandoned load: its landed
        shards' transfer really was hidden, so a partial record goes to
        the engine's next reap for overlap measurement."""
        landed = [sh for sh in ld.shards if sh.landed]
        if landed:
            # The online busy values ride along, filtered to the landed
            # shards so they stay parallel to the record's intervals.
            busy = ld.ol_take()
            if busy is not None:
                busy = tuple(b for sh, b in zip(ld.shards, busy)
                             if sh.landed)
            self._partials.append(LoadRecord(
                app=ld.app, bits=ld.variant.bits,
                load_ms=sum(sh.load_ms for sh in landed),
                t_enqueue_ms=ld.t_enqueue_ms,
                t_ready_ms=max(sh.ready_ms for sh in landed),
                demand=ld.demand,
                shard_intervals=tuple(
                    (sh.t_start_ms, sh.ready_ms, sh.load_ms)
                    for sh in landed),
                partial=True,
                overlap_busy=busy))

    def _retire_load(self, ld: ShardedInflightLoad) -> bool:
        """Release an abandoned load and queue its partial credit; False
        (and no release) when the record already left ``staging``."""
        if not self._release_load(ld):
            return False
        self._queue_partial(ld)
        return True

    def cancel(self, app: str,
               now_ms: float) -> Optional[ShardedInflightLoad]:
        """Release the claim shard-by-shard and restore the device; the
        landed shards' transfer still counts toward ``load_overlap_ms``
        (queued for the engine's next reap)."""
        ld = self.inflight.pop(app, None)
        if ld is None or not self._retire_load(ld):
            return None
        self.prefetch_wasted += 1
        self._emit(now_ms, "cancel", app, -ld.charge_mb)
        return ld

    def shrink_inflight(self, app: str, variant: Optional[ModelVariant],
                        now_ms: float
                        ) -> Optional[ShardedInflightLoad]:
        """Sharded shrink: one atomic plan releases the old shard claims
        and reserves the smaller variant's, then the smaller transfer
        restages from ``now`` under a fresh in-flight record (the old
        record leaves ``staging`` first, so no stale path can release
        the new record's claims).  The landed shards' overlap is still
        credited via a partial record."""
        ld = self.inflight.get(app)
        if ld is None or ld.demand or variant is None or not ld.staging:
            return None
        if variant.size_mb >= ld.variant.size_mb:
            return None
        state = self.manager.state
        loaded = state.tenants[app].loaded
        new_charge = variant.size_mb - (loaded.size_mb if loaded else 0.0)
        if new_charge <= 0.0:
            return None  # below residency: that is a cancel, not a shrink
        del self.inflight[app]
        shards = self._build_shards(app, variant, now_ms, new_charge)
        ld.state = "cancelled"  # before the claims move: one-way
        # Release-then-reserve in one transaction — the shrunk claims
        # always fit (strictly less on the same devices), and a failure
        # anywhere would roll the whole exchange back.
        state.apply(A.ResidencyPlan((
            A.CancelPrefetch(app, ld.charge_mb, ld.shard_claims),
            A.Load(app, variant, staged=True, claim_mb=new_charge,
                   shard_claims=tuple(sh.claim_mb for sh in shards)),
        )))
        for sh in ld.shards:
            if sh.future is not None:
                sh.future.cancel()
        ld.future.cancel()
        self._queue_partial(ld)
        new_ld = self._track_load(app, variant, now_ms, new_charge,
                                  shards, demand=ld.demand,
                                  predicted_ms=ld.predicted_ms,
                                  on_action=ld.on_action)
        self.wire_mb_staged += (variant.size_mb
                                * self.wire_ratio(variant))
        self.prefetch_shrunk += 1
        self._emit(now_ms, "shrink", app, -(ld.charge_mb - new_charge))
        return new_ld

    def stage_shards_sync(self, app: str,
                          variant: Optional[ModelVariant]) -> None:
        """Run one whole variant's per-device stream ops concurrently and
        wait them out — the wall-clock shape of a sharded admission-path
        load (and what ``benchmarks.perf_compare`` measures against
        single-stream staging)."""
        futs = [self._device_pools[d].submit(
                    self._stage_shard_fn, app, variant, d, self.n_devices)
                for d in range(self.n_devices)]
        for f in futs:
            f.result()

    def close(self) -> None:
        super().close()
        for pool in self._device_pools:
            pool.shutdown(wait=True)
