"""Elastic serving mesh: chip loss & recovery as transactional drain plans.

A production edge box loses accelerators mid-serve; Edge-MultiAI's
premise — latency-sensitive tenants keep serving under contention — has
to survive that, not just memory pressure.  This module makes device
availability a first-class scheduling input (cf. Liang et al.,
"Model-driven Cluster Resource Management for AI Workloads in Edge
Clouds") by expressing a chip's death as *one* residency plan:

* :class:`FaultSpec` — a declarative chip-down/chip-up schedule on the
  engine clock, carried by ``ServingConfig``;
* :func:`drain_plan` — the pure planner: vacate the dead chip with
  ``MigrateShard`` rehomings where live chips have room, ``Downgrade`` +
  migrate where only a smaller variant fits, ``Unload`` where nothing
  does, plus ``EvictKV`` for sequences holding KV pages on the chip;
* :func:`rebalance_plan` — the reverse migration toward the canonical
  layout when the chip returns;
* :class:`ElasticController` — bridges
  :class:`~repro.distributed.fault_tolerance.FailureInjector` into the
  serving loop: the engine polls it each iteration, and a due ``down``
  event raises :class:`~repro.distributed.fault_tolerance.NodeFailure`
  through the injector, which the controller converts into offline
  ledger/pool bookkeeping + one simulate-validated, all-or-nothing
  drain plan applied through the manager while other tenants keep
  decoding.

Deliberately imports nothing from ``serving.engine``/``serving.server``
(the engine imports *us*): the controller talks to the world through
the manager, the loader protocol, and plain callbacks.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import (TYPE_CHECKING, Callable, Dict, List, Optional,
                    Sequence, Tuple)

from repro.core import actions as A
from repro.core.policies import variant_score
from repro.distributed.fault_tolerance import FailureInjector, NodeFailure

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.manager import EdgeMultiAI
    from repro.core.memory_state import MemoryState
    from repro.core.model_zoo import ModelVariant

__all__ = ["ElasticController", "FaultSpec", "drain_plan",
           "rebalance_plan"]

EPS = A.EPS

# (t_ms, chip, kind) schedule entry kinds.
_KINDS = ("down", "up")


@dataclass(frozen=True)
class FaultSpec:
    """A deterministic chip fault schedule on the engine clock.

    ``events`` is a sequence of ``(t_ms, chip, kind)`` with ``kind`` in
    ``{"down", "up"}``; events fire in time order when the engine clock
    reaches them (events past the end of the trace never fire).  The
    schedule is bridged through a
    :class:`~repro.distributed.fault_tolerance.FailureInjector`
    (``seed`` is its seed), so the same failure authority drives
    training restarts and serving drains.

    ``prob`` makes the ``down`` entries stochastic: each scheduled down
    fires with probability ``prob`` via the injector's counter-based
    ``(seed, step)`` stream, so faulted runs can sweep seeds while one
    seed stays bit-reproducible.  The default ``prob=0.0`` keeps the
    deterministic path: every listed down fires, exactly as before.
    """

    events: Tuple[Tuple[float, int, str], ...] = ()
    seed: int = 0
    prob: float = 0.0

    def __post_init__(self) -> None:
        if not 0.0 <= self.prob <= 1.0:
            raise ValueError(f"fault prob must be in [0, 1], "
                             f"got {self.prob}")
        norm = []
        for ev in self.events:
            t, chip, kind = ev
            if kind not in _KINDS:
                raise ValueError(f"bad fault event kind {kind!r} in {ev}")
            if t < 0 or int(chip) < 0:
                raise ValueError(f"bad fault event {ev}")
            norm.append((float(t), int(chip), str(kind)))
        norm.sort(key=lambda e: e[0])
        object.__setattr__(self, "events", tuple(norm))

    def with_seed(self, seed: int) -> "FaultSpec":
        """The same schedule under a different injector seed — the
        seed-sweep idiom: ``spec.with_seed(s)`` per benchmark seed,
        each run bit-reproducible on its own stream."""
        return replace(self, seed=seed)


def _fill(remaining: float, rooms: Dict[int, float]
          ) -> Optional[List[Tuple[int, float]]]:
    """Greedily place ``remaining`` MB across chips with ``rooms`` free
    (roomiest first, ties to the lowest chip); None when it cannot all
    land."""
    out: List[Tuple[int, float]] = []
    for j in sorted(rooms, key=lambda j: (-rooms[j], j)):
        if remaining <= EPS:
            break
        take = min(remaining, rooms[j])
        if take > EPS:
            out.append((j, take))
            remaining -= take
    return out if remaining <= EPS else None


def drain_plan(state: "MemoryState", dead: int, *, now: float = 0.0
               ) -> Tuple[Tuple[A.Action, ...], Dict[str, int],
                          Tuple[Tuple[str, int], ...], float]:
    """Plan the evacuation of chip ``dead`` (already taken offline, so
    its budget reads zero).

    Tenants holding weights on the chip are handled in *descending*
    ``accuracy · readiness`` order (the :func:`~repro.core.policies.
    variant_score` CostBFE ranks procurement with, evaluated at ``now``;
    ties break by name): the residents worth the most by their next
    predicted request claim the survivors' free room first and migrate
    intact, so the degradation cascade lands on the variants that were
    cheapest to lose.  Per tenant: (a) migrate the dead-chip shard to
    live chips with room (split across chips if needed); (b) else walk
    the zoo down to the largest variant whose (layout-preserving)
    dead-chip share the survivors can absorb, downgrading then
    migrating; (c) else unload.  Sequences holding KV pages on the chip
    are evicted (their pages land in the pool's offline stash) and
    returned as preempted ``(app, seq)`` pairs for the engine to
    requeue.

    Returns ``(actions, counters, preempted, vacated_mb)``.  The plan is
    feasible by construction — the worst case degrades to pure unloads —
    but callers still ``simulate`` before ``apply``.
    """
    led = state.devices
    if led is None:
        raise A.PlanError("drain_plan without a DeviceLedger")
    n = led.n_devices
    used = [led.used_mb(d) for d in range(n)]
    counters = {"migrations": 0, "downgrades": 0, "unloads": 0}
    acts: List[A.Action] = []
    vacated = 0.0

    def rank(app: str) -> float:
        t = state.tenants[app]
        if t.loaded is None:
            return 0.0
        pred = t.predicted_next
        idle = math.inf if pred is None or math.isinf(pred) \
            else max(pred - now, 0.0)
        return variant_score(t.loaded, idle)

    for app in sorted(led.weights, key=lambda a: (-rank(a), a)):
        cur = list(led.weights[app])
        share = cur[dead]
        if share <= EPS:
            continue
        vacated += share
        t = state.tenants[app]
        rooms = {j: led.budgets_mb[j] - used[j]
                 for j in range(n) if j != dead}

        # (a) Rehome the shard as-is.
        placed = _fill(share, rooms)
        if placed is not None:
            for j, mb in placed:
                acts.append(A.MigrateShard(app, dead, j, mb))
                used[j] += mb
                counters["migrations"] += 1
            used[dead] -= share
            continue

        # (b) Downgrade until the (smaller) dead-chip share fits.
        total = sum(cur)
        planned = None
        v = t.loaded
        while v is not None and planned is None:
            v = t.zoo.next_smaller(v)
            if v is None:
                break
            # Layout-preserving projection — exactly what Downgrade will
            # commit through DeviceLedger.projected.
            scale = sum(led.split(app, v)) / total
            proj = [w * scale for w in cur]
            rooms_after = {
                j: led.budgets_mb[j] - used[j] + (cur[j] - proj[j])
                for j in range(n) if j != dead}
            placed = _fill(proj[dead], rooms_after)
            if placed is not None:
                planned = (v, proj, placed)
        if planned is not None:
            v, proj, placed = planned
            # A drain downgrade always targets a lower-bits sibling of
            # the resident variant, so it requantizes in place — the
            # degraded layout lands with zero bytes over the host link.
            acts.append(A.downgrade_action(app, t.loaded, v))
            counters["downgrades"] += 1
            for d in range(n):
                used[d] += proj[d] - cur[d]
            for j, mb in placed:
                acts.append(A.MigrateShard(app, dead, j, mb))
                used[j] += mb
                counters["migrations"] += 1
            used[dead] -= proj[dead]
            continue

        # (c) Nothing fits anywhere: the tenant goes cold.
        acts.append(A.Unload(app))
        counters["unloads"] += 1
        for d in range(n):
            used[d] -= cur[d]

    preempted: Tuple[Tuple[str, int], ...] = ()
    if state.kv_pool is not None:
        preempted = tuple(state.kv_pool.seqs_on_device(dead))
        for app, seq in preempted:
            acts.append(A.EvictKV(app, 0.0, seq=seq))

    return tuple(acts), counters, preempted, vacated


def rebalance_plan(state: "MemoryState", chip: int,
                   *, exclude: Sequence[str] = ()
                   ) -> Tuple[A.Action, ...]:
    """Reverse migration when ``chip`` comes back: move each tenant's
    surplus (held above canonical on the chips that absorbed it) toward
    its canonical share on the restored chip.  Tenants with in-flight
    loads are left alone — their commit re-derives placement anyway."""
    led = state.devices
    if led is None:
        raise A.PlanError("rebalance_plan without a DeviceLedger")
    acts: List[A.Action] = []
    used = list(led.device_used())
    frozen = set(exclude) | set(led.inflight)
    for app in sorted(led.weights):
        if app in frozen:
            continue
        loaded = state.tenants[app].loaded
        if loaded is None:
            continue
        cur = list(led.weights[app])
        canon = led.split(app, loaded)
        deficit = min(canon[chip] - cur[chip],
                      led.budgets_mb[chip] - used[chip])
        if deficit <= EPS:
            continue
        order = sorted((j for j in range(led.n_devices) if j != chip),
                       key=lambda j: (-(cur[j] - canon[j]), j))
        for j in order:
            if deficit <= EPS:
                break
            surplus = cur[j] - canon[j]
            if surplus <= EPS:
                continue
            mb = min(deficit, surplus)
            acts.append(A.MigrateShard(app, j, chip, mb))
            used[j] -= mb
            used[chip] += mb
            cur[j] -= mb
            cur[chip] += mb
            deficit -= mb
    return tuple(acts)


class ElasticController:
    """Fires a :class:`FaultSpec` on the engine clock.

    The engine calls :meth:`poll` each maintenance pass (and folds
    :meth:`next_event_ms` into its idle wake-up), so faults land at
    their scheduled instant even on an idle mesh.  A ``down`` event:

    1. cancels in-flight loads that claim the chip or belong to tenants
       holding weights there (the existing loader lifecycle — budget
       claims unwind shard-by-shard);
    2. takes the ledger budget and KV pages offline;
    3. builds one :func:`drain_plan`, validates it with
       ``state.simulate``, and applies it all-or-nothing through
       ``manager._apply_actions`` — the same mirror path admission
       migration uses, so variant changes restage and ``migrate``
       events flow;
    4. records preempted sequences with the manager so the continuous
       engine requeues them.

    An ``up`` event restores the budget/pages and applies a best-effort
    :func:`rebalance_plan`.  ``on_event(t, kind, app, mb)`` mirrors
    ``chip_down`` / ``chip_up`` / ``drain`` into the engine's audit
    stream; ``on_reshard(app)`` lets a real executor re-place buffers
    after a plan lands.
    """

    def __init__(self, spec: FaultSpec, manager: "EdgeMultiAI",
                 loader=None):
        state = manager.state
        if state.devices is None:
            raise ValueError("elastic serving requires a device ledger "
                             "(LoaderSpec(sharded=True))")
        n = state.devices.n_devices
        for t, chip, kind in spec.events:
            if chip >= n:
                raise ValueError(
                    f"fault event targets chip {chip} of a "
                    f"{n}-device mesh")
        self.spec = spec
        self.manager = manager
        self.loader = loader
        # The training-world failure authority, keyed by schedule index:
        # a scheduled "down" only drains if the injector actually fires.
        # prob > 0 switches the injector to its counter-based (seed,
        # step) stream — the same schedule becomes a seed-sweepable
        # failure distribution.
        if spec.prob > 0.0:
            self.injector = FailureInjector(prob=spec.prob,
                                            seed=spec.seed)
        else:
            self.injector = FailureInjector(
                fail_at_steps=tuple(i for i, ev in enumerate(spec.events)
                                    if ev[2] == "down"),
                seed=spec.seed)
        self._next = 0
        self.on_event: Optional[Callable[[float, str, str, float],
                                         None]] = None
        self.on_reshard: Optional[Callable[[str], None]] = None
        self.chips_lost = 0
        self.chips_recovered = 0
        self.drain_migrations = 0
        self.drain_downgrades = 0
        self.drain_unloads = 0
        self.repromotions = 0
        # Pre-drain variants of tenants a drain degraded, awaiting
        # re-promotion when a chip returns.
        self._demoted: Dict[str, "ModelVariant"] = {}

    # -- engine protocol -------------------------------------------------
    def next_event_ms(self) -> float:
        if self._next >= len(self.spec.events):
            return math.inf
        return self.spec.events[self._next][0]

    def poll(self, now_ms: float) -> None:
        """Fire every schedule entry due at ``now_ms``."""
        while (self._next < len(self.spec.events)
               and self.spec.events[self._next][0] <= now_ms + 1e-9):
            idx = self._next
            _, chip, kind = self.spec.events[idx]
            self._next += 1
            if kind == "down":
                try:
                    self.injector.check(idx)
                except NodeFailure:
                    self._chip_down(chip, now_ms)
            else:
                self._chip_up(chip, now_ms)

    # -- internals -------------------------------------------------------
    def _emit(self, t: float, kind: str, app: str, mb: float) -> None:
        if self.on_event is not None:
            self.on_event(t, kind, app, mb)

    def _affected(self, acts: Sequence[A.Action]) -> Tuple[str, ...]:
        return tuple(sorted({a.app for a in acts
                             if isinstance(a, (A.Downgrade, A.Unload,
                                               A.MigrateShard))}))

    def _chip_down(self, chip: int, now: float) -> None:
        state = self.manager.state
        led = state.devices
        if chip in led._offline:
            return
        # In-flight loads touching the chip unwind through the existing
        # cancel lifecycle before the budget shrinks.
        if self.loader is not None:
            for app in sorted(self.loader.inflight):
                ld = self.loader.inflight[app]
                claims = getattr(ld, "shard_claims", None)
                touches = claims is not None and claims[chip] > EPS
                holds = led.weights.get(app, ())
                holds = bool(holds) and holds[chip] > EPS
                if touches or holds:
                    self.loader.cancel(app, now)
        # Emit before the budget shrinks: the event snapshots per-device
        # budgets, and the drain that reconciles the chip has not
        # applied yet at this instant.
        self._emit(now, "chip_down", f"chip{chip}",
                   -led.budgets_mb[chip])
        led.offline(chip)
        if state.kv_pool is not None:
            state.kv_pool.offline_device(chip)

        acts, counters, preempted, vacated = drain_plan(state, chip,
                                                        now=now)
        if acts:
            msg = state.simulate(A.ResidencyPlan(acts))
            if msg is not None:
                # Pure-shed fallback: always feasible (only frees).
                acts = tuple(
                    [A.Unload(a) for a in sorted(led.weights)
                     if led.weights[a][chip] > EPS]
                    + [A.EvictKV(a, 0.0, seq=s) for a, s in preempted])
                counters = {"migrations": 0, "downgrades": 0,
                            "unloads": sum(
                                1 for a in acts
                                if isinstance(a, A.Unload))}
            # Remember what each degraded tenant held before the drain,
            # so chip_up can restore it.  setdefault: across stacked
            # drains the *original* variant is the re-promotion target.
            for a in acts:
                if isinstance(a, (A.Downgrade, A.Unload)):
                    was = state.tenants[a.app].loaded
                    if was is not None:
                        self._demoted.setdefault(a.app, was)
            self.manager._apply_actions(acts, now=now)
        for app, seq in preempted:
            self.manager.kv_preemptions += 1
            self.manager._preempted.append((app, seq))
        self.chips_lost += 1
        self.drain_migrations += counters["migrations"]
        self.drain_downgrades += counters["downgrades"]
        self.drain_unloads += counters["unloads"]
        self._emit(now, "drain", f"chip{chip}", -vacated)
        if self.on_reshard is not None:
            for app in self._affected(acts):
                self.on_reshard(app)

    def _chip_up(self, chip: int, now: float) -> None:
        state = self.manager.state
        led = state.devices
        if chip not in led._offline:
            return
        restored = led._offline[chip]
        led.online(chip)
        if state.kv_pool is not None:
            state.kv_pool.restore_device(chip)
        self._emit(now, "chip_up", f"chip{chip}", restored)
        acts = rebalance_plan(state, chip)
        if acts and state.simulate(A.ResidencyPlan(acts)) is None:
            self.manager._apply_actions(acts, now=now)
            if self.on_reshard is not None:
                for app in self._affected(acts):
                    self.on_reshard(app)
        self.chips_recovered += 1
        self._repromote(now)

    def _repromote(self, now: float) -> None:
        """Restore the variants a drain degraded, now that capacity is
        back: a staged load through the loader when one is attached (the
        transfer overlaps serving, exactly like a prefetch — committing
        before the tenant's next request makes that admission warm),
        else a synchronous ``Load``.  Each attempt is simulate-validated;
        a target that no longer fits is dropped rather than retried
        forever."""
        state = self.manager.state
        for app in sorted(self._demoted):
            want = self._demoted[app]
            t = state.tenants[app]
            if t.loaded is not None and t.loaded.size_mb >= want.size_mb:
                del self._demoted[app]
                continue
            if self.loader is not None and app in self.loader.inflight:
                continue  # the loader owns this tenant's residency;
                # a later chip_up (or the load itself) resolves it
            if self.loader is not None:
                plan = A.ResidencyPlan(
                    (A.staged_load_action(state, app, want),))
                if state.simulate(plan) is None \
                        and self.loader.execute(plan, now) is not None:
                    self.repromotions += 1
            else:
                # A bare Load is device-blind by design (admission may
                # transiently overshoot a chip mid-downgrade), so mirror
                # the per-device commit check here: fits_variant
                # validates exactly the layout on_load will write.
                plan = A.ResidencyPlan((A.Load(app, want),))
                if state.simulate(plan) is None \
                        and state.devices.fits_variant(app, want):
                    self.manager._apply_actions(plan.actions, now=now)
                    self.repromotions += 1
                    if self.on_reshard is not None:
                        self.on_reshard(app)
            del self._demoted[app]
