from repro.serving.api import (BatchingSpec, LoaderSpec, PredictorSpec,
                               ServingConfig, SimTenant, TenantSpec,
                               build_server)
from repro.serving.batcher import Batch, Batcher, Request
from repro.serving.engine import (EngineEvent, LoaderChannel, RequestResult,
                                  ServingEngine, ServingHost, TenantExecutor,
                                  kv_cache_mb, poisson_trace,
                                  trace_from_workload)
from repro.serving.loader import BackgroundLoader, InflightLoad, LoadRecord
from repro.serving.server import (EdgeServer, MultiTenantServer, ServeResult,
                                  TenantRuntime)
from repro.serving.sharded_loader import (ShardedInflightLoad,
                                          ShardedLoaderChannel, ShardStage)

__all__ = ["Batch", "Batcher", "Request", "EdgeServer", "MultiTenantServer",
           "ServeResult", "TenantRuntime", "ServingEngine", "RequestResult",
           "EngineEvent", "kv_cache_mb", "poisson_trace",
           "trace_from_workload", "BackgroundLoader", "InflightLoad",
           "LoadRecord", "ServingConfig", "TenantSpec", "PredictorSpec",
           "BatchingSpec", "LoaderSpec", "SimTenant", "build_server",
           "ServingHost", "TenantExecutor", "LoaderChannel",
           "ShardedLoaderChannel", "ShardedInflightLoad", "ShardStage"]
