from repro.serving.api import (BatchingSpec, FaultSpec, LoaderSpec,
                               PredictorSpec, ServingConfig, SimTenant,
                               TenantSpec, build_server)
from repro.serving.batcher import Batch, Batcher, Request
from repro.serving.engine import (EngineEvent, LoaderChannel, RequestResult,
                                  ServingEngine, ServingHost, TenantExecutor,
                                  fast_trace_from_workload, kv_cache_mb,
                                  poisson_trace, trace_from_workload)
from repro.serving.loader import BackgroundLoader, InflightLoad, LoadRecord
from repro.serving.server import EdgeServer, ServeResult, TenantRuntime
from repro.serving.sharded_loader import (ShardedInflightLoad,
                                          ShardedLoaderChannel, ShardStage)
from repro.serving.stats import AuditEvent, EventKind, ServingStats

__all__ = ["Batch", "Batcher", "Request", "EdgeServer",
           "ServeResult", "TenantRuntime", "ServingEngine", "RequestResult",
           "EngineEvent", "kv_cache_mb", "poisson_trace",
           "trace_from_workload", "fast_trace_from_workload",
           "BackgroundLoader", "InflightLoad",
           "LoadRecord", "ServingConfig", "TenantSpec", "PredictorSpec",
           "BatchingSpec", "LoaderSpec", "FaultSpec", "SimTenant",
           "build_server", "ServingStats", "AuditEvent", "EventKind",
           "ServingHost", "TenantExecutor", "LoaderChannel",
           "ShardedLoaderChannel", "ShardedInflightLoad", "ShardStage"]
