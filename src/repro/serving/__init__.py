from repro.serving.batcher import Batch, Batcher, Request
from repro.serving.engine import (EngineEvent, RequestResult, ServingEngine,
                                  kv_cache_mb, poisson_trace,
                                  trace_from_workload)
from repro.serving.loader import BackgroundLoader, InflightLoad, LoadRecord
from repro.serving.server import MultiTenantServer, ServeResult, TenantRuntime

__all__ = ["Batch", "Batcher", "Request", "MultiTenantServer",
           "ServeResult", "TenantRuntime", "ServingEngine", "RequestResult",
           "EngineEvent", "kv_cache_mb", "poisson_trace",
           "trace_from_workload", "BackgroundLoader", "InflightLoad",
           "LoadRecord"]
