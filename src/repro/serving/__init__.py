from repro.serving.batcher import Batch, Batcher, Request
from repro.serving.server import MultiTenantServer, ServeResult, TenantRuntime

__all__ = ["Batch", "Batcher", "Request", "MultiTenantServer",
           "ServeResult", "TenantRuntime"]
