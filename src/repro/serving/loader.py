"""Background model-loading pipeline: staging tenant weights off the hot
path (the live-engine half of the paper's iWS-BFE prefetch story).

Table 1 of the paper measures model *load* time at 8-17x inference time —
which is exactly why Edge-MultiAI fires proactive loads at t_pred - Delta
- theta instead of waiting for the request.  PR 1's engine still enacted
every load synchronously inside the admit path, so one tenant's cold
start stalled every other tenant's decode loop.  This module closes that
gap:

* **One staging channel.**  Every physical weight movement — prefetches,
  demand loads, victim downgrades, synchronous admission-path loads —
  funnels through a single worker thread (:meth:`BackgroundLoader.stage`).
  That gives a total order over device mutations that matches the order
  of the accounting mutations on the engine thread, so a victim's
  background downgrade can never land *after* a later reactive reload of
  the same tenant.

* **In-flight memory charges.**  An enqueued load immediately claims the
  memory its commit will add (``MemoryState.reserve_inflight``), so
  eviction/procurement planning against ``free_mb`` cannot double-book
  memory a prefetch already owns; a cancelled prefetch releases the
  charge.  Tenants mid-staging are exempt from victim selection (see
  ``repro.core.policies``) — the loader owns their residency until the
  load commits or is cancelled.

* **Virtual-time completion.**  A load enqueued at virtual time ``t``
  commits at ``t + variant.load_ms`` (the zoo's measured transfer time),
  while the wall-clock ``jax.device_put`` runs on the worker.  The engine
  defers batches whose tenant is mid-staging and keeps serving everyone
  else — the load is *overlapped*, and the overlap is measured
  (``load_overlap_ms``) as the time other tenants spent executing inside
  the load interval.

Every residency mutation here is expressed in the action IR
(:mod:`repro.core.actions`) and committed through the one transactional
applier, ``MemoryState.apply``: :meth:`BackgroundLoader.execute` takes a
:class:`~repro.core.actions.ResidencyPlan`, applies it atomically (a
stale plan rolls back whole — its evictions are *not* left behind), then
translates each action to this loader's physical stage ops; per-action
completion callbacks fire as each action's effect lands (instantaneous
actions immediately, a staged load's at commit).  ``enqueue`` survives
as the ProcurePlan-shaped wrapper.

Lifecycle of one load (the action-record state machine: ``staging`` →
``committed`` | ``cancelled``, one-way — a record that has left
``staging`` can never release its claim again)::

    execute([... , Load(staged=True)])
                   ->  in-flight (claim reserved, evictions enacted,
                       device_put queued on the worker)
        |-- reap(now >= ready_ms)  ->  committed (Load commit applied:
        |                              claim converts to weights,
        |                              awaiting first use)
        |       |-- first admit    ->  prefetch hit (warm) or demand-cold
        |-- shrink_inflight(..)    ->  claim shrunk to a smaller variant
        |                              (one smaller transfer instead of
        |                              cancel-then-demand)
        |-- cancel(..)             ->  cancelled (claim released, device
                                       restored, counted as wasted)
"""
from __future__ import annotations

import math
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.core import actions as A
from repro.core.model_zoo import ModelVariant
from repro.core.policies import ProcurePlan
from repro.distributed.compression import wire_compression_ratio
from repro.serving.events import MonotoneQueue

INF = math.inf

# (t_ms, kind, app, mb) — the engine mirrors these into its audit trail.
LoadEventHook = Callable[[float, str, str, float], None]

# (action, t_ms) — per-action completion hook for LoaderChannel.execute.
ActionHook = Callable[[A.Action, float], None]


@dataclass
class InflightLoad:
    """One background load between enqueue and commit/cancel."""
    app: str
    variant: ModelVariant
    t_enqueue_ms: float
    ready_ms: float  # virtual completion: t_enqueue + variant.load_ms
    charge_mb: float  # in-flight claim = what the commit will add
    demand: bool  # a request is already waiting (vs. predictor-driven)
    predicted_ms: float  # the prediction that justified a prefetch
    future: Future  # the wall-clock device staging task
    # Action-record state machine: "staging" -> "committed"|"cancelled".
    # One-way: release/commit paths check-and-set, so a stale reference
    # (e.g. a cancel racing a shrink's restage) can never double-release
    # the claim — the new record owns it.
    state: str = field(default="staging")
    on_action: Optional[ActionHook] = None  # fires at commit
    # Online overlap accounting (indexed scheduler): the engine folds
    # each execution span into these as it retires — ``ol_ivals`` are
    # the load's transfer intervals, ``ol_busy`` the per-interval busy
    # time accumulated so far, ``ol_key`` the (enqueue, ready) window
    # the accumulation is valid for (an in-place shrink re-times the
    # window, invalidating the accumulated values by key mismatch).
    ol_key: Optional[Tuple[float, float]] = None
    ol_ivals: Optional[List[Tuple[float, float]]] = None
    ol_busy: Optional[List[float]] = None

    @property
    def staging(self) -> bool:
        return self.state == "staging"

    def ol_take(self) -> Optional[Tuple[float, ...]]:
        """The accumulated per-interval busy times, or None when the
        accumulator is absent or stale (then the reap-time span scan is
        the fallback)."""
        if (self.ol_busy is None
                or self.ol_key != (self.t_enqueue_ms, self.ready_ms)):
            return None
        return tuple(self.ol_busy)


@dataclass
class LoadRecord:
    """A committed load, kept until its first admission claims it."""
    app: str
    bits: int
    load_ms: float
    t_enqueue_ms: float
    t_ready_ms: float
    demand: bool
    overlap_ms: float = 0.0  # other tenants' execution inside the window
    # Per-shard transfer intervals ``(t0, t1, cap_ms)`` for mesh-sharded
    # loads; None = one single-stream interval spanning the whole load.
    # The engine measures overlap per interval, so a sharded load's
    # landed shards count honestly even when the load never commits.
    shard_intervals: Optional[Tuple[Tuple[float, float, float], ...]] = None
    partial: bool = False  # landed shards of a cancelled sharded load
    # Per-interval busy time accumulated online by the indexed engine
    # (parallel to the intervals above); None = measure by span scan.
    overlap_busy: Optional[Tuple[float, ...]] = None


class BackgroundLoader:
    """Stages tenant weights to the device off the engine's hot path.

    ``stage_fn(app, variant_or_None)`` performs the physical move (the
    serving runtime passes ``TenantRuntime.set_variant``); accounting-only
    tests can omit it and exercise the charge lifecycle alone.

    ``compress="int8"`` turns on quantize-on-the-wire staging: every
    load ships the int8 payload + per-group scales host→chip and
    dequantizes on land, so a load's *virtual transfer time* is
    ``variant.load_ms ×``
    :func:`~repro.distributed.compression.wire_compression_ratio` while
    the in-flight claim and the committed weights still charge the
    resident footprint (the bytes on the chip are full width after
    dequantize).  ``wire_mb_staged`` counts the MB actually shipped
    over the link; ``inplace_downgrades`` counts variant switches that
    shipped *zero* bytes (``Downgrade(in_place=True)`` — resident
    leaves requantized via the ``quant_matmul`` machinery).
    """

    def __init__(self, manager, stage_fn: Optional[
            Callable[[str, Optional[ModelVariant]], None]] = None,
            compress: Optional[str] = None):
        if compress not in (None, "int8"):
            raise ValueError(
                f"unknown wire compression {compress!r} (None or 'int8')")
        self.manager = manager
        self.compress = compress
        self._stage_fn = stage_fn or (lambda app, variant: None)
        self._pool = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="model-loader")
        # Predictor fits get their own worker: they mutate no device
        # state (so they need no slot in the staging channel's total
        # order), and a 150-step RNN fit queued ahead of a weight move
        # would head-of-line block reap()/stage_sync() in wall clock.
        self._fit_pool = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="predictor-fit")
        self.inflight: Dict[str, InflightLoad] = {}
        # Readiness heap for the indexed scheduler: every (re)timed
        # in-flight load pushes an entry; stale entries (committed /
        # cancelled / shrunk-and-restaged records) are lazily dropped at
        # peek.  ``indexed_ready`` selects it over the linear scan — the
        # engine sets it from ``ServingConfig.scheduler``; both paths
        # return the identical float (min over live ready_ms).
        self.indexed_ready = False
        self._ready = MonotoneQueue()
        self._committed: Dict[str, LoadRecord] = {}
        self.history: List[LoadRecord] = []
        self.on_event: Optional[LoadEventHook] = None
        self._fits: Dict[int, Future] = {}  # in-flight predictor fits
        # Counters surfaced through engine/server stats.
        self.prefetch_hits = 0  # predictor-staged load served warm
        self.prefetch_wasted = 0  # cancelled before any request used it
        self.prefetch_shrunk = 0  # in-flight load shrunk under pressure
        self.demand_loads = 0  # cold admits staged off the loop instead
        self.loads_committed = 0
        self.load_overlap_ms = 0.0
        self.fits_scheduled = 0  # background predictor fits enqueued
        self.wire_mb_staged = 0.0  # MB actually shipped host→chip
        self.inplace_downgrades = 0  # variant switches with zero wire MB

    # -- quantize-on-the-wire staging -------------------------------------
    def wire_ratio(self, variant: ModelVariant) -> float:
        """Fraction of ``variant``'s full-width bytes a transfer ships
        under this channel's compression scheme (1.0 when off)."""
        if self.compress is None:
            return 1.0
        return wire_compression_ratio(variant.bits, scheme=self.compress)

    def _wire_ms(self, variant: ModelVariant) -> float:
        """Virtual host→chip transfer time: the zoo's measured load time
        scaled by the wire ratio — same link, fewer bytes."""
        return variant.load_ms * self.wire_ratio(variant)

    def _count_stage(self, act: A.Action) -> None:
        """Wire accounting for a residency action's physical move: an
        in-place downgrade ships zero bytes (resident leaves are
        requantized on-chip); everything else ships the variant's
        compressed payload; an unload ships nothing."""
        if isinstance(act, A.Downgrade) and act.in_place:
            self.inplace_downgrades += 1
        elif act.variant is not None:
            self.wire_mb_staged += (act.variant.size_mb
                                    * self.wire_ratio(act.variant))

    # -- physical staging channel ---------------------------------------
    def stage(self, app: str, variant: Optional[ModelVariant]) -> Future:
        """Queue a physical weight move on the single worker.  All device
        mutations go through here so they serialize in submission order."""
        return self._pool.submit(self._stage_fn, app, variant)

    def stage_sync(self, app: str, variant: Optional[ModelVariant]) -> None:
        """Hot-path (admission) staging: same channel, but wait for it."""
        self.stage(app, variant).result()

    def submit_fit(self, predictor,
                   steps: Optional[int] = None) -> Optional[Future]:
        """Schedule a predictor's :meth:`fit` on the loader's fit worker —
        the RNN trains in the background once enough inter-arrival
        history accumulates, never on the serving loop and never ahead
        of a weight move (fits ride a separate worker from the staging
        channel).  One fit per predictor at a time: a still-running fit
        dedupes the resubmission (returns None).  ``steps`` defaults to
        the predictor's own ``fit_steps`` (the ``PredictorSpec.fit_steps``
        config knob)."""
        key = id(predictor)
        fut = self._fits.get(key)
        if fut is not None and not fut.done():
            return None
        if steps is None:
            steps = getattr(predictor, "fit_steps", 150)
        fut = self._fit_pool.submit(predictor.fit, steps)
        self._fits[key] = fut
        self.fits_scheduled += 1
        return fut

    def close(self) -> None:
        self._pool.shutdown(wait=True)
        self._fit_pool.shutdown(wait=True)

    # -- load lifecycle --------------------------------------------------
    def _emit(self, t_ms: float, kind: str, app: str, mb: float) -> None:
        if self.on_event is not None:
            self.on_event(t_ms, kind, app, mb)

    def enqueue(self, plan: ProcurePlan, now_ms: float, *,
                demand: bool = False,
                predicted_ms: float = INF) -> Optional[InflightLoad]:
        """Start a background load for ``plan.app``'s chosen variant:
        the ProcurePlan-shaped wrapper over :meth:`execute` — victims'
        evictions plus one staged load, compiled to a ResidencyPlan and
        applied atomically.  Returns None when there is nothing to do
        (already in flight / already resident / the plan would not grow
        the tenant / the plan went stale — in which case *nothing* is
        enacted, evictions included)."""
        if plan is None or plan.variant is None:
            return None
        return self.execute(
            A.ResidencyPlan(A.procure_actions(plan, staged=True)),
            now_ms, demand=demand, predicted_ms=predicted_ms)

    def execute(self, rplan: A.ResidencyPlan, now_ms: float, *,
                demand: bool = False, predicted_ms: float = INF,
                on_action: Optional[ActionHook] = None
                ) -> Optional[InflightLoad]:
        """Enact a :class:`~repro.core.actions.ResidencyPlan` through
        this staging channel.

        The whole plan commits against ``MemoryState`` in one
        transaction (``apply``; an infeasible plan rolls back and
        returns None), then every action is translated to the loader's
        physical ops in plan order: evictions/loads ride the staging
        worker, a ``Load(staged=True)`` becomes an in-flight transfer
        tracked until :meth:`reap` commits it.  ``on_action(action,
        t_ms)`` fires as each action's effect lands — instantaneous
        actions during this call, the staged load's at commit time.
        Returns the in-flight record when the plan staged a transfer.
        """
        rplan = self._concretize(rplan, now_ms)
        if rplan is None:
            return None
        try:
            self.manager.state.apply(rplan)
        except A.PlanError:
            return None  # plan went stale between planning and execute
        ld: Optional[InflightLoad] = None
        for act in rplan:
            staged = self._perform(act, now_ms, demand=demand,
                                   predicted_ms=predicted_ms,
                                   on_action=on_action)
            ld = staged if staged is not None else ld
        return ld

    # -- plan translation hooks (overridden by the sharded channel) ------
    def _concretize(self, rplan: A.ResidencyPlan, now_ms: float
                    ) -> Optional[A.ResidencyPlan]:
        """Resolve staged loads to concrete claims; None = nothing to do
        (duplicate in-flight load, or a plan that would not grow the
        tenant — downgrades are admission-time decisions)."""
        state = self.manager.state
        acts = []
        for act in rplan:
            if isinstance(act, A.Load) and act.staged:
                t = state.tenants[act.app]
                if act.app in self.inflight:
                    return None
                if t.loaded is not None and \
                        act.variant.size_mb <= t.loaded.size_mb:
                    return None
                act = A.concretize_load(act, state)
            acts.append(act)
        return A.ResidencyPlan(tuple(acts))

    def _perform(self, act: A.Action, now_ms: float, *, demand: bool,
                 predicted_ms: float,
                 on_action: Optional[ActionHook]
                 ) -> Optional[InflightLoad]:
        """Translate one applied action to this loader's physical ops."""
        if isinstance(act, A.Load) and act.staged:
            ld = InflightLoad(
                app=act.app, variant=act.variant, t_enqueue_ms=now_ms,
                ready_ms=now_ms + self._wire_ms(act.variant),
                charge_mb=act.claim_mb, demand=demand,
                predicted_ms=predicted_ms,
                future=self.stage(act.app, act.variant),
                on_action=on_action)
            self.inflight[act.app] = ld
            self._ready.push(ld.ready_ms, (act.app, ld))
            self.wire_mb_staged += (act.variant.size_mb
                                    * self.wire_ratio(act.variant))
            if demand:
                self.demand_loads += 1
            self._emit(now_ms, "demand" if demand else "prefetch",
                       act.app, act.claim_mb)
            return ld
        if isinstance(act, A.RESIDENCY_ACTIONS):
            self._count_stage(act)
            self.stage(act.app, act.variant)
        if on_action is not None:
            on_action(act, now_ms)
        return None

    def _ready_live(self, t: float, payload) -> bool:
        """A heap entry is live iff its record is still the in-flight
        load for its tenant, still staging, and still timed at ``t`` —
        commits, cancels, and shrink restages all invalidate by value."""
        app, ld = payload
        return (self.inflight.get(app) is ld and ld.staging
                and ld.ready_ms == t)

    def earliest_ready(self) -> float:
        if self.indexed_ready:
            return self._ready.peek(self._ready_live)
        return min((ld.ready_ms for ld in self.inflight.values()),
                   default=INF)

    def reap(self, now_ms: float) -> List[LoadRecord]:
        """Commit every load whose virtual completion has passed: release
        the in-flight charge and charge the variant as loaded weights (a
        net zero on ``free_mb``, so commits never trip the budget).  The
        wall-clock staging is awaited here — the virtual clock says the
        transfer is done, so any real lag is absorbed now, off the other
        tenants' critical path."""
        out = []
        state = self.manager.state
        for app in [a for a, ld in self.inflight.items()
                    if ld.ready_ms <= now_ms]:
            ld = self.inflight.pop(app)
            if not ld.staging:
                continue  # a stale record cannot commit twice
            ld.future.result()
            commit = A.Load(app, ld.variant, claim_mb=ld.charge_mb)
            state.apply(A.ResidencyPlan((commit,)))
            ld.state = "committed"
            rec = LoadRecord(
                app=app, bits=ld.variant.bits,
                # Wire time, not the zoo's full-width load_ms: with
                # compression on, the transfer interval (and the
                # overlap it can hide) really is shorter.
                load_ms=ld.ready_ms - ld.t_enqueue_ms,
                t_enqueue_ms=ld.t_enqueue_ms, t_ready_ms=ld.ready_ms,
                demand=ld.demand, overlap_busy=ld.ol_take())
            self._committed[app] = rec
            self.history.append(rec)
            self.loads_committed += 1
            self._emit(ld.ready_ms, "load", app, ld.variant.size_mb)
            if ld.on_action is not None:
                ld.on_action(commit, ld.ready_ms)
            out.append(rec)
        return out

    def peek_use(self, app: str) -> Optional[LoadRecord]:
        """The committed-but-unused load the next admission will consume."""
        return self._committed.get(app)

    def take_use(self, app: str, warm: bool) -> Optional[LoadRecord]:
        """An admission for ``app`` succeeded: claim its pending commit.
        A predictor-staged load that serves warm is the payoff the whole
        pipeline exists for — count it."""
        rec = self._committed.pop(app, None)
        if rec is not None and warm and not rec.demand:
            self.prefetch_hits += 1
        return rec

    def shrink_inflight(self, app: str, variant: Optional[ModelVariant],
                        now_ms: float) -> Optional[InflightLoad]:
        """Shrink an in-flight *speculative* load to a smaller variant
        under memory pressure: release the claim difference and restage
        the smaller transfer from ``now``.  If the prediction was right,
        the tenant still warm-starts (degraded) — one smaller transfer
        instead of cancel-now-plus-demand-load-later.  Demand loads are
        never shrunk (their variant was planned against a waiting
        batch's cache needs).  Returns the updated load, or None when
        there is nothing to shrink (not in flight / not smaller / the
        target is not above what is already resident)."""
        ld = self.inflight.get(app)
        if ld is None or ld.demand or variant is None or not ld.staging:
            return None
        if variant.size_mb >= ld.variant.size_mb:
            return None
        state = self.manager.state
        loaded = state.tenants[app].loaded
        new_charge = variant.size_mb - (loaded.size_mb if loaded else 0.0)
        if new_charge <= 0.0:
            return None  # below residency: that is a cancel, not a shrink
        freed = ld.charge_mb - new_charge
        state.apply(A.ResidencyPlan((A.Shrink(app, variant, freed),)))
        # Restage the smaller variant; if the big move already ran (or is
        # running) the new stage lands after it on the same worker, so
        # the device converges to the shrunk variant either way.  The
        # overlap window restarts at *now*: the abandoned transfer hid
        # nothing worth crediting, and measuring the small load over the
        # big load's interval would inflate load_overlap_ms.
        ld.future.cancel()
        ld.variant = variant
        ld.charge_mb = new_charge
        ld.t_enqueue_ms = now_ms
        ld.ready_ms = now_ms + self._wire_ms(variant)
        self._ready.push(ld.ready_ms, (app, ld))  # re-time: old entry stale
        ld.future = self.stage(app, variant)
        self.wire_mb_staged += (variant.size_mb
                                * self.wire_ratio(variant))
        self.prefetch_shrunk += 1
        self._emit(now_ms, "shrink", app, -freed)
        return ld

    def cancel(self, app: str, now_ms: float) -> Optional[InflightLoad]:
        """The predictor was wrong (or the caller changed its mind):
        release the in-flight charge and restore the device to what the
        accounting says is loaded, in case the staging already ran."""
        ld = self.inflight.pop(app, None)
        if ld is None or not ld.staging:
            return None
        ld.state = "cancelled"  # before the release: one-way, no repeats
        state = self.manager.state
        state.apply(A.ResidencyPlan(
            (A.CancelPrefetch(app, ld.charge_mb),)))
        self.prefetch_wasted += 1
        if not ld.future.cancel():
            # The worker already staged (or is staging) the new variant:
            # queue a restore so device contents match the accounting.
            self.stage(app, state.tenants[app].loaded)
        self._emit(now_ms, "cancel", app, -ld.charge_mb)
        return ld

    def cancel_stale(self, now_ms: float,
                     delta_ms: "float | Callable[[str], float]",
                     has_queued: Callable[[str], bool]) -> int:
        """Cancel predictor-driven prefetches whose predicted request
        window has fully passed with no request in sight — the in-flight
        memory goes back to the pool instead of squatting on a wrong
        guess.  Demand loads are never stale (a batch is waiting).
        ``delta_ms`` may be a per-tenant callable (the adaptive window's
        ``delta_for``), so staleness agrees with the same Δ the window
        checks use."""
        def delta(app: str) -> float:
            return delta_ms(app) if callable(delta_ms) else delta_ms

        stale = [a for a, ld in self.inflight.items()
                 if not ld.demand and not has_queued(a)
                 and now_ms > ld.predicted_ms + delta(a)]
        for app in stale:
            self.cancel(app, now_ms)
        return len(stale)
