"""Typed serving telemetry: event kinds, audit records, stats schema.

Six PRs of serving work accreted telemetry as loose strings and dict
keys — ``ev.kind == "migrate"``, ``stats()["warm_ratio"]``, new keys
appearing whenever a subsystem (loader, mesh, paged KV, elastic) was
attached.  This module is the one place that schema lives:

* :class:`EventKind` — every audit/engine event kind as a ``str``-enum,
  so ``ev.kind == "admit"`` keeps working while typos become errors;
* :class:`AuditEvent` — the frozen ``(kind, t, app, detail)`` record
  every stringly callback normalizes into;
* :class:`ServingStats` — the frozen result of ``engine.run_trace`` /
  ``engine.stats()`` / ``server.stats()``.  Core fields are always
  populated; subsystem blocks (loader, mesh, paged KV, elastic,
  server-level gauges) are ``None`` until that subsystem is attached,
  and :meth:`ServingStats.to_dict` drops the ``None`` fields so the
  benchmark CSV path sees exactly the keys the old dict had.

This is deliberately a leaf module (stdlib imports only): engine,
server, and api all import it without cycles.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, fields
from typing import Any, Dict, Optional, Tuple

__all__ = ["AuditEvent", "EventKind", "ServingStats"]


class EventKind(str, enum.Enum):
    """Every audit/engine event kind.  ``str``-valued so existing
    comparisons (``ev.kind == "admit"``, ``ev.kind in (...)``) hold."""

    # Request lifecycle (engine).
    SUBMIT = "submit"
    ADMIT = "admit"
    REJECT = "reject"
    RETIRE = "retire"
    FREE_KV = "free_kv"
    PREEMPT = "preempt"
    # Loader pipeline.
    PREFETCH = "prefetch"
    DEMAND = "demand"
    LOAD = "load"
    CANCEL = "cancel"
    SHRINK = "shrink"
    # Memory-state audit.
    MIGRATE = "migrate"
    KV_OVERRELEASE = "kv_overrelease"
    # Elastic mesh (chip loss & recovery).
    CHIP_DOWN = "chip_down"
    CHIP_UP = "chip_up"
    DRAIN = "drain"
    # Cluster tier (cross-server tenant movement).
    HANDOFF = "handoff"

    def __str__(self) -> str:  # keep f-string formatting as the raw kind
        return self.value


@dataclass(frozen=True)
class AuditEvent:
    """One normalized audit record.

    ``detail`` is the event's MB delta (weights moved, KV charged or
    freed, claim cancelled, ...); sign follows the ledger (frees and
    cancels are negative).  ``app`` is the tenant, or a synthetic name
    like ``chip3`` for mesh-level events.
    """

    kind: EventKind
    t: float
    app: str
    detail: float

    def __str__(self) -> str:
        return (f"[{self.t:8.0f}ms] {self.kind.value:8s} {self.app:16s} "
                f"{self.detail:+8.3f}MB")


@dataclass(frozen=True)
class ServingStats:
    """The typed result of a serving run.

    Core fields are always set.  Each ``Optional`` block is ``None``
    until the matching subsystem is attached (then every field in the
    block is populated), and :meth:`to_dict` drops ``None`` fields —
    the dict therefore has exactly the keys the subsystems earned.
    """

    # --- core (always populated) -----------------------------------
    requests: int = 0
    warm_ratio: float = 0.0           # admitted on already-resident weights
    kv_downgrades: int = 0
    kv_rejections: int = 0
    weight_failures: int = 0
    kv_overrelease_mb: float = 0.0    # release drift; 0.0 when healthy
    prediction_hit_rate: float = 0.0
    per_tenant: Dict[str, Dict[str, float]] = None  # type: ignore[assignment]

    # --- throughput (needs >= 1 completed request) ------------------
    requests_per_sec: Optional[float] = None

    # --- background loader pipeline ---------------------------------
    prefetch_hits: Optional[int] = None
    prefetch_wasted: Optional[int] = None
    prefetch_shrunk: Optional[int] = None
    demand_loads: Optional[int] = None
    loads_committed: Optional[int] = None
    load_overlap_ms: Optional[float] = None
    fits_scheduled: Optional[int] = None
    shards_landed: Optional[int] = None   # sharded loader only
    # Quantize-on-the-wire staging: MB actually shipped host→chip (the
    # compressed payload under LoaderSpec(compress="int8")) and variant
    # switches that shipped zero bytes (in-place requantization).
    wire_mb_staged: Optional[float] = None
    inplace_downgrades: Optional[int] = None

    # --- device mesh -------------------------------------------------
    shards_migrated: Optional[int] = None
    device_used_mb: Optional[Tuple[float, ...]] = None
    device_budget_mb: Optional[Tuple[float, ...]] = None

    # --- paged KV (continuous batching) ------------------------------
    kv_page_mb: Optional[float] = None
    kv_pages_total: Optional[int] = None
    kv_pages_used: Optional[int] = None
    kv_preemptions: Optional[int] = None

    # --- elastic mesh (fault schedule configured) --------------------
    chips_lost: Optional[int] = None
    chips_recovered: Optional[int] = None
    drain_migrations: Optional[int] = None
    drain_downgrades: Optional[int] = None
    # Variants the drain degraded that chip_up restored.
    repromotions: Optional[int] = None

    # --- cluster tier (EdgeCluster.stats() only) ---------------------
    # Fleet-level block: router name, routed/spilled/handed-off counts,
    # and per-server request/warm-ratio tuples.  None on single-server
    # stats, so the dict keys only exist when a cluster produced them.
    cluster: Optional[Dict[str, Any]] = None

    # --- server-level gauges (EdgeServer.stats() only) ---------------
    redispatched: Optional[int] = None
    resident_mb: Optional[float] = None
    weights_mb: Optional[float] = None
    kv_mb: Optional[float] = None
    fail_ratio: Optional[float] = None
    mean_latency_s: Optional[float] = None
    predictor_fits: Optional[int] = None
    # Residual-adapted prediction window per tenant (adaptive-delta
    # servers only).
    delta_ms: Optional[Dict[str, float]] = None

    def __post_init__(self) -> None:
        if self.per_tenant is None:
            object.__setattr__(self, "per_tenant", {})

    def to_dict(self) -> Dict[str, Any]:
        """Flatten to the historical stats dict, dropping unset blocks."""
        out: Dict[str, Any] = {}
        for f in fields(self):
            val = getattr(self, f.name)
            if val is None:
                continue
            out[f.name] = val
        return out
