"""Indexed event scheduling: the primitives behind ``scheduler="indexed"``.

The serving engine's virtual clock only ever needs *when does the next
thing happen*.  The linear-scan engine answers that by rescanning O(n)
collections every idle step — ``min(ld.ready_ms for ld in inflight)``
over loader records, a fresh ``predict_next_time()`` per tenant (which
re-materializes the tenant's full arrival history as a numpy array), and
so on.  The indexed engine answers it from incremental structures:

* **Load readiness** — a lazy-deletion min-heap (:class:`MonotoneQueue`)
  keyed by ``ready_ms``.  Loaders push an entry whenever a record's
  readiness is (re)established; entries whose payload no longer matches
  the live record are discarded at pop time instead of being searched
  for and removed.
* **Prediction triggers** — a per-tenant memo of ``predict_next_time()``
  keyed on the predictor's observable state (history length, fit count,
  last arrival), so the O(history) forward pass runs once per state
  change instead of once per maintenance pass (see
  ``EdgeServer._predict_time``).
* **Fault schedule** — already an indexed cursor
  (``ElasticController.next_event_ms`` reads ``events[self._next]``);
  the unified wake computation consumes it as-is.
* **Arrivals / step boundaries** — the trace cursor and the continuous
  batcher's step clock, both already incremental.

Tie-break contract
------------------
The engine consumes these sources by **value only**: the wake time is
``min()`` over the candidate timestamps, and the engine then re-derives
*what* to do from current state exactly as the linear path does.  Two
sources proposing the same timestamp therefore cannot reorder any
action, which is what makes the heap refactor bit-exact — it must (and
does) reproduce the same float the linear scan would have computed, and
nothing else about scan order can leak into behavior.  This is asserted
end-to-end by ``tests/test_engine_equivalence.py``.
"""
from __future__ import annotations

import heapq
import math
from typing import Any, Callable, List, Tuple

__all__ = ["MonotoneQueue"]


class MonotoneQueue:
    """Lazy-deletion min-heap of ``(time_ms, payload)`` events.

    ``push`` is O(log n); ``peek(valid)`` discards stale heads (entries
    whose ``valid(time_ms, payload)`` predicate fails) and returns the
    earliest live timestamp, or ``inf`` when none remain.  Stale entries
    arise when a record is committed, cancelled, or re-timed in place:
    rather than deleting from the middle of the heap, the producer
    pushes a fresh entry and the old one is dropped here on first
    contact.  Insertion order breaks timestamp ties (FIFO), though the
    engine consumes timestamps by value only — see the module docstring.
    """

    __slots__ = ("_heap", "_seq")

    def __init__(self) -> None:
        self._heap: List[Tuple[float, int, Any]] = []
        self._seq = 0

    def __len__(self) -> int:
        return len(self._heap)

    def push(self, time_ms: float, payload: Any = None) -> None:
        heapq.heappush(self._heap, (time_ms, self._seq, payload))
        self._seq += 1

    def peek(self, valid: Callable[[float, Any], bool]) -> float:
        """Earliest timestamp whose payload is still live, else inf."""
        heap = self._heap
        while heap:
            t, _, payload = heap[0]
            if valid(t, payload):
                return t
            heapq.heappop(heap)
        return math.inf

    def clear(self) -> None:
        self._heap.clear()
