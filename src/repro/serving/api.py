"""`repro.serving.api` — the declarative front door for the serving stack.

One config tree, one entry point::

    from repro.serving.api import EdgeServer, ServingConfig, TenantSpec

    srv = EdgeServer.build(ServingConfig(
        tenants=(TenantSpec("tinyllama-1.1b"), TenantSpec("gemma2-2b")),
        policy="iws-bfe",                    # any registered Policy
        batching=BatchingSpec(max_batch=4),
    ))
    stats = srv.engine.run_trace(trace)

``build`` performs every piece of wiring the benchmarks, examples, and
launcher used to repeat by hand: resolve each tenant's model config,
initialize and quantize its zoo (or attach a sim-time executor), install
the arrival predictor, derive the contended memory budget, resolve the
policy through the registry, and attach the background loader + engine.
The imperative ``EdgeServer(...)`` / ``register`` / ``start`` path stays
public underneath for callers with custom params.

Specs are frozen dataclasses with a ``to_dict``/``from_dict`` round trip
so a serving deployment is one JSON-able document.
"""
from __future__ import annotations

import dataclasses
import zlib
from dataclasses import dataclass, field
from typing import Any, Optional, Tuple, Union

import numpy as np

from repro.core.manager import LOAD_OVER_INFER
from repro.core.model_zoo import ModelVariant, zoo_from_config
from repro.core.policies import Policy, resolve_policy
from repro.core.predictor import RequestPredictor
from repro.models.config import ModelConfig
from repro.serving.elastic import FaultSpec
from repro.serving.server import EdgeServer
from repro.serving.stats import AuditEvent, EventKind, ServingStats

__all__ = ["EdgeServer", "ServingConfig", "TenantSpec", "PredictorSpec",
           "BatchingSpec", "LoaderSpec", "FaultSpec", "SimTenant",
           "ServingStats", "AuditEvent", "EventKind", "build_server"]


# ---------------------------------------------------------------------------
# The config tree
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class TenantSpec:
    """One application: which architecture, which precision variants.

    ``arch`` defaults to ``name`` (the registered config name); ``seed``
    defaults to a stable digest of the name so parameter init is
    reproducible across processes without coordinating seeds.
    ``service_ms`` overrides the sim executor's virtual batch service
    time (default: derived from the loaded variant's load cost via the
    paper's load/infer asymmetry) — the knob that lets a trace build
    real queue depth; ignored by the real executor, whose service time
    is measured.

    >>> TenantSpec("tinyllama-1.1b", precisions=(16, 8)).config_name
    'tinyllama-1.1b'
    """
    name: str
    arch: Optional[str] = None
    precisions: Tuple[int, ...] = (16, 8)
    reduced: bool = True
    seed: Optional[int] = None
    service_ms: Optional[float] = None

    @property
    def config_name(self) -> str:
        return self.arch or self.name

    @property
    def init_seed(self) -> int:
        if self.seed is not None:
            return self.seed
        return zlib.crc32(self.name.encode()) & 0x7FFFFFFF


@dataclass(frozen=True)
class PredictorSpec:
    """Per-tenant RNN arrival-predictor shape and its background-training
    schedule (fits run on the loader's staging worker)."""
    context: int = 8
    hidden: int = 16
    min_fit_samples: int = 24
    refit_interval: int = 16
    fit_steps: int = 150


@dataclass(frozen=True)
class BatchingSpec:
    """``continuous=True`` switches the engine to continuous batching:
    the request (not the batch) is the admission unit — each request
    charges its own page-rounded KV need against a
    :class:`~repro.core.memory_state.KVPagePool`, joins the running
    decode batch per step, and frees its pages the step it retires.
    ``kv_page_mb`` is the page size knob (0 = auto: the largest
    tenant's 8-token decode cache); smaller pages waste less memory per
    request, larger pages keep the page tables shorter.

    >>> BatchingSpec(max_batch=4, window_ms=20.0).continuous
    False
    """
    max_batch: int = 8
    window_ms: float = 0.0
    continuous: bool = False
    kv_page_mb: float = 0.0


@dataclass(frozen=True)
class LoaderSpec:
    """``prefetch=False`` is the reactive baseline: no background loader,
    every weight move synchronous inside the admit path.

    ``sharded=True`` serves from a device mesh: tenant weights shard
    across ``mesh_shape`` (1-D = pure tensor parallel ``("model",)``,
    2-D = ``("data", "model")``) via the real partition rules, the
    loader stages per-shard on per-device streams, and ``MemoryState``
    gains per-chip budget ledgers (``device_budget_mb`` per chip; None
    derives a budget that covers the replication overhead, so tighter
    values deliberately exercise the whole-load-failure path; a tuple
    gives *per-chip* budgets — a deliberately skewed mesh).  Requires
    ``prefetch=True`` — the reactive engine has no staging channel to
    decompose.

    ``migrate=True`` (default) arms cross-device victim migration: a
    load blocked by one chip's budget moves a resident victim's shards
    to chips with room (``MigrateShard`` actions, committed atomically
    with the load) instead of failing into the downgrade path.
    ``migrate=False`` keeps the PR-4 downgrade-only behaviour — the
    benchmark's A/B baseline.

    ``compress="int8"`` stages **compressed bytes** host→chip: every
    load (both loader channels) ships the int8 payload plus per-group
    scales instead of full-width leaves and dequantizes on land, so the
    virtual transfer time shrinks by
    :func:`repro.distributed.compression.wire_compression_ratio` (bf16
    → ~0.56×) while ``inflight_mb`` claims and the ``DeviceLedger``
    still charge the *resident* footprint.  ``None`` (default) stages
    full-width.

    >>> LoaderSpec(sharded=True, mesh_shape=(4,), compress="int8").compress
    'int8'
    """
    prefetch: bool = True
    sharded: bool = False
    mesh_shape: Tuple[int, ...] = (8,)
    device_budget_mb: "Optional[float | Tuple[float, ...]]" = None
    migrate: bool = True
    compress: Optional[str] = None

    def __post_init__(self):
        object.__setattr__(self, "mesh_shape", tuple(self.mesh_shape))
        if isinstance(self.device_budget_mb, (tuple, list)):
            object.__setattr__(self, "device_budget_mb",
                               tuple(float(b)
                                     for b in self.device_budget_mb))
        if self.sharded and not self.prefetch:
            raise ValueError(
                "LoaderSpec(sharded=True) requires prefetch=True")
        if self.sharded and not (1 <= len(self.mesh_shape) <= 2):
            raise ValueError(
                f"mesh_shape must be 1-D or 2-D, got {self.mesh_shape}")
        if self.compress not in (None, "int8"):
            raise ValueError(
                f"unknown wire compression {self.compress!r} "
                "(None or 'int8')")


@dataclass(frozen=True)
class ServingConfig:
    """Everything ``EdgeServer.build`` needs, in one declarative tree.

    ``budget_mb=None`` derives the standard contended budget from the
    registered zoos (every tenant resident at its smallest variant, room
    to upgrade the widest zoo, 5% slack) plus KV headroom —
    ``kv_headroom_mb`` directly, and/or ``kv_headroom_shape=(batch,
    total_len)`` for the largest decode cache the workload will admit.

    ``policy`` resolves through the policy registry (a name like
    ``"iws-bfe"`` or ``"batch-bfe"``, a Policy class, or an instance);
    ``"none"`` is the paper's unmanaged baseline (no procurement
    authority).  ``fallback`` is the last-resort eviction backstop
    (``"desperation"`` or ``"none"``).  ``executor="sim"`` swaps every
    tenant for a deterministic sim-time executor — no XLA, virtual
    service times — for tests and capacity modelling.
    """
    tenants: Tuple[TenantSpec, ...]
    budget_mb: Optional[float] = None
    kv_headroom_mb: float = 0.0
    kv_headroom_shape: Optional[Tuple[int, int]] = None
    policy: Union[str, Policy, type] = "iws-bfe"
    fallback: Union[str, None, Any] = "desperation"
    delta_ms: float = 500.0
    # Adapt each tenant's Δ from its measured arrival residuals (EWMA of
    # |t_actual − t_pred|) instead of the fixed delta_ms — closes the
    # predictor-quality loop behind prediction_hit_rate.  Off by default
    # (the paper's fixed window).
    adaptive_delta: bool = False
    history_ms: float = 3000.0
    batching: BatchingSpec = field(default_factory=BatchingSpec)
    loader: LoaderSpec = field(default_factory=LoaderSpec)
    predictor: PredictorSpec = field(default_factory=PredictorSpec)
    executor: str = "real"  # "real" | "sim"
    straggler_deadline_s: float = 30.0
    # Chip-fault schedule (elastic mesh): chip-down/chip-up events on the
    # engine clock, each down firing one transactional drain plan.
    # Requires LoaderSpec(sharded=True) — the drain planner works the
    # per-device ledger.
    fault: Optional[FaultSpec] = None
    # Audit level: "full" (default) records per-event usage/device
    # snapshots — what the invariant tests replay; "counters" keeps
    # only event counts, for large-scale replays where the snapshots
    # dominate the hot path.
    audit: str = "full"
    # Event scheduling: "indexed" (default) answers idle wake-ups from
    # incremental structures (loader readiness heap, memoized prediction
    # triggers, online overlap accounting); "linear" is the retained
    # pre-refactor reference path that rescans per step.  Both produce
    # bit-identical audit trails and stats.
    scheduler: str = "indexed"

    def __post_init__(self):
        if not self.tenants:
            raise ValueError("ServingConfig needs at least one TenantSpec")
        if self.audit not in ("full", "counters"):
            raise ValueError(
                f"audit must be 'full' or 'counters', got {self.audit!r}")
        if self.scheduler not in ("indexed", "linear"):
            raise ValueError(
                "scheduler must be 'indexed' or 'linear', got "
                f"{self.scheduler!r}")
        if self.fault is not None and not self.loader.sharded:
            raise ValueError(
                "ServingConfig(fault=...) requires "
                "LoaderSpec(sharded=True) — chip faults drain a device "
                "ledger")
        names = [t.name for t in self.tenants]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tenant names in {names}")
        if self.executor not in ("real", "sim"):
            raise ValueError(
                f"executor must be 'real' or 'sim', got {self.executor!r}")
        # Fail at declaration time, not at start(): unknown policy names
        # raise here with the registered set in the message.  "none" is
        # the unmanaged baseline, handled by the manager itself.
        if self.policy != "none":
            resolve_policy(self.policy)

    # -- serialization round trip ---------------------------------------
    def to_dict(self) -> dict:
        from repro.core.policies import available_policies
        d = dataclasses.asdict(self)
        if not isinstance(self.policy, str):
            name = resolve_policy(self.policy).name
            if name not in available_policies():
                raise ValueError(
                    f"policy {type(self.policy).__name__!r} (name="
                    f"{name!r}) is not registered — @register_policy it "
                    f"to make the config serializable")
            d["policy"] = name
        if not isinstance(d.get("fallback"), (str, type(None))):
            name = self.fallback.name
            if name not in ("desperation", "none"):
                raise ValueError(
                    f"fallback {type(self.fallback).__name__!r} has no "
                    f"serializable name; pass 'desperation'/'none' or "
                    f"keep the instance form for in-process use")
            d["fallback"] = name
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "ServingConfig":
        d = dict(d)
        d["tenants"] = tuple(
            t if isinstance(t, TenantSpec)
            else TenantSpec(**{**t, "precisions": tuple(t["precisions"])})
            for t in d["tenants"])
        for key, spec_cls in (("batching", BatchingSpec),
                              ("loader", LoaderSpec),
                              ("predictor", PredictorSpec),
                              ("fault", FaultSpec)):
            if key in d and isinstance(d[key], dict):
                d[key] = spec_cls(**d[key])
        if d.get("kv_headroom_shape") is not None:
            d["kv_headroom_shape"] = tuple(d["kv_headroom_shape"])
        return cls(**d)


# ---------------------------------------------------------------------------
# Sim-time executor: the TenantExecutor protocol without XLA
# ---------------------------------------------------------------------------
class SimTenant:
    """Deterministic ``TenantExecutor``: zoo sizes from exact parameter
    math (:func:`zoo_from_config`, no weights materialized), zero-token
    outputs, and a *virtual* service time derived from the loaded
    variant's load cost via the paper's load/infer asymmetry — so a full
    engine run is reproducible bit-for-bit with no XLA and no wall-clock
    jitter."""

    def __init__(self, name: str, cfg: ModelConfig,
                 precisions: Tuple[int, ...] = (16, 8),
                 predictor: Optional[RequestPredictor] = None,
                 service_ms: Optional[float] = None):
        self.name = name
        self.cfg = cfg
        self.zoo = zoo_from_config(cfg, precisions=tuple(precisions))
        self.predictor = predictor or RequestPredictor(context=8, hidden=16)
        self.service_ms = service_ms  # None => variant.load_ms / asymmetry
        self.loaded_bits: Optional[int] = None

    # -- loader callback target -----------------------------------------
    def set_variant(self, variant: Optional[ModelVariant]) -> None:
        self.loaded_bits = variant.bits if variant else None

    # -- TenantExecutor protocol -----------------------------------------
    def execute(self, batch, extra: Optional[dict] = None
                ) -> Tuple[np.ndarray, float]:
        assert self.loaded_bits is not None, f"{self.name}: not loaded"
        virt = (self.service_ms if self.service_ms is not None
                else self.zoo.by_bits(self.loaded_bits).load_ms
                / LOAD_OVER_INFER)
        tokens = np.zeros((len(batch.requests), batch.max_new), np.int32)
        return tokens, virt


# ---------------------------------------------------------------------------
# The wiring ``EdgeServer.build`` performs
# ---------------------------------------------------------------------------
def build_server(config: ServingConfig, cls=None):
    """Resolve a :class:`ServingConfig` into a started server: register
    every tenant (real quantized zoos or sim executors), install
    predictors, derive the budget, and ``start()`` the manager + loader +
    engine.  This is the only construction path the benchmarks, examples,
    and launcher use."""
    from repro.serving.engine import kv_cache_mb

    cls = cls or EdgeServer
    srv = cls(budget_mb=config.budget_mb or 0.0,
              policy=config.policy,
              fallback=config.fallback,
              delta_ms=config.delta_ms,
              adaptive_delta=config.adaptive_delta,
              history_ms=config.history_ms,
              straggler_deadline_s=config.straggler_deadline_s,
              max_batch=config.batching.max_batch,
              batch_window_ms=config.batching.window_ms,
              continuous=config.batching.continuous,
              kv_page_mb=config.batching.kv_page_mb,
              prefetch=config.loader.prefetch,
              sharded_mesh=(config.loader.mesh_shape
                            if config.loader.sharded else None),
              device_budget_mb=config.loader.device_budget_mb,
              migrate=config.loader.migrate,
              compress=config.loader.compress,
              fault=config.fault,
              audit=config.audit,
              scheduler=config.scheduler)
    ps = config.predictor
    for spec in config.tenants:
        from repro.configs import get_config
        cfg = get_config(spec.config_name, reduced=spec.reduced)
        predictor = RequestPredictor(
            context=ps.context, hidden=ps.hidden,
            min_fit_samples=ps.min_fit_samples,
            refit_interval=ps.refit_interval,
            fit_steps=ps.fit_steps)
        # The linear reference scheduler keeps the pre-refactor
        # O(history) predict cost (bit-identical values either way) so
        # engine_scale's A/B measures against a faithful baseline.
        predictor.full_history_predict = config.scheduler == "linear"
        if config.executor == "sim":
            srv.register_tenant(spec.name, SimTenant(
                spec.name, cfg, precisions=spec.precisions,
                predictor=predictor, service_ms=spec.service_ms))
        else:
            import jax
            import jax.numpy as jnp

            from repro.models import transformer as T
            params = T.init_params(cfg, jax.random.key(spec.init_seed),
                                   jnp.float32)
            srv.register(spec.name, cfg, params, spec.precisions,
                         predictor=predictor)
    if config.executor == "sim":
        # Deterministic runs: a background fit must not race the virtual
        # clock, so sim builds wait each fit out at its schedule point.
        srv.sync_predictor_fits = True
    if config.budget_mb is None:
        headroom = config.kv_headroom_mb
        if config.kv_headroom_shape is not None:
            b, total_len = config.kv_headroom_shape
            headroom += max(kv_cache_mb(t.cfg, b, total_len)
                            for t in srv.tenants.values())
        srv.budget_mb = srv.contention_budget(headroom)
    srv.start()
    return srv
