"""Request batching for the multi-tenant server.

Requests queue per tenant; a batching window groups same-tenant requests
(padding prompts to a common length) so one prefill+decode serves many
requests — the standard serving amortization, orthogonal to the paper's
residency management but required for a real deployment.
"""
from __future__ import annotations

import itertools
from collections import defaultdict, deque
from dataclasses import dataclass
from typing import Deque, Dict, Iterable, KeysView, List, Optional

import numpy as np


@dataclass
class Request:
    app: str
    prompt: np.ndarray  # (S,) int32
    max_new: int = 8
    arrival_ms: float = 0.0
    # Assigned by the Batcher at submit (a module-global counter here
    # leaked ids across server builds in one process, making the FIFO
    # rid tie-break in next_batch non-reproducible between builds).
    rid: Optional[int] = None


@dataclass
class Batch:
    app: str
    requests: List[Request]
    prompts: np.ndarray  # (B, S_max) right-aligned padded
    max_new: int


class Batcher:
    def __init__(self, max_batch: int = 8, pad_id: int = 0):
        # Deques: head pops (next_batch, continuous join) and head
        # re-inserts (preemption requeue) are O(1) instead of shifting
        # the whole tenant queue.
        self.queues: Dict[str, Deque[Request]] = defaultdict(deque)
        self.max_batch = max_batch
        self.pad_id = pad_id
        # Instance-scoped so two server builds in one process each start
        # at rid 0: identical traces get identical tie-break orders.
        self._ids = itertools.count()

    def assign(self, req: Request) -> Request:
        """Give a request its id (idempotent: explicit rids survive)."""
        if req.rid is None:
            req.rid = next(self._ids)
        return req

    def submit(self, req: Request) -> None:
        self.queues[req.app].append(self.assign(req))

    def pending(self) -> int:
        return sum(len(q) for q in self.queues.values())

    def queued(self, app: str) -> int:
        """Depth of one tenant's queue."""
        return len(self.queues.get(app, ()))

    def queued_apps(self) -> KeysView[str]:
        """Live view of tenants with queued work, in insertion order.

        A view, not a copy: callers that only iterate (and do not
        mutate the queue table mid-loop) avoid materializing a fresh
        tuple every scheduler step.  Callers that *do* mutate mid-loop
        (e.g. the continuous-batching join, where a preemption requeue
        can insert new keys) must snapshot with ``list(...)`` first.
        """
        return self.queues.keys()

    def head_arrival(self, app: str) -> Optional[float]:
        """Arrival time of the tenant's oldest queued request."""
        q = self.queues.get(app)
        return q[0].arrival_ms if q else None

    def next_batch(self, exclude: Optional[Iterable[str]] = None
                   ) -> Optional[Batch]:
        """Pop the largest same-tenant group (up to max_batch), FIFO
        within the tenant; queue-size ties go to the tenant whose head
        request has waited longest (no starvation under equal load).
        Tenants in ``exclude`` (mid-load: their weights are still
        staging) are skipped so everyone else keeps serving; returns None
        when every queued tenant is excluded."""
        skip = frozenset(exclude) if exclude else frozenset()
        apps = [a for a in self.queues if a not in skip]
        if not apps:
            return None
        app = max(apps,
                  key=lambda a: (len(self.queues[a]),
                                 -self.queues[a][0].arrival_ms,
                                 -self.queues[a][0].rid))
        q = self.queues[app]
        reqs = [q.popleft() for _ in range(min(self.max_batch, len(q)))]
        if not q:
            del self.queues[app]
        S = max(len(r.prompt) for r in reqs)
        prompts = np.full((len(reqs), S), self.pad_id, np.int32)
        for i, r in enumerate(reqs):
            prompts[i, S - len(r.prompt):] = r.prompt  # right-align
        return Batch(app, reqs, prompts, max(r.max_new for r in reqs))
