"""Gradient compression: int8 symmetric quantization with error feedback.

Two layers:

* :func:`compress_grads` — the numerical transform applied inside the
  train step (pure pytree -> pytree, with the error-feedback accumulator
  carried in TrainState). Under pjit the subsequent all-reduce moves the
  *values* produced here; the error accumulator guarantees the long-run
  bias is zero (EF-SGD).
* :func:`compressed_psum` — an explicit shard_map collective that actually
  moves int8 on the wire (quantize → psum(int8 payload as int32 partial
  sums won't overflow for ≤2^23 shards) → dequantize), demonstrating the
  cross-pod bandwidth saving on the multi-pod mesh's ``pod`` axis.

The same byte-count argument applies to *weight staging*:
:func:`wire_compression_ratio` is the serving loaders' contract for
``LoaderSpec(compress="int8")`` — host→chip shard streams ship the int8
payload plus per-group scales instead of full-width leaves, so a load's
virtual transfer time shrinks by exactly this ratio while the resident
footprint (what ``inflight_mb`` claims and the ``DeviceLedger`` charge)
is unchanged.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

PyTree = Any
_QMAX = 127.0


class CompressionState(NamedTuple):
    error: PyTree  # error-feedback accumulator, same structure as grads

    @classmethod
    def init(cls, params: PyTree) -> "CompressionState":
        return cls(error=jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params))


def wire_compression_ratio(bits: int, *, scheme: str = "int8",
                           group: int = 32) -> float:
    """Bytes-on-the-wire ratio for staging a ``bits``-wide variant with
    ``scheme`` compression, as a fraction of the uncompressed transfer.

    The int8 scheme ships 1 byte per element plus one f32 scale per
    group of ``group`` elements along the reduction axis — the exact
    payload layout :func:`repro.kernels.quant_matmul.quantize_params`
    produces (per-(K-group, N-column) symmetric scales, ``group=32``)
    and :func:`repro.kernels.quant_matmul.quant_matmul` dequantizes in
    VMEM on the other end.  A variant already at or below 8 bits gains
    nothing (the payload *is* its resident width), so the ratio clamps
    at 1.0 — compression never makes a transfer slower.

    >>> wire_compression_ratio(16)   # bf16 → int8 payload + scales
    0.5625
    >>> wire_compression_ratio(8)    # already int8-resident: no win
    1.0
    """
    if scheme != "int8":
        raise ValueError(f"unknown wire-compression scheme {scheme!r}")
    wire_bytes = 1.0 + 4.0 / group          # int8 payload + f32 scales
    resident_bytes = bits / 8.0
    return min(1.0, wire_bytes / resident_bytes)


def _q_dq(x: jnp.ndarray) -> jnp.ndarray:
    """Quantize to int8 and back (per-tensor absmax scale)."""
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / _QMAX
    q = jnp.clip(jnp.round(x / scale), -_QMAX - 1, _QMAX)
    return q * scale


def compress_grads(grads: PyTree, state: CompressionState
                   ) -> Tuple[PyTree, CompressionState]:
    """EF-compression: g' = Q(g + e);  e' = (g + e) − g'."""

    def one(g, e):
        g = g.astype(jnp.float32)
        corrected = g + e
        if g.ndim < 2:  # tiny tensors: not worth compressing
            return corrected, jnp.zeros_like(e)
        out = _q_dq(corrected)
        return out, corrected - out

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(state.error)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = treedef.unflatten([o[0] for o in outs])
    new_e = treedef.unflatten([o[1] for o in outs])
    return new_g, CompressionState(error=new_e)


def compressed_psum(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """All-reduce that ships int8 on the wire (inside shard_map).

    Each shard quantizes with its own scale; scales (one f32 per tensor)
    are all-gathered — negligible — and partial dequantized sums are
    formed via psum of the int8 payload widened to int32 (exact).
    """
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / _QMAX
    q = jnp.clip(jnp.round(x / scale), -_QMAX - 1, _QMAX).astype(jnp.int8)
    # Wire payload is int8; the sum itself needs a wider accumulator.
    # Scales differ per shard, so sum q_i * s_i via psum over the products
    # quantized at 16-bit — we keep exactness by summing q (int32) scaled
    # after: psum(q * s) == psum over shards of dequantized values.
    deq = q.astype(jnp.float32) * scale
    return jax.lax.psum(deq, axis_name)


def compressed_allreduce_demo(values: jnp.ndarray, mesh) -> jnp.ndarray:
    """shard_map demo used by tests: int8-compressed all-reduce over the
    first mesh axis."""
    axis = mesh.axis_names[0]
    def body(v):
        return compressed_psum(v, axis)

    if hasattr(jax, "shard_map"):
        fn = jax.shard_map(body, mesh=mesh, in_specs=P(axis),
                           out_specs=P())
    else:  # older jax: the pre-promotion experimental API
        from jax.experimental.shard_map import shard_map
        fn = shard_map(body, mesh=mesh, in_specs=P(axis), out_specs=P(),
                       check_rep=False)
    return fn(values)
