"""Gradient compression: int8 symmetric quantization with error feedback.

Two layers:

* :func:`compress_grads` — the numerical transform applied inside the
  train step (pure pytree -> pytree, with the error-feedback accumulator
  carried in TrainState). Under pjit the subsequent all-reduce moves the
  *values* produced here; the error accumulator guarantees the long-run
  bias is zero (EF-SGD).
* :func:`compressed_psum` — an explicit shard_map collective that actually
  moves int8 on the wire (quantize → psum(int8 payload as int32 partial
  sums won't overflow for ≤2^23 shards) → dequantize), demonstrating the
  cross-pod bandwidth saving on the multi-pod mesh's ``pod`` axis.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

PyTree = Any
_QMAX = 127.0


class CompressionState(NamedTuple):
    error: PyTree  # error-feedback accumulator, same structure as grads

    @classmethod
    def init(cls, params: PyTree) -> "CompressionState":
        return cls(error=jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params))


def _q_dq(x: jnp.ndarray) -> jnp.ndarray:
    """Quantize to int8 and back (per-tensor absmax scale)."""
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / _QMAX
    q = jnp.clip(jnp.round(x / scale), -_QMAX - 1, _QMAX)
    return q * scale


def compress_grads(grads: PyTree, state: CompressionState
                   ) -> Tuple[PyTree, CompressionState]:
    """EF-compression: g' = Q(g + e);  e' = (g + e) − g'."""

    def one(g, e):
        g = g.astype(jnp.float32)
        corrected = g + e
        if g.ndim < 2:  # tiny tensors: not worth compressing
            return corrected, jnp.zeros_like(e)
        out = _q_dq(corrected)
        return out, corrected - out

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(state.error)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = treedef.unflatten([o[0] for o in outs])
    new_e = treedef.unflatten([o[1] for o in outs])
    return new_g, CompressionState(error=new_e)


def compressed_psum(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """All-reduce that ships int8 on the wire (inside shard_map).

    Each shard quantizes with its own scale; scales (one f32 per tensor)
    are all-gathered — negligible — and partial dequantized sums are
    formed via psum of the int8 payload widened to int32 (exact).
    """
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / _QMAX
    q = jnp.clip(jnp.round(x / scale), -_QMAX - 1, _QMAX).astype(jnp.int8)
    # Wire payload is int8; the sum itself needs a wider accumulator.
    # Scales differ per shard, so sum q_i * s_i via psum over the products
    # quantized at 16-bit — we keep exactness by summing q (int32) scaled
    # after: psum(q * s) == psum over shards of dequantized values.
    deq = q.astype(jnp.float32) * scale
    return jax.lax.psum(deq, axis_name)


def compressed_allreduce_demo(values: jnp.ndarray, mesh) -> jnp.ndarray:
    """shard_map demo used by tests: int8-compressed all-reduce over the
    first mesh axis."""
    axis = mesh.axis_names[0]
    def body(v):
        return compressed_psum(v, axis)

    if hasattr(jax, "shard_map"):
        fn = jax.shard_map(body, mesh=mesh, in_specs=P(axis),
                           out_specs=P())
    else:  # older jax: the pre-promotion experimental API
        from jax.experimental.shard_map import shard_map
        fn = shard_map(body, mesh=mesh, in_specs=P(axis), out_specs=P(),
                       check_rep=False)
    return fn(values)
