"""Activation-sharding context: lets pure layer code emit
``with_sharding_constraint`` hints without threading mesh objects through
every call.  The launcher (steps.build_cell) installs the context; on a
bare CPU (tests, smoke) it stays disabled and hints are no-ops.

Why this exists: XLA's sharding propagation picks the wrong dim after
head-split reshapes — e.g. (B,S,KV·hd)→(B,S,KV,hd) can land the model
axis on ``hd``, making every attention einsum a partial-sum all-reduce of
score-sized tensors.  A handful of explicit hints on q/k/v, FFN hidden,
and SSM internals pins the intended TP layout (measured effect recorded
in EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P


@dataclass
class ShardCtx:
    dp_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"
    model_size: int = 1
    dp_size: int = 1
    enabled: bool = False

    @property
    def dp_spec(self):
        return self.dp_axes if len(self.dp_axes) > 1 else self.dp_axes[0]


_CTX = ShardCtx()


def set_ctx(ctx: Optional[ShardCtx]) -> None:
    global _CTX
    _CTX = ctx if ctx is not None else ShardCtx()


def get_ctx() -> ShardCtx:
    return _CTX


def hint(x, *dims: Optional[str]):
    """Constrain ``x``: each entry is 'dp', 'model', or None per dim.

    'dp' requires exact divisibility (batch semantics).  'model' also
    accepts *uneven* sharding (XLA GSPMD pads the last shards) whenever
    the dim is at least model_size/4 — e.g. llama4's 40 heads or hymba's
    25 heads shard 16-way with ≤2× padding waste, versus 16× redundant
    compute+memory if left replicated (measured: a 36 GB/device score
    arena on llama4 train_4k)."""
    ctx = _CTX
    if not ctx.enabled:
        return x
    spec = []
    for d, want in zip(x.shape, dims):
        if want == "model" and ctx.model_size > 1 and (
                d % ctx.model_size == 0 or d * 4 >= ctx.model_size):
            spec.append(ctx.model_axis)
        elif want == "dp" and ctx.dp_size > 1 and d % ctx.dp_size == 0:
            spec.append(ctx.dp_spec)
        else:
            spec.append(None)
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:
        return x
