"""Sharding rules: map every parameter / batch / cache / optimizer leaf to a
PartitionSpec for the production mesh.

Scheme (DESIGN.md §5): Megatron-style TP on the ``model`` axis with
column-parallel in-projections and row-parallel out-projections (avoids
mid-block all-gathers), EP for expert tensors, DP over ``data`` (+``pod``),
and sequence sharding for long-context KV caches.  Every rule checks
divisibility and degrades gracefully (heads → feature dim → replicate),
which is what lets one rule set serve all 10 architectures — including the
awkward ones (hymba's 25 heads / 3257-wide in_proj, granite's odd vocab).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig

PyTree = Any

# Weights whose *input* (K) dim is sharded: the row-parallel halves of each
# Megatron pair.  Everything else 2-D prefers column (output/N) sharding.
_ROW_PARALLEL = ("wo", "wd", "ws_d", "ssm_out")


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name]


def _div(n: int, m: int) -> bool:
    return m > 0 and n % m == 0


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


# 1-D (TP-only) shards above this per-device size get a second axis
# (fully-sharded compute weights): without it the 103B-param MoE tenant's
# bf16 compute copy alone is 12.9 GB/chip.
_FSDP_THRESHOLD = 64 * 1024 * 1024


def param_specs(cfg: ModelConfig, abstract_params: PyTree,
                mesh: Mesh, *, model_axis: str = "model",
                dp_axes: Tuple[str, ...] = ("data",),
                fsdp: bool = True) -> PyTree:
    m = _axis_size(mesh, model_axis)
    dp_size = 1
    for a in dp_axes:
        dp_size *= _axis_size(mesh, a)
    dp_spec = dp_axes if len(dp_axes) > 1 else dp_axes[0]

    def maybe_2d(shape, base, itemsize=4):
        """Add the dp axis on the largest free divisible dim when the
        1-D shard is still huge (MoE expert stacks).  Train-only: at
        serve time 2-D weights force per-layer gathers (measured 3x the
        decode collective on llama4) and the 1-D bf16 weights fit."""
        if not fsdp:
            return base
        n = itemsize
        for d in shape:
            n *= d
        n //= m
        if n < _FSDP_THRESHOLD:
            return base
        cands = [i for i in range(len(shape))
                 if base[i] is None and _div(shape[i], dp_size)]
        if cands:
            best = max(cands, key=lambda i: shape[i])
            base[best] = dp_spec
        return base

    def spec_for(name: str, shape: Tuple[int, ...], in_layers: bool):
        nd = len(shape)
        lead = 1 if in_layers else 0  # stacked L dim
        base = [None] * nd
        if in_layers and nd - lead <= 1:
            return P(*base)  # per-layer vectors: replicate
        if name in ("embed",):  # (Kcb, Vp, D)
            if _div(shape[1], m):
                base[1] = model_axis
            return P(*maybe_2d(shape, base))
        if name in ("head",):  # (Kcb, D, Vp)
            if _div(shape[2], m):
                base[2] = model_axis
            return P(*maybe_2d(shape, base))
        if name in ("meta", "final_norm"):
            return P(*base)
        if name.startswith("we_"):  # (L, E, D, F): shard experts
            if _div(shape[1], m):
                base[1] = model_axis
            elif _div(shape[-1], m):
                base[-1] = model_axis
            return P(*maybe_2d(shape, base))
        if nd - lead == 2:  # (L, K, N) linear weights
            k_dim, n_dim = nd - 2, nd - 1
            row_first = any(name.startswith(r) or name == r
                            for r in _ROW_PARALLEL)
            order = ((k_dim, n_dim) if row_first else (n_dim, k_dim))
            for d in order:
                if _div(shape[d], m):
                    base[d] = model_axis
                    break
            return P(*maybe_2d(shape, base))
        return P(*base)

    def visit(path, leaf):
        ps = _path_str(path)
        parts = ps.split("/")
        in_layers = parts[0] == "layers"
        # Quantized leaves: path ends with /q or /s — spec from the pair.
        name = parts[1] if in_layers else parts[0]
        shape = leaf.shape
        if parts[-1] in ("q", "s"):
            qname = parts[-2]
            if parts[-1] == "s":
                # scales (..., G, N): shard N like q's N; never shard G.
                sp = list(spec_for(qname, shape, in_layers))
                k_dim = len(shape) - 2
                if sp[k_dim] is not None:
                    sp[k_dim] = None  # row-parallel q: scales replicate on G
                return P(*sp)
            return spec_for(qname, shape, in_layers)
        return spec_for(name, shape, in_layers)

    return jax.tree_util.tree_map_with_path(visit, abstract_params)


# ---------------------------------------------------------------------------
def batch_specs(cfg: ModelConfig, batch_abstract: PyTree, mesh: Mesh,
                *, dp_axes: Tuple[str, ...] = ("data",)) -> PyTree:
    dp = sum(1 for _ in dp_axes)
    dp_size = 1
    for a in dp_axes:
        dp_size *= _axis_size(mesh, a)

    def visit(path, leaf):
        shape = leaf.shape
        base: list = [None] * len(shape)
        if len(shape) >= 1 and _div(shape[0], dp_size):
            base[0] = dp_axes if dp > 1 else dp_axes[0]
        return P(*base)

    return jax.tree_util.tree_map_with_path(visit, batch_abstract)


def cache_specs(cfg: ModelConfig, cache_abstract: PyTree, mesh: Mesh,
                *, dp_axes: Tuple[str, ...] = ("data",),
                model_axis: str = "model") -> PyTree:
    m = _axis_size(mesh, model_axis)
    dp_size = 1
    for a in dp_axes:
        dp_size *= _axis_size(mesh, a)
    dp_spec = dp_axes if len(dp_axes) > 1 else dp_axes[0]

    def visit(path, leaf):
        name = _path_str(path).split("/")[0]
        shape = leaf.shape
        if name == "lengths":  # (B,)
            return P(dp_spec if _div(shape[0], dp_size) else None)
        if name in ("k", "v"):  # (L, B, T, KV, hd)
            Lc, B, T, KV, hd = shape
            sp: list = [None] * 5
            b_sharded = _div(B, dp_size)
            if b_sharded:
                sp[1] = dp_spec
            if _div(KV, m):
                sp[3] = model_axis
            elif not b_sharded and _div(T, dp_size * m):
                sp[2] = dp_axes + (model_axis,)  # long-context seq sharding
            elif _div(T, m):
                sp[2] = model_axis
            return P(*sp)
        if name in ("k_scale", "v_scale"):  # (L, B, T, KV)
            Lc, B, T, KV = shape
            sp = [None] * 4
            b_sharded = _div(B, dp_size)
            if b_sharded:
                sp[1] = dp_spec
            if _div(KV, m):
                sp[3] = model_axis
            elif not b_sharded and _div(T, dp_size * m):
                sp[2] = dp_axes + (model_axis,)
            elif _div(T, m):
                sp[2] = model_axis
            return P(*sp)
        if name == "state":  # (L, B, nh, hd, N)
            Lc, B, nh, hd, N = shape
            sp = [None] * 5
            if _div(B, dp_size):
                sp[1] = dp_spec
            if _div(nh, m):
                sp[2] = model_axis
            return P(*sp)
        if name == "conv":  # (L, B, W-1, convd)
            sp = [None] * 4
            if _div(shape[1], dp_size):
                sp[1] = dp_spec
            if _div(shape[3], m):
                sp[3] = model_axis
            return P(*sp)
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(visit, cache_abstract)


def zero1_specs(abstract_tree: PyTree, spec_tree: PyTree, mesh: Mesh,
                *, dp_axes: Tuple[str, ...] = ("data",)) -> PyTree:
    """ZeRO-1: additionally shard f32 master/optimizer leaves over the
    data axis on the largest free divisible dim.  Without this, the big
    MoE tenants (llama4-scout ≈ 103 B params) cannot hold f32 master +
    AdamW moments in a 16-wide TP slice (77 GB/chip); with it they drop
    by the DP degree.  XLA inserts the ZeRO gather/reduce-scatter pair
    automatically from the sharding mismatch."""
    dp_size = 1
    for a in dp_axes:
        dp_size *= _axis_size(mesh, a)
    dp_spec = dp_axes if len(dp_axes) > 1 else dp_axes[0]

    def augment(leaf, spec):
        dims = list(spec)
        shape = leaf.shape
        if len(shape) < 2:
            return spec
        used = {a for d in dims if d is not None
                for a in (d if isinstance(d, tuple) else (d,))}
        if used & set(dp_axes):
            return spec  # already fully-sharded (FSDP 2-D weights)
        # Try the combined dp axes first, then pairs of dims, then single
        # axes: tenants whose dims don't divide the full DP degree
        # (hymba: 1600/5504 vs 256) still get sharded state instead of
        # silently replicating 12 bytes/param (measured: 20 GB/device).
        attempts = [(dp_spec, dp_size)]
        for a in dp_axes:
            if a not in used:
                attempts.append((a, _axis_size(mesh, a)))
        for ax_spec, ax_size in attempts:
            cands = [i for i in range(len(shape))
                     if dims[i] is None and _div(shape[i], ax_size)]
            if cands:
                best = max(cands, key=lambda i: shape[i])
                dims[best] = ax_spec
                return P(*dims)
        return spec

    return jax.tree.map(augment, abstract_tree, spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def state_specs(cfg: ModelConfig, abstract_state, mesh: Mesh,
                param_spec_tree: PyTree, *, zero1: bool = True,
                dp_axes: Tuple[str, ...] = ("data",)) -> PyTree:
    """Optimizer state mirrors the parameter sharding (+ ZeRO-1 over the
    data axis for the f32 master copy and AdamW moments)."""
    import repro.training.train_step as TS

    if zero1:
        master = zero1_specs(abstract_state.params, param_spec_tree, mesh,
                             dp_axes=dp_axes)
    else:
        master = param_spec_tree
    comp_spec = (None if abstract_state.comp is None
                 else CompState_spec(master))
    return TS.TrainState(
        params=master,
        opt=type(abstract_state.opt)(
            step=P(),
            mu=jax.tree.map(lambda s: s, master),
            nu=jax.tree.map(lambda s: s, master),
        ),
        comp=comp_spec,
    )


def CompState_spec(param_spec_tree: PyTree):
    from repro.distributed.compression import CompressionState

    return CompressionState(error=param_spec_tree)


def named(mesh: Mesh, spec_tree: PyTree) -> PyTree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Logical meshes and per-device weight footprints (serving-side accounting)
# ---------------------------------------------------------------------------
class LogicalMesh:
    """Duck-typed mesh: a shape mapping + axis names, nothing more.

    The spec rules above only read ``mesh.shape[name]``, so serving-side
    accounting (per-device memory ledgers, shard-size math) can run them
    without ever touching jax device state — a sharded sim run needs no
    devices at all.  ``jax.sharding.Mesh`` satisfies the same interface,
    so callers with real devices pass one interchangeably."""

    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(self.shape)
        if any(v < 1 for v in self.shape.values()):
            raise ValueError(f"mesh axes must be >= 1: {self.shape}")

    @property
    def size(self) -> int:
        n = 1
        for v in self.shape.values():
            n *= v
        return n

    def __repr__(self) -> str:
        return f"LogicalMesh({self.shape})"


def serving_mesh(mesh_shape: Tuple[int, ...]) -> LogicalMesh:
    """The serving stack's mesh convention: a 1-D shape is pure tensor
    parallelism (``("model",)``); a 2-D shape is ``("data", "model")``."""
    if len(mesh_shape) == 1:
        return LogicalMesh({"model": mesh_shape[0]})
    if len(mesh_shape) == 2:
        return LogicalMesh({"data": mesh_shape[0], "model": mesh_shape[1]})
    raise ValueError(
        f"serving mesh_shape must be 1-D or 2-D, got {mesh_shape}")


def weight_shard_fraction(cfg: ModelConfig, mesh, *,
                          model_axis: str = "model",
                          dtype=None) -> float:
    """Fraction of a tenant's weight bytes resident on ONE device of the
    mesh under :func:`param_specs`: sharded leaves contribute ``1/m`` of
    their bytes per model-slice, replicated leaves (norms, odd-width
    projections that don't divide the axis) a full copy.  Always
    ``>= 1/mesh.size`` — the excess is the replication overhead a
    per-device memory ledger must budget for.  Model slices are
    symmetric, so one fraction describes every device."""
    import jax.numpy as jnp

    from repro.models import transformer as T

    abstract = T.abstract_params(cfg, dtype or jnp.bfloat16)
    dp_axes = tuple(a for a in mesh.axis_names if a != model_axis)
    if not dp_axes:
        # Model-only serving mesh: give the rules a trivial data axis.
        mesh = LogicalMesh({"data": 1,
                            model_axis: mesh.shape[model_axis]})
        dp_axes = ("data",)
    specs = param_specs(cfg, abstract, mesh, model_axis=model_axis,
                        dp_axes=dp_axes, fsdp=False)
    total = 0
    per_device = 0.0
    for leaf, spec in zip(jax.tree.leaves(abstract),
                          jax.tree.leaves(
                              specs,
                              is_leaf=lambda x: isinstance(x, P))):
        nbytes = 1
        for d in leaf.shape:
            nbytes *= d
        nbytes *= leaf.dtype.itemsize
        div = 1
        for entry in spec:
            if entry is None:
                continue
            for ax in (entry if isinstance(entry, tuple) else (entry,)):
                div *= mesh.shape[ax]
        total += nbytes
        per_device += nbytes / div
    return per_device / total if total else 1.0


def variant_shard_mb(size_mb: float, n_devices: int,
                     fraction: Optional[float] = None) -> Tuple[float, ...]:
    """Per-device resident MB for one zoo variant staged across
    ``n_devices``: each device holds ``fraction`` of the variant
    (``1/n`` for an ideal even split; :func:`weight_shard_fraction` for
    the real spec-derived figure including replication).  The serving
    loader stages one such shard per device stream."""
    f = (1.0 / n_devices) if fraction is None else fraction
    return tuple(size_mb * f for _ in range(n_devices))
