"""Fault tolerance: failure injection + supervised checkpoint/restart loop.

At fleet scale the question is not *if* a node dies mid-step but how many
steps you lose when it does.  The driver below wraps any step function in
a supervise-restore-continue loop; tests inject failures and assert the
run completes with bitwise-identical results to an uninterrupted run
(possible because the data pipeline is step-indexed and checkpoints are
atomic).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

import numpy as np

from repro.distributed import checkpoint as ckpt


class NodeFailure(RuntimeError):
    pass


@dataclass
class FailureInjector:
    """Deterministic failure schedule (or probabilistic with a seed)."""
    fail_at_steps: tuple = ()
    prob: float = 0.0
    seed: int = 0
    _fired: set = field(default_factory=set)

    def check(self, step: int) -> None:
        if step in self.fail_at_steps and step not in self._fired:
            self._fired.add(step)
            raise NodeFailure(f"injected node failure at step {step}")
        if self.prob > 0.0:
            rng = np.random.default_rng((self.seed, step))
            if rng.random() < self.prob and step not in self._fired:
                self._fired.add(step)
                raise NodeFailure(f"random node failure at step {step}")


@dataclass
class RunReport:
    steps_completed: int
    restarts: int
    final_metrics: Dict[str, float]
    losses: list


def run_supervised(
    *,
    init_state: Any,
    step_fn: Callable,  # (state, batch) -> (state, metrics)
    batch_fn: Callable,  # step -> batch
    total_steps: int,
    ckpt_dir: str,
    ckpt_every: int = 10,
    injector: Optional[FailureInjector] = None,
    max_restarts: int = 10,
    async_save: bool = True,
) -> RunReport:
    """Run to total_steps, surviving injected failures via restore."""
    saver = ckpt.AsyncCheckpointer()
    restarts = 0
    losses = []
    state = init_state
    step = 0
    # Resume if a previous incarnation left checkpoints.
    last = ckpt.latest_step(ckpt_dir)
    if last is not None:
        state = ckpt.restore(init_state, ckpt_dir, last)
        step = last

    metrics: Dict[str, float] = {}
    while step < total_steps:
        try:
            if injector is not None:
                injector.check(step)
            batch = batch_fn(step)
            state, m = step_fn(state, batch)
            metrics = {k: float(v) for k, v in m.items()}
            losses.append(metrics.get("loss", float("nan")))
            step += 1
            if step % ckpt_every == 0 or step == total_steps:
                if async_save:
                    saver.save_async(state, ckpt_dir, step)
                else:
                    ckpt.save(state, ckpt_dir, step)
        except NodeFailure:
            restarts += 1
            if restarts > max_restarts:
                raise
            saver.wait()
            last = ckpt.latest_step(ckpt_dir)
            if last is None:
                state, step = init_state, 0
            else:
                state = ckpt.restore(init_state, ckpt_dir, last)
                step = last
    saver.wait()
    return RunReport(step, restarts, metrics, losses)
