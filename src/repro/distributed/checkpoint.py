"""Sharded, atomic, async checkpointing (no orbax in this environment).

Layout:
    <dir>/step_<N>/
        manifest.json        tree structure, shapes, dtypes, step
        leaf_00000.npy ...   one file per pytree leaf

Guarantees:
* **atomic commit** — written to ``step_<N>.tmp`` then ``os.rename``d, so a
  crash mid-save never corrupts the latest checkpoint;
* **async** — ``save_async`` snapshots to host memory synchronously (cheap)
  and writes in a background thread, overlapping I/O with the next steps;
* **elastic restore** — ``restore`` materializes onto any mesh/sharding via
  ``jax.device_put`` with the *target* sharding, so a checkpoint taken on
  one mesh shape restores onto another (tested in tests/test_distributed).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any
_INT_DTYPES = {"int8", "int16", "int32", "int64", "uint8", "bool"}


def _tree_paths(tree: PyTree):
    flat, treedef = jax.tree.flatten(tree)
    return flat, treedef


def save(tree: PyTree, directory: str, step: int) -> str:
    """Synchronous atomic save. Returns the committed path."""
    flat, treedef = _tree_paths(tree)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "treedef": str(treedef), "leaves": []}
    for i, leaf in enumerate(flat):
        arr = np.asarray(leaf)
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append(
            {"file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    _gc(directory, keep=3)
    return final


class AsyncCheckpointer:
    """Snapshot-now, write-later checkpointing."""

    def __init__(self):
        self._thread: Optional[threading.Thread] = None
        self.last_path: Optional[str] = None

    def save_async(self, tree: PyTree, directory: str, step: int) -> None:
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)  # synchronous snapshot

        def run():
            self.last_path = save(host_tree, directory, step)

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(template: PyTree, directory: str, step: Optional[int] = None,
            shardings: Optional[PyTree] = None) -> PyTree:
    """Restore into the structure of ``template``; if ``shardings`` is
    given (a matching pytree of Sharding or a single Sharding), leaves are
    placed with it — this is the elastic-resharding path."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat_t, treedef = _tree_paths(template)
    assert len(flat_t) == len(manifest["leaves"]), (
        f"checkpoint has {len(manifest['leaves'])} leaves, "
        f"template has {len(flat_t)}")
    if shardings is not None and not isinstance(shardings, (list, tuple)):
        try:
            flat_s = treedef.flatten_up_to(shardings)
        except Exception:
            flat_s = [shardings] * len(flat_t)
    else:
        flat_s = [None] * len(flat_t)
    out = []
    for t_leaf, meta, sh in zip(flat_t, manifest["leaves"], flat_s):
        arr = np.load(os.path.join(path, meta["file"]))
        if arr.dtype.kind == "V":  # numpy represents bf16 as void16
            import ml_dtypes

            arr = arr.view(ml_dtypes.bfloat16)
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jnp.asarray(arr))
    return treedef.unflatten(out)


def _gc(directory: str, keep: int) -> None:
    steps = sorted(
        d for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)
