"""Elastic scaling: reshard a running state onto a different mesh.

Fleet reality: a pod drops out, or capacity frees up — the job should
continue on the new topology from the latest checkpoint without retracing
history.  Two paths:

* :func:`reshard` — live state → new mesh (device_put with new shardings);
* checkpoint restore with target shardings (``checkpoint.restore``) — the
  cold path after a full restart.

Both work because all state (params, optimizer, compression error) is
plain pytrees with mesh-agnostic logical shapes; only PartitionSpecs
change.  The data pipeline re-derives rank assignments from the new world
size, and global batch is preserved (per-rank batch rescales).
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding

PyTree = Any


def reshard(tree: PyTree, spec_tree: PyTree, new_mesh: Mesh) -> PyTree:
    """Place every leaf of ``tree`` onto ``new_mesh`` with ``spec_tree``."""

    def place(leaf, spec):
        return jax.device_put(leaf, NamedSharding(new_mesh, spec))

    return jax.tree.map(place, tree, spec_tree,
                        is_leaf=lambda x: x is None)


def validate_elastic_plan(old_mesh: Mesh, new_mesh: Mesh,
                          global_batch: int) -> dict:
    """Check a proposed mesh change keeps the job well-posed."""
    old_dp = old_mesh.shape.get("data", 1) * old_mesh.shape.get("pod", 1)
    new_dp = new_mesh.shape.get("data", 1) * new_mesh.shape.get("pod", 1)
    report = {
        "old_devices": old_mesh.size,
        "new_devices": new_mesh.size,
        "old_per_rank_batch": global_batch // old_dp,
        "new_per_rank_batch": global_batch // new_dp,
        "ok": global_batch % new_dp == 0,
    }
    return report
