"""Training step factory: loss → grads → (optional compression) → AdamW.

Features for the fleet: activation remat over the layer scan, microbatched
gradient accumulation (pipelines the pod-axis all-reduce under XLA's
latency-hiding scheduler), int8+error-feedback gradient compression, and a
pure-pytree TrainState that checkpoints/reshards transparently.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.distributed.compression import CompressionState, compress_grads
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.training.optim import AdamW, AdamWState

PyTree = Any


class TrainState(NamedTuple):
    params: PyTree
    opt: AdamWState
    comp: Optional[CompressionState]


def init_state(cfg: ModelConfig, key, optimizer: AdamW,
               dtype=jnp.float32, compression: bool = False) -> TrainState:
    params = T.init_params(cfg, key, dtype)
    comp = CompressionState.init(params) if compression else None
    return TrainState(params, optimizer.init(params), comp)


def abstract_state(cfg: ModelConfig, optimizer: AdamW, dtype=jnp.float32,
                   compression: bool = False) -> TrainState:
    return jax.eval_shape(
        lambda: init_state(cfg, jax.random.key(0), optimizer, dtype,
                           compression))


def make_train_step(
    cfg: ModelConfig,
    optimizer: AdamW,
    *,
    moe_impl: str = "dense",
    remat: bool = True,
    grad_accum: int = 1,
    compression: bool = False,
    z_loss: float = 1e-4,
    compute_dtype=jnp.bfloat16,
    zero_specs=None,
) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    Mixed precision: parameters live in f32 (master copy, AdamW moments
    f32); matrices are cast to ``compute_dtype`` for fwd/bwd, which also
    halves the remat-saved activations.

    ``zero_specs`` (a pytree of PartitionSpec matching params) turns on
    ZeRO-2/FSDP behaviour under pjit: the bf16 compute copy and the
    gradients are constrained to the data-sharded specs, so XLA keeps
    them scattered and inserts per-use all-gathers / reduce-scatters.
    Without it the 103B-param MoE tenant cannot fit f32 grads + a bf16
    copy in a 16-wide TP slice (measured: 354% of HBM)."""

    def _constrain(tree):
        if zero_specs is None:
            return tree
        return jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(x, s),
            tree, zero_specs)

    def cast(params):
        if compute_dtype is None:
            return params
        out = jax.tree.map(
            lambda p: p.astype(compute_dtype)
            if p.dtype == jnp.float32 and p.ndim >= 2 else p, params)
        return _constrain(out)

    def loss_fn(params, batch):
        return T.loss_fn(cfg, cast(params), batch, moe_impl=moe_impl,
                         remat=remat, z_loss=z_loss)

    def compute_grads(params, batch):
        if grad_accum == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            return loss, metrics, _constrain(grads)

        # Microbatch accumulation: scan over grad_accum slices of the batch.
        def split(x):
            b = x.shape[0]
            return x.reshape(grad_accum, b // grad_accum, *x.shape[1:])

        micro = jax.tree.map(split, batch)

        def acc_step(carry, mb):
            g_acc, l_acc = carry
            (loss, _), g = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb)
            g_acc = _constrain(jax.tree.map(jnp.add, g_acc, _constrain(g)))
            return (g_acc, l_acc + loss), ()

        zeros = _constrain(jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params))
        (g_sum, l_sum), _ = jax.lax.scan(
            acc_step, (zeros, jnp.zeros((), jnp.float32)), micro)
        grads = jax.tree.map(lambda g: g / grad_accum, g_sum)
        loss = l_sum / grad_accum
        return loss, {"loss": loss}, grads

    def train_step(state: TrainState, batch):
        loss, metrics, grads = compute_grads(state.params, batch)
        comp = state.comp
        if compression:
            grads, comp = compress_grads(grads, comp)
        params, opt, opt_metrics = optimizer.update(
            grads, state.opt, state.params)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        return TrainState(params, opt, comp), metrics

    return train_step
