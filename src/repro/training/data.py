"""Synthetic data pipeline: deterministic, shardable token streams.

Generates Zipf-distributed token documents with BOS-delimited boundaries —
enough structure that a small LM's loss visibly decreases (used by the
end-to-end training example and the convergence test).  Each data-parallel
rank draws a disjoint PRNG stream, so the pipeline scales to any DP degree
without coordination (and restarts deterministically from a step index —
required for checkpoint/restart to be exactly reproducible).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    zipf_a: float = 1.3
    bos_id: int = 1
    mean_doc_len: int = 64
    seed: int = 1234


class SyntheticStream:
    """Deterministic per-(rank, step) batch generator."""

    def __init__(self, cfg: DataConfig, rank: int = 0, world: int = 1):
        assert cfg.global_batch % world == 0
        self.cfg = cfg
        self.rank = rank
        self.world = world
        self.local_batch = cfg.global_batch // world

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed, self.rank, step))  # restart-deterministic
        n = self.local_batch * (cfg.seq_len + 1)
        toks = rng.zipf(cfg.zipf_a, size=n).astype(np.int64)
        toks = np.minimum(toks + 1, cfg.vocab_size - 1).astype(np.int32)
        # Inject document boundaries; make position-after-BOS predictable
        # (a learnable bigram structure).
        doc_mask = rng.random(n) < 1.0 / cfg.mean_doc_len
        toks[doc_mask] = cfg.bos_id
        after = np.roll(doc_mask, 1)
        toks[after] = (toks[np.roll(np.arange(n), 2)][after] % 16) + 2
        toks = toks.reshape(self.local_batch, cfg.seq_len + 1)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
