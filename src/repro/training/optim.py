"""Optimizers from scratch (no optax in this environment).

AdamW with decoupled weight decay, global-norm gradient clipping, and
linear-warmup + cosine-decay schedules — the standard LM training stack.
State is a plain pytree so it checkpoints/reshards like everything else.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: PyTree
    nu: PyTree


@dataclass(frozen=True)
class AdamW:
    lr: Callable[[jnp.ndarray], jnp.ndarray] | float = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params: PyTree) -> AdamWState:
        zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                          nu=jax.tree.map(jnp.copy, zeros))

    def update(self, grads: PyTree, state: AdamWState, params: PyTree
               ) -> tuple[PyTree, AdamWState, dict]:
        gnorm = global_norm(grads)
        if self.clip_norm:
            scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        step = state.step + 1
        lr = self.lr(step) if callable(self.lr) else jnp.asarray(self.lr)
        b1, b2 = self.b1, self.b2

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mhat = m / (1 - b1 ** step.astype(jnp.float32))
            vhat = v / (1 - b2 ** step.astype(jnp.float32))
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if self.weight_decay and p.ndim >= 2:  # decay matrices only
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - lr * delta
            return new_p.astype(p.dtype), m, v

        flat_g, treedef = jax.tree.flatten(grads)
        flat_m = treedef.flatten_up_to(state.mu)
        flat_v = treedef.flatten_up_to(state.nu)
        flat_p = treedef.flatten_up_to(params)
        out = [upd(g, m, v, p)
               for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        metrics = {"grad_norm": gnorm, "lr": lr}
        return new_p, AdamWState(step, new_m, new_v), metrics


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(leaf.astype(jnp.float32)))
                        for leaf in leaves))


def warmup_cosine(peak_lr: float, warmup: int, total: int,
                  floor: float = 0.1) -> Callable:
    def schedule(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 *
                         (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)
    return schedule
