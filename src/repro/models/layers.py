"""Building-block layers shared by all 10 architecture families.

Everything is a pure function over explicit parameter pytrees (no module
framework).  Per-layer parameters arrive stacked with a leading ``L`` dim and
are consumed one slice at a time inside the layer scan in
:mod:`repro.models.transformer`.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels import ops
from repro.distributed.ctx import hint
from repro.models.config import ModelConfig


# ---------------------------------------------------------------------------
# Weight application — transparently serves quantized zoo variants through
# the fused dequant matmul kernel (the paper's low-precision serving path).
# ---------------------------------------------------------------------------
def _is_q(w) -> bool:
    return isinstance(w, dict) and set(w) == {"q", "s"}


def mm(x: jnp.ndarray, w) -> jnp.ndarray:
    """x @ w for dense or quantized ({"q","s"}) 2-D weights."""
    if _is_q(w):
        return ops.quant_matmul(x, w["q"], w["s"], out_dtype=x.dtype)
    return x @ w


def dense_w(w) -> jnp.ndarray:
    """Materialize a (possibly quantized) weight densely — used for >2-D
    expert tensors and embedding-style contractions where the fused kernel
    doesn't apply."""
    if _is_q(w):
        from repro.quant.quantize import dequantize_leaf

        return dequantize_leaf(w)
    return w


# ---------------------------------------------------------------------------
# Norms / activations
# ---------------------------------------------------------------------------
def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    # Variance accumulates in f32 via the dot's accumulator — no f32 copy
    # of x ever materializes (XLA CPU hoists such converts of the whole
    # remat stack into a 3.75 GB/device buffer on the biggest tenant).
    var = jnp.einsum("...d,...d->...", x, x,
                     preferred_element_type=jnp.float32) / x.shape[-1]
    scale = lax.rsqrt(var + eps)[..., None]
    wf = (1.0 + w.astype(jnp.float32))
    return (x * scale.astype(x.dtype)) * wf.astype(x.dtype)


def act_fn(x: jnp.ndarray, kind: str) -> jnp.ndarray:
    if kind == "gelu":
        return jax.nn.gelu(x, approximate=True)
    return jax.nn.silu(x)


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    return jnp.tanh(x / cap) * cap


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------
def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, D) with positions (S,) or (B, S)."""
    B, S, H, D = x.shape
    half = D // 2
    freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions.astype(jnp.float32)[:, :, None] * freq[None, None, :]
    cos = jnp.cos(ang)[:, :, None, :]  # (B, S, 1, half)
    sin = jnp.sin(ang)[:, :, None, :]
    xf = x.astype(jnp.float32)
    x1, x2 = xf[..., :half], xf[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention branch (full-sequence prefill/train and single-token decode)
# ---------------------------------------------------------------------------
def attention_prefill(
    cfg: ModelConfig,
    lp: dict,
    x: jnp.ndarray,  # (B, S, D) — already input-normed
    positions: jnp.ndarray,  # (S,) or (B, S)
    window: jnp.ndarray,  # scalar int32, 0 = full
    prefix: int = 0,  # positions < prefix always visible (hymba meta tokens)
):
    """Returns (attn_out (B,S,H*hd), k, v) so the caller can build caches."""
    B, S, _ = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = hint(mm(x, lp["wq"]).reshape(B, S, H, hd),
             "dp", None, "model", None)
    k = hint(mm(x, lp["wk"]).reshape(B, S, KV, hd),
             "dp", None, "model", None)
    v = hint(mm(x, lp["wv"]).reshape(B, S, KV, hd),
             "dp", None, "model", None)
    if cfg.qk_norm:
        q = rms_norm(q, lp["q_norm"], cfg.norm_eps)
        k = rms_norm(k, lp["k_norm"], cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    out = _masked_attention(
        q, k, v,
        window=window,
        softcap_v=cfg.attn_logit_softcap,
        scale=cfg.attn_scale,
        prefix=prefix,
    )
    return out.reshape(B, S, H * hd), k, v


ATTN_BLOCK_Q = 512  # q-chunk size for the blocked jnp attention path


def _masked_attention(q, k, v, *, window, softcap_v, scale, prefix):
    """Blocked-softmax reference attention with dynamic (traced) window.

    KV heads are repeated up to H *before* the score matmul so the head
    dim shards cleanly on the TP axis (a grouped (KV, G) reshape would
    split one mesh axis across two tensor dims, which SPMD cannot
    express).  Queries stream in ``ATTN_BLOCK_Q`` chunks via the layer
    ``_scan`` (so score tensors never exceed B×H×bq×T — this is what
    keeps the lowered train graphs inside HBM; the Pallas flash kernel is
    the VMEM-resident production analogue).  ``window`` is a traced
    scalar so one scanned layer body serves local and global layers.
    """
    from repro.models.transformer import _scan

    B, S, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    sc = scale if scale else D ** -0.5
    if G > 1:
        k = hint(jnp.repeat(k, G, axis=2), "dp", None, "model", None)
        v = hint(jnp.repeat(v, G, axis=2), "dp", None, "model", None)
    kv_pos = jnp.arange(S)[None, :]  # (1, T)

    def attend_block(qb, pos0):
        """qb: (B, bq, H, D), absolute positions pos0 + arange(bq)."""
        bq = qb.shape[1]
        qs_ = (qb.astype(jnp.float32) * sc).astype(qb.dtype)
        # f32 accumulation inside the dots; k/v stay in storage dtype so
        # no full-size f32 copies materialize.
        s = hint(jnp.einsum("bqhd,bthd->bhqt", qs_, k,
                            preferred_element_type=jnp.float32),
                 "dp", "model", None, None)
        if softcap_v:
            s = softcap(s, softcap_v)
        q_pos = pos0 + jnp.arange(bq)[:, None]  # (bq, 1)
        mask = kv_pos <= q_pos
        in_w = (window == 0) | (kv_pos > q_pos - window) | (kv_pos < prefix)
        mask = mask & in_w
        s = jnp.where(mask[None, None], s, -2.3819763e38)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqt,bthd->bqhd", p.astype(v.dtype), v,
                       preferred_element_type=jnp.float32).astype(q.dtype)
        return hint(o, "dp", None, "model", None)

    bq = ATTN_BLOCK_Q
    if S <= bq:
        return attend_block(q, 0)
    nq, rem = divmod(S, bq)
    # Scan stacks/emissions must stay head-sharded or the bwd cotangent
    # stack materializes fully gathered (measured: +17 GB/device).
    qs = hint(jnp.moveaxis(
        q[:, :nq * bq].reshape(B, nq, bq, H, D), 1, 0),
        None, "dp", None, "model", None)  # (nq, B, bq, H, D)
    offs = jnp.arange(nq) * bq

    def body(_, inp):
        qb, off = inp
        return (), attend_block(qb, off)

    # Recompute scores in the backward pass instead of saving the full
    # (nq, B, H, bq, T) stacks (~10 GB/device on hymba under DP-only) —
    # the same trade flash attention makes on TPU.
    body = jax.checkpoint(body, prevent_cse=False)
    _, blocks = _scan(body, (), (qs, offs))  # (nq, B, bq, H, D)
    blocks = hint(blocks, None, "dp", None, "model", None)
    out = jnp.moveaxis(blocks, 0, 1).reshape(B, nq * bq, H, D)
    if rem:
        out = jnp.concatenate(
            [out, attend_block(q[:, nq * bq:], nq * bq)], axis=1)
    return out


def attention_decode(
    cfg: ModelConfig,
    lp: dict,
    x: jnp.ndarray,  # (B, 1, D) input-normed single token
    k_cache: jnp.ndarray,  # (B, T, KV, hd)
    v_cache: jnp.ndarray,
    lengths: jnp.ndarray,  # (B,) current valid length (new token index)
    window: jnp.ndarray,  # scalar int32
    prefix: int = 0,
    uniform_pos: bool = False,
):
    """Returns (attn_out (B, 1, H*hd), new_k_cache, new_v_cache).

    ``uniform_pos=True`` writes the cache with one dynamic_update_slice
    (all rows at the same decode position — true for the lowered
    serve_step's synchronized batches).  The per-row scatter path exists
    for ragged serving batches, but XLA:CPU lowers bf16 scatters via an
    f32 upcast of the *whole* cache stack (measured 6 GB/device), and the
    dry-run must reflect the TPU behaviour, not that artifact."""
    B = x.shape[0]
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    xq = x[:, 0, :]
    q = mm(xq, lp["wq"]).reshape(B, 1, H, hd)
    k = mm(xq, lp["wk"]).reshape(B, 1, KV, hd)
    v = mm(xq, lp["wv"]).reshape(B, 1, KV, hd)
    if cfg.qk_norm:
        q = rms_norm(q, lp["q_norm"], cfg.norm_eps)
        k = rms_norm(k, lp["k_norm"], cfg.norm_eps)
    pos = lengths[:, None]  # (B, 1) absolute positions
    q = rope(q, pos, cfg.rope_theta)
    k = rope(k, pos, cfg.rope_theta)
    if uniform_pos:
        # Deferred-write path: attend over the cache + the fresh token
        # directly; the caller stacks the per-layer (B, KV, hd) new k/v and
        # writes them into the big cache with ONE dynamic_update_slice
        # after the layer scan.  This removes L whole-cache copies per
        # decode step from the scan emission (and the f32 upcast XLA:CPU
        # applies to them).
        out = _decode_attention_deferred(
            q[:, 0], k[:, 0], v[:, 0], k_cache, v_cache, lengths,
            window=window, softcap_v=cfg.attn_logit_softcap,
            scale=cfg.attn_scale, prefix=prefix)
        return (out.reshape(B, 1, H * hd),
                k[:, 0].astype(k_cache.dtype),
                v[:, 0].astype(v_cache.dtype))
    bidx = jnp.arange(B)
    k_cache = k_cache.at[bidx, lengths].set(
        k[:, 0].astype(k_cache.dtype))
    v_cache = v_cache.at[bidx, lengths].set(
        v[:, 0].astype(v_cache.dtype))
    out = _decode_attention_windowed(
        q[:, 0], k_cache, v_cache, lengths + 1,
        window=window,
        softcap_v=cfg.attn_logit_softcap,
        scale=cfg.attn_scale,
        prefix=prefix,
    )
    return out.reshape(B, 1, H * hd), k_cache, v_cache


def quantize_kv(x: jnp.ndarray):
    """Per-(token, kv-head) symmetric int8 quantization of k/v rows.
    x: (..., KV, hd) -> (int8 values, f32 scales (..., KV))."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scales = jnp.maximum(absmax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scales[..., None]),
                 -128, 127).astype(jnp.int8)
    return q, scales.astype(jnp.float32)


def _decode_attention_deferred_q(q, k_new, v_new, kq, ks, vq, vs, lengths,
                                 *, window, softcap_v, scale, prefix):
    """int8-KV-cache decode attention (§Perf C3): the cache streams at
    half the bytes; dequantization folds into the score/output scaling
    (one multiply per (token, head) — never a dequantized cache copy).

    kq/vq: (B, T, KV, hd) int8;  ks/vs: (B, T, KV) f32.
    """
    B, H, D = q.shape
    T, KV = kq.shape[1], kq.shape[2]
    G = H // KV
    sc = scale if scale else D ** -0.5
    qf = (q.astype(jnp.float32) * sc).astype(q.dtype).reshape(B, KV, G, D)
    s = jnp.einsum("bkgd,btkd->bkgt", qf, kq.astype(q.dtype),
                   preferred_element_type=jnp.float32)
    s = s * jnp.moveaxis(ks, 1, 2)[:, :, None, :]  # fold in k scales
    s_self = jnp.einsum("bkgd,bkd->bkg", qf, k_new,
                        preferred_element_type=jnp.float32)[..., None]
    if softcap_v:
        s = softcap(s, softcap_v)
        s_self = softcap(s_self, softcap_v)
    kv_pos = jnp.arange(T)[None, :]
    valid = kv_pos < lengths[:, None]
    in_w = (window == 0) | (kv_pos >= lengths[:, None] + 1 - window) | (
        kv_pos < prefix)
    valid = valid & in_w
    s = jnp.where(valid[:, None, None, :], s, -2.3819763e38)
    # Self token combined via log-sum-exp, NOT concat: concatenating onto
    # the T dim breaks its sharding and XLA all-gathers the whole cache
    # (measured 1 GB/layer on llama4 decode).
    m = jnp.maximum(jnp.max(s, -1, keepdims=True), s_self)
    e = jnp.exp(s - m)
    e_self = jnp.exp(s_self - m)
    denom = jnp.sum(e, -1, keepdims=True) + e_self
    # fold v scales into the weights (e_t · s_t) before the int8 pv
    ec = (e * jnp.moveaxis(vs, 1, 2)[:, :, None, :]).astype(q.dtype)
    o = jnp.einsum("bkgt,btkd->bkgd", ec, vq.astype(q.dtype),
                   preferred_element_type=jnp.float32)
    o = (o + e_self * v_new.astype(jnp.float32)[:, :, None, :]) / denom
    return o.reshape(B, H, D).astype(q.dtype)


def attention_decode_q(cfg, lp, x, kq, ks, vq, vs, lengths, window,
                       prefix=0):
    """Quantized-cache decode step (deferred write).  Returns
    (attn_out, k_new_q, k_new_s, v_new_q, v_new_s)."""
    B = x.shape[0]
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    xq = x[:, 0, :]
    q = mm(xq, lp["wq"]).reshape(B, 1, H, hd)
    k = mm(xq, lp["wk"]).reshape(B, 1, KV, hd)
    v = mm(xq, lp["wv"]).reshape(B, 1, KV, hd)
    if cfg.qk_norm:
        q = rms_norm(q, lp["q_norm"], cfg.norm_eps)
        k = rms_norm(k, lp["k_norm"], cfg.norm_eps)
    pos = lengths[:, None]
    q = rope(q, pos, cfg.rope_theta)
    k = rope(k, pos, cfg.rope_theta)
    out = _decode_attention_deferred_q(
        q[:, 0], k[:, 0], v[:, 0], kq, ks, vq, vs, lengths,
        window=window, softcap_v=cfg.attn_logit_softcap,
        scale=cfg.attn_scale, prefix=prefix)
    knq, kns = quantize_kv(k[:, 0])
    vnq, vns = quantize_kv(v[:, 0])
    return out.reshape(B, 1, H * hd), knq, kns, vnq, vns


def _decode_attention_deferred(q, k_new, v_new, k_cache, v_cache, lengths,
                               *, window, softcap_v, scale, prefix):
    """Decode attention where the fresh token's k/v ride alongside the
    (not-yet-updated) cache: scores over [cache, self]."""
    B, H, D = q.shape
    T, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    sc = scale if scale else D ** -0.5
    qf = (q.astype(jnp.float32) * sc).astype(q.dtype).reshape(B, KV, G, D)
    s = jnp.einsum("bkgd,btkd->bkgt", qf, k_cache,
                   preferred_element_type=jnp.float32)
    s_self = jnp.einsum("bkgd,bkd->bkg", qf, k_new,
                        preferred_element_type=jnp.float32)[..., None]
    if softcap_v:
        s = softcap(s, softcap_v)
        s_self = softcap(s_self, softcap_v)
    kv_pos = jnp.arange(T)[None, :]
    valid = kv_pos < lengths[:, None]
    in_w = (window == 0) | (kv_pos >= lengths[:, None] + 1 - window) | (
        kv_pos < prefix)
    valid = valid & in_w
    s = jnp.where(valid[:, None, None, :], s, -2.3819763e38)
    # log-sum-exp combine (see the quantized variant for why not concat)
    m = jnp.maximum(jnp.max(s, -1, keepdims=True), s_self)
    e = jnp.exp(s - m)
    e_self = jnp.exp(s_self - m)
    denom = jnp.sum(e, -1, keepdims=True) + e_self
    o = jnp.einsum("bkgt,btkd->bkgd", e.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    o = (o + e_self * v_new.astype(jnp.float32)[:, :, None, :]) / denom
    return o.reshape(B, H, D).astype(q.dtype)


def _decode_attention_windowed(q, k_cache, v_cache, lengths, *, window,
                               softcap_v, scale, prefix):
    B, H, D = q.shape
    T, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    sc = scale if scale else D ** -0.5
    # The cache stays in its storage dtype: upcasting it would materialize
    # an f32 copy of the ENTIRE stacked KV cache (measured 6 GB/device on
    # musicgen decode — XLA hoists the convert out of the layer scan).
    # f32 accumulation happens inside the dots instead.
    qf = (q.astype(jnp.float32) * sc).astype(q.dtype).reshape(B, KV, G, D)
    s = jnp.einsum("bkgd,btkd->bkgt", qf, k_cache,
                   preferred_element_type=jnp.float32)
    if softcap_v:
        s = softcap(s, softcap_v)
    kv_pos = jnp.arange(T)[None, :]
    valid = kv_pos < lengths[:, None]
    in_window = (window == 0) | (kv_pos >= lengths[:, None] - window) | (
        kv_pos < prefix)
    valid = valid & in_window
    s = jnp.where(valid[:, None, None, :], s, -2.3819763e38)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgt,btkd->bkgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Dense FFN
# ---------------------------------------------------------------------------
def mlp(cfg: ModelConfig, x: jnp.ndarray, wg, wu, wd) -> jnp.ndarray:
    h = act_fn(mm(x, wg), cfg.act) * mm(x, wu)
    h = hint(h, *(["dp"] + [None] * (h.ndim - 2) + ["model"]))
    return mm(h, wd)


# ---------------------------------------------------------------------------
# Mixture-of-Experts FFN
# ---------------------------------------------------------------------------
def moe_ffn(cfg: ModelConfig, lp: dict, x: jnp.ndarray,
            impl: str = "dense") -> jnp.ndarray:
    """x: (B, S, D) -> (B, S, D).

    ``impl="dense"`` is the paper-faithful baseline formulation: every expert
    processes every token and the one-hot gates zero the rest.  It is simple
    and shards cleanly (experts over the ``model`` axis), at the cost of
    E/K× redundant FLOPs — visible in the roofline's useful-flops ratio and
    attacked in the §Perf hillclimb via the "ragged" implementation.
    """
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    xt = x.reshape(B * S, D)
    logits = mm(xt, lp["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = lax.top_k(probs, K)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
    gates = jnp.sum(
        jax.nn.one_hot(topi, E, dtype=jnp.float32) * topv[..., None], axis=1
    )  # (T, E)
    if impl == "ragged":
        y = _moe_ragged(cfg, lp, xt, topi, topv)
        if cfg.num_shared_experts:
            y = y + mlp(cfg, xt, lp["ws_g"], lp["ws_u"], lp["ws_d"])
    elif impl == "local":
        # shared expert computed inside the shard_map: its partial sums
        # ride the SAME model-axis psum as the routed experts (one AR
        # instead of two per layer, fwd and bwd — §Perf A3).
        y = _moe_local(cfg, lp, xt, topi, topv)
    else:
        y = _moe_dense(cfg, lp, xt, gates)
        if cfg.num_shared_experts:
            y = y + mlp(cfg, xt, lp["ws_g"], lp["ws_u"], lp["ws_d"])
    return y.reshape(B, S, D)


def _moe_dense(cfg, lp, xt, gates):
    # Token dim stays DP-sharded and experts stay TP-sharded — without
    # these hints XLA resolves the (dp × model × fsdp) axis conflict by
    # replicating the full token dim in the backward pass (measured:
    # ~10 live f32[T_full, D] buffers on llama4-scout).
    xt = hint(xt, "dp", None)
    hg = hint(jnp.einsum("td,edf->tef", xt, dense_w(lp["we_g"])),
              "dp", "model", None)
    hu = hint(jnp.einsum("td,edf->tef", xt, dense_w(lp["we_u"])),
              "dp", "model", None)
    hh = act_fn(hg, cfg.act) * hu
    hh = hint(hh * gates.astype(hh.dtype)[:, :, None], "dp", "model", None)
    return hint(jnp.einsum("tef,efd->td", hh, dense_w(lp["we_d"])),
                "dp", None)


def _moe_local(cfg, lp, xt, topi, topv):
    """TP-native expert-local MoE (the §Perf hillclimb winner for MoE
    tenants).

    Activations are already replicated across the ``model`` axis under
    Megatron TP, so dispatch needs NO communication: each model-rank
    selects (capacity-bounded) the tokens routed to ITS experts from its
    replicated copy, runs a dense per-expert matmul, and the combine is
    the psum over ``model`` that the block performs anyway.  Spends only
    routed FLOPs (vs E/K× for the dense baseline) at the cost of
    capacity-dropping overflow tokens (capacity factor 2.0)."""
    from jax.sharding import PartitionSpec as P

    from repro.distributed.ctx import get_ctx

    ctx = get_ctx()
    T, D = xt.shape
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    m_ax, m = ctx.model_axis, ctx.model_size
    d_ax = ctx.dp_spec
    assert E % m == 0, "local MoE needs experts divisible by model axis"
    e_loc = E // m
    t_loc = T // ctx.dp_size
    # per (expert, data-shard); never more than the slot count
    cap = min(max(32, int(2.0 * t_loc * K / E)), t_loc * K)

    shared = bool(cfg.num_shared_experts)

    def local(x, we_g, we_u, we_d, ti, tv, *sw):
        # x: (t_loc, D) — this data-shard's tokens (replicated over model)
        # we_*: (e_loc, D, F) — this model-rank's experts
        # ti/tv: (t_loc, K) routed experts / gates
        # sw: optional model-sharded shared-expert weights
        rank = jax.lax.axis_index(m_ax)
        slots_e = ti.reshape(-1)  # (t_loc*K,)
        slots_v = tv.reshape(-1)
        slot_tok = jnp.arange(t_loc * K) // K
        out = jnp.zeros((t_loc, D), jnp.float32)
        for j in range(e_loc):
            eid = rank * e_loc + j
            match = slots_e == eid
            # fixed-capacity local selection (top_k on match positions)
            score = jnp.where(match, jnp.arange(t_loc * K), -1)
            sel = jax.lax.top_k(score, cap)[0]  # slot ids, -1 = empty
            valid = sel >= 0
            tok = jnp.where(valid, slot_tok[jnp.maximum(sel, 0)], 0)
            gate = jnp.where(valid, slots_v[jnp.maximum(sel, 0)], 0.0)
            xe = jnp.take(x, tok, axis=0)  # (cap, D)
            h = act_fn(xe @ we_g[j], cfg.act) * (xe @ we_u[j])
            ye = (h @ we_d[j]).astype(jnp.float32)
            ye = ye * gate[:, None]
            out = out.at[tok].add(jnp.where(valid[:, None], ye, 0.0))
        if sw:
            ws_g, ws_u, ws_d = sw  # (D, F/m), (D, F/m), (F/m, D)
            hs = act_fn(x @ ws_g, cfg.act) * (x @ ws_u)
            out = out + (hs @ ws_d).astype(jnp.float32)
        # Combine in bf16: each token's output comes from exactly K expert
        # ranks (the rest contribute zeros), so the low-precision sum is
        # benign — and the wire bytes halve on bf16-native fabrics.
        return jax.lax.psum(out.astype(x.dtype), m_ax)

    in_specs = [P(d_ax, None), P(m_ax, None, None), P(m_ax, None, None),
                P(m_ax, None, None), P(d_ax, None), P(d_ax, None)]
    args = [xt, dense_w(lp["we_g"]), dense_w(lp["we_u"]),
            dense_w(lp["we_d"]), topi, topv]
    if shared:
        in_specs += [P(None, m_ax), P(None, m_ax), P(m_ax, None)]
        args += [dense_w(lp["ws_g"]), dense_w(lp["ws_u"]),
                 dense_w(lp["ws_d"])]
    fn = jax.shard_map(
        local,
        in_specs=tuple(in_specs),
        out_specs=P(d_ax, None),
        check_vma=False,
    )
    out = fn(*args)
    return out.astype(xt.dtype)


def _moe_ragged(cfg, lp, xt, topi, topv):
    """Sort-based token routing with jax.lax.ragged_dot: only the routed
    top-K expert FLOPs are spent (the §Perf optimized path)."""
    T, D = xt.shape
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    flat_e = topi.reshape(-1)  # (T*K,)
    order = jnp.argsort(flat_e)
    tok_of = order // K  # originating token per routed slot
    xs = jnp.take(xt, tok_of, axis=0)  # (T*K, D) sorted by expert
    group_sizes = jnp.bincount(flat_e, length=E).astype(jnp.int32)
    hg = lax.ragged_dot(xs, dense_w(lp["we_g"]), group_sizes)
    hu = lax.ragged_dot(xs, dense_w(lp["we_u"]), group_sizes)
    hh = act_fn(hg, cfg.act) * hu
    ys = lax.ragged_dot(hh, dense_w(lp["we_d"]), group_sizes)  # (T*K, D)
    w = jnp.take(topv.reshape(-1), order)  # gate per routed slot
    ys = ys * w[:, None].astype(ys.dtype)
    return jax.ops.segment_sum(ys, tok_of, num_segments=T)


# ---------------------------------------------------------------------------
# Mamba-2 (SSD) branch
# ---------------------------------------------------------------------------
def _ssm_dims(cfg: ModelConfig, hybrid: bool):
    di = cfg.d_model if hybrid else cfg.ssm_d_inner
    nh = di // cfg.ssm_head_dim
    return di, nh


def ssm_prefill(
    cfg: ModelConfig,
    lp: dict,
    x: jnp.ndarray,  # (B, S, D) input-normed
    *,
    hybrid: bool = False,
    init_state=None,
    init_conv=None,
    return_state: bool = False,
):
    """Returns y (B, S, di) pre-out-proj [+ (ssm_state, conv_tail)]."""
    B, S, _ = x.shape
    di, nh = _ssm_dims(cfg, hybrid)
    G, N, W = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_conv_width
    zxbcdt = hint(mm(x, lp["ssm_in"]), "dp", None, "model")
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di: 2 * di + 2 * G * N]
    dt_raw = zxbcdt[..., 2 * di + 2 * G * N:]
    xbc = ops.causal_conv1d(xbc, lp["conv_w"], lp["conv_b"], init=init_conv)
    xs = xbc[..., :di]
    Bm = xbc[..., di: di + G * N].reshape(B, S, G, N)
    Cm = xbc[..., di + G * N:].reshape(B, S, G, N)
    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + lp["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(lp["A_log"].astype(jnp.float32))
    xh = hint(xs.reshape(B, S, nh, cfg.ssm_head_dim),
              "dp", None, "model", None)
    out = ops.ssd_scan(
        xh, dt.astype(xh.dtype), A, Bm, Cm, lp["D_skip"],
        init_state=init_state, return_state=return_state,
        chunk=cfg.ssm_chunk)
    if return_state:
        y, state = out
    else:
        y, state = out, None
    y = y.reshape(B, S, di)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 lp["ssm_gnorm"], cfg.norm_eps)
    if return_state:
        conv_tail = _conv_tail(xbc_pre_conv=zxbcdt[..., di: 2 * di + 2 * G * N],
                               init=init_conv, W=W)
        return y, state, conv_tail
    return y


def _conv_tail(xbc_pre_conv, init, W):
    """Last W-1 pre-activation conv inputs — the decode rolling buffer."""
    B, S, C = xbc_pre_conv.shape
    if init is None:
        init = jnp.zeros((B, W - 1, C), xbc_pre_conv.dtype)
    full = jnp.concatenate([init, xbc_pre_conv], axis=1)
    return full[:, -(W - 1):, :]


def ssm_decode(
    cfg: ModelConfig,
    lp: dict,
    x: jnp.ndarray,  # (B, 1, D) input-normed
    state: jnp.ndarray,  # (B, nh, hd, N)
    conv_buf: jnp.ndarray,  # (B, W-1, convd)
    *,
    hybrid: bool = False,
):
    """Single-token SSD step.  Returns (y (B,1,di), new_state, new_conv)."""
    B = x.shape[0]
    di, nh = _ssm_dims(cfg, hybrid)
    G, N = cfg.ssm_ngroups, cfg.ssm_state
    zxbcdt = mm(x[:, 0, :], lp["ssm_in"])
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di: 2 * di + 2 * G * N]
    dt_raw = zxbcdt[..., 2 * di + 2 * G * N:]
    xbc_act, new_conv = ops.causal_conv1d_step(
        xbc, lp["conv_w"], lp["conv_b"], conv_buf)
    xs = xbc_act[..., :di]
    Bm = xbc_act[..., di: di + G * N].reshape(B, G, N)
    Cm = xbc_act[..., di + G * N:].reshape(B, G, N)
    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + lp["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(lp["A_log"].astype(jnp.float32))
    xh = xs.reshape(B, nh, cfg.ssm_head_dim)
    y, new_state = ops.ssd_step(xh, dt, A, Bm, Cm, lp["D_skip"], state)
    y = y.reshape(B, di)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 lp["ssm_gnorm"], cfg.norm_eps)
    return y[:, None, :], new_state, new_conv
