"""Unified LM-family model: dense / MoE / SSM / hybrid / audio / vlm.

Pure-functional: parameters and caches are explicit pytrees, per-layer
parameters stacked along a leading ``L`` axis and consumed by a
``lax.scan`` (keeps HLO size and compile time O(1) in depth — essential
for the 512-device dry-run and for fleet compile latency).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models.config import ModelConfig

PyTree = Any

# Layer-scan unroll factor.  1 in production (compact HLO); the dry-run's
# cost probe lowers at full unroll on shallow configs to recover exact
# per-layer marginal FLOPs/bytes/collectives (cost_analysis counts a scan
# body once regardless of trip count — measured, see EXPERIMENTS.md).
SCAN_UNROLL: int = 1


def set_scan_unroll(n: int) -> None:
    global SCAN_UNROLL
    SCAN_UNROLL = n


def _scan(f, init, xs):
    return lax.scan(f, init, xs, unroll=SCAN_UNROLL)


# Save exactly the TP all-reduce outputs across the remat boundary so
# backward recompute never re-runs forward collectives (§Perf B1).  Costs
# ~2·L·B·S·D bf16 of residency, so the largest tenant opts out
# (REMAT_SAVE_TP=False) to stay inside HBM.
_SAVE_TP = jax.checkpoint_policies.save_only_these_names("tp_out")
REMAT_SAVE_TP: bool = True


def set_remat_save_tp(on: bool) -> None:
    global REMAT_SAVE_TP
    REMAT_SAVE_TP = on


def _remat_policy():
    return _SAVE_TP if REMAT_SAVE_TP else None


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------
def _dense_init(key, shape, dtype, fan_in=None):
    fan_in = fan_in or shape[-2] if len(shape) >= 2 else shape[-1]
    return (jax.random.normal(key, shape, jnp.float32) * (fan_in ** -0.5)
            ).astype(dtype)


def _layer_param_template(cfg: ModelConfig) -> Dict[str, Tuple[Tuple[int, ...], str]]:
    """name -> (shape, init kind). Shapes are per-layer (no L dim)."""
    D, F = cfg.d_model, cfg.d_ff
    hd = cfg.resolved_head_dim
    H, KV = cfg.num_heads, cfg.num_kv_heads
    t: Dict[str, Tuple[Tuple[int, ...], str]] = {}
    hybrid = cfg.family == "hybrid"
    if cfg.uses_attention:
        t["ln1"] = ((D,), "zeros")
        t["wq"] = ((D, H * hd), "dense")
        t["wk"] = ((D, KV * hd), "dense")
        t["wv"] = ((D, KV * hd), "dense")
        t["wo"] = ((H * hd, D), "dense")
        if cfg.post_norm:
            t["post_ln1"] = ((D,), "zeros")
        if cfg.qk_norm:
            t["q_norm"] = ((hd,), "zeros")
            t["k_norm"] = ((hd,), "zeros")
    if cfg.uses_ssm:
        di = D if hybrid else cfg.ssm_d_inner
        nh = di // cfg.ssm_head_dim
        G, N, W = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_conv_width
        convd = di + 2 * G * N
        if not cfg.uses_attention:
            t["ln1"] = ((D,), "zeros")
        t["ssm_in"] = ((D, 2 * di + 2 * G * N + nh), "dense")
        t["conv_w"] = ((W, convd), "conv")
        t["conv_b"] = ((convd,), "zeros_b")
        t["A_log"] = ((nh,), "a_log")
        t["D_skip"] = ((nh,), "ones")
        t["dt_bias"] = ((nh,), "dt_bias")
        t["ssm_gnorm"] = ((di,), "zeros")
        if not hybrid:
            t["ssm_out"] = ((di, D), "dense")
    if hybrid:
        t["fuse_na"] = ((D,), "zeros")
        t["fuse_ns"] = ((D,), "zeros")
    if cfg.is_moe:
        E, Fe = cfg.num_experts, cfg.moe_d_ff
        t["ln2"] = ((D,), "zeros")
        t["router"] = ((D, E), "dense")
        t["we_g"] = ((E, D, Fe), "dense3")
        t["we_u"] = ((E, D, Fe), "dense3")
        t["we_d"] = ((E, Fe, D), "dense3")
        if cfg.num_shared_experts:
            t["ws_g"] = ((D, F), "dense")
            t["ws_u"] = ((D, F), "dense")
            t["ws_d"] = ((F, D), "dense")
    elif F:
        t["ln2"] = ((D,), "zeros")
        t["wg"] = ((D, F), "dense")
        t["wu"] = ((D, F), "dense")
        t["wd"] = ((F, D), "dense")
        if cfg.post_norm:
            t["post_ln2"] = ((D,), "zeros")
    return t


def _init_one(key, name, shape, kind, dtype):
    if kind == "zeros":
        return jnp.zeros(shape, dtype)
    if kind == "zeros_b":
        return jnp.zeros(shape, dtype)
    if kind == "ones":
        return jnp.ones(shape, jnp.float32)
    if kind == "a_log":
        u = jax.random.uniform(key, shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u)
    if kind == "dt_bias":
        dt = jax.random.uniform(key, shape, jnp.float32, 1e-3, 0.1)
        return dt + jnp.log(-jnp.expm1(-dt))  # inverse softplus
    if kind == "conv":
        return _dense_init(key, shape, dtype, fan_in=shape[0])
    if kind == "dense3":
        return _dense_init(key, shape, dtype, fan_in=shape[1])
    return _dense_init(key, shape, dtype)


def init_params(cfg: ModelConfig, key: jax.Array,
                dtype=jnp.bfloat16) -> PyTree:
    D, Vp = cfg.d_model, cfg.padded_vocab
    Kcb = cfg.num_codebooks
    keys = jax.random.split(key, 8)
    params: Dict[str, Any] = {}
    params["embed"] = (jax.random.normal(keys[0], (Kcb, Vp, D), jnp.float32)
                       * (D ** -0.5)).astype(dtype)
    if cfg.num_meta_tokens:
        params["meta"] = (jax.random.normal(
            keys[1], (cfg.num_meta_tokens, D), jnp.float32) * 0.02
        ).astype(dtype)
    template = _layer_param_template(cfg)
    layer_keys = jax.random.split(keys[2], len(template))
    stacked = {}
    for (name, (shape, kind)), k in zip(sorted(template.items()), layer_keys):
        def one(k_):
            return _init_one(k_, name, shape, kind, dtype)
        ks = jax.random.split(k, cfg.num_layers)
        stacked[name] = jax.vmap(one)(ks)
    params["layers"] = stacked
    params["final_norm"] = jnp.zeros((D,), dtype)
    if not cfg.tie_embeddings:
        params["head"] = _dense_init(keys[3], (Kcb, D, Vp), dtype, fan_in=D)
    return params


def abstract_params(cfg: ModelConfig, dtype=jnp.bfloat16) -> PyTree:
    return jax.eval_shape(
        lambda: init_params(cfg, jax.random.key(0), dtype))


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16, quantized: bool = False) -> PyTree:
    """Decode cache pytree; leaves stacked (L, ...) for the layer scan.
    ``quantized=True`` stores k/v as int8 + per-(token, head) scales —
    half the residency and half the per-step HBM streaming (§Perf C3),
    the paper's precision-zoo idea applied to the cache."""
    Lc = cfg.num_layers
    cache: Dict[str, Any] = {"lengths": jnp.zeros((batch,), jnp.int32)}
    if cfg.uses_attention:
        KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        T = max_len + cfg.cache_extra_tokens
        if quantized and cfg.family != "hybrid":
            # hybrid blocks fuse attention+SSM per layer; their caches
            # stay bf16 (the SSM state dominates their residency anyway)
            cache["k"] = jnp.zeros((Lc, batch, T, KV, hd), jnp.int8)
            cache["v"] = jnp.zeros((Lc, batch, T, KV, hd), jnp.int8)
            cache["k_scale"] = jnp.zeros((Lc, batch, T, KV), jnp.float32)
            cache["v_scale"] = jnp.zeros((Lc, batch, T, KV), jnp.float32)
        else:
            cache["k"] = jnp.zeros((Lc, batch, T, KV, hd), dtype)
            cache["v"] = jnp.zeros((Lc, batch, T, KV, hd), dtype)
    if cfg.uses_ssm:
        di = cfg.d_model if cfg.family == "hybrid" else cfg.ssm_d_inner
        nh = di // cfg.ssm_head_dim
        convd = di + 2 * cfg.ssm_ngroups * cfg.ssm_state
        cache["state"] = jnp.zeros(
            (Lc, batch, nh, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32)
        cache["conv"] = jnp.zeros(
            (Lc, batch, cfg.ssm_conv_width - 1, convd), dtype)
    return cache


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16, quantized: bool = False) -> PyTree:
    return jax.eval_shape(
        lambda: init_cache(cfg, batch, max_len, dtype, quantized))


def _layer_windows(cfg: ModelConfig) -> jnp.ndarray:
    return jnp.array(
        [cfg.window_for_kind(k) for k in cfg.layer_kinds()], jnp.int32)


# ---------------------------------------------------------------------------
# One transformer block (handles every family; scanned over layers)
# ---------------------------------------------------------------------------
def _block_prefill(cfg: ModelConfig, h, lp, window, positions, *,
                   moe_impl: str, collect_cache: bool):
    prefix = cfg.num_meta_tokens
    new_cache = {}
    # "tp_out" names mark the row-parallel outputs (the tensors produced
    # by a model-axis all-reduce).  The remat policy saves exactly these,
    # so backward recompute does NOT re-run the TP collectives — the
    # Megatron-style selective-activation-recompute trick (§Perf B1).
    from jax.ad_checkpoint import checkpoint_name as name
    if cfg.family == "hybrid":
        x = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
        attn_raw, k, v = L.attention_prefill(
            cfg, lp, x, positions, window, prefix=prefix)
        ssm_out = L.ssm_prefill(cfg, lp, x, hybrid=True,
                                return_state=collect_cache)
        if collect_cache:
            ssm_raw, state, conv_tail = ssm_out
            new_cache.update(k=k, v=v, state=state, conv=conv_tail)
        else:
            ssm_raw = ssm_out
        fused = 0.5 * (L.rms_norm(attn_raw, lp["fuse_na"], cfg.norm_eps)
                       + L.rms_norm(ssm_raw, lp["fuse_ns"], cfg.norm_eps))
        h = h + name(L.mm(fused, lp["wo"]), "tp_out")
    elif cfg.uses_ssm:  # pure SSM (mamba2)
        x = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
        out = L.ssm_prefill(cfg, lp, x, return_state=collect_cache)
        if collect_cache:
            y, state, conv_tail = out
            new_cache.update(state=state, conv=conv_tail)
        else:
            y = out
        h = h + name(L.mm(y, lp["ssm_out"]), "tp_out")
    else:  # attention families
        x = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
        attn_raw, k, v = L.attention_prefill(
            cfg, lp, x, positions, window, prefix=prefix)
        if collect_cache:
            new_cache.update(k=k, v=v)
        attn = name(L.mm(attn_raw, lp["wo"]), "tp_out")
        if cfg.post_norm:
            attn = L.rms_norm(attn, lp["post_ln1"], cfg.norm_eps)
        h = h + attn
    # FFN
    if cfg.is_moe:
        x2 = L.rms_norm(h, lp["ln2"], cfg.norm_eps)
        h = h + name(L.moe_ffn(cfg, lp, x2, impl=moe_impl), "tp_out")
    elif cfg.d_ff:
        x2 = L.rms_norm(h, lp["ln2"], cfg.norm_eps)
        ff = name(L.mlp(cfg, x2, lp["wg"], lp["wu"], lp["wd"]), "tp_out")
        if cfg.post_norm:
            ff = L.rms_norm(ff, lp["post_ln2"], cfg.norm_eps)
        h = h + ff
    return h, new_cache


def _block_decode(cfg: ModelConfig, h, lp, window, cache_layer, lengths, *,
                  moe_impl: str, uniform_pos: bool = False):
    prefix = cfg.num_meta_tokens
    new_cache = {}
    if cfg.family == "hybrid":
        x = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
        attn_raw, nk, nv = L.attention_decode(
            cfg, lp, x, cache_layer["k"], cache_layer["v"], lengths, window,
            prefix=prefix, uniform_pos=uniform_pos)
        ssm_raw, nstate, nconv = L.ssm_decode(
            cfg, lp, x, cache_layer["state"], cache_layer["conv"],
            hybrid=True)
        new_cache.update(k=nk, v=nv, state=nstate, conv=nconv)
        fused = 0.5 * (L.rms_norm(attn_raw, lp["fuse_na"], cfg.norm_eps)
                       + L.rms_norm(ssm_raw, lp["fuse_ns"], cfg.norm_eps))
        h = h + L.mm(fused, lp["wo"])
    elif cfg.uses_ssm:
        x = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
        y, nstate, nconv = L.ssm_decode(
            cfg, lp, x, cache_layer["state"], cache_layer["conv"])
        new_cache.update(state=nstate, conv=nconv)
        h = h + L.mm(y, lp["ssm_out"])
    elif "k_scale" in cache_layer:  # int8 KV cache (§Perf C3)
        x = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
        attn_raw, knq, kns, vnq, vns = L.attention_decode_q(
            cfg, lp, x, cache_layer["k"], cache_layer["k_scale"],
            cache_layer["v"], cache_layer["v_scale"], lengths, window,
            prefix=prefix)
        new_cache.update(k=knq, k_scale=kns, v=vnq, v_scale=vns)
        attn = L.mm(attn_raw, lp["wo"])
        if cfg.post_norm:
            attn = L.rms_norm(attn, lp["post_ln1"], cfg.norm_eps)
        h = h + attn
    else:
        x = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
        attn_raw, nk, nv = L.attention_decode(
            cfg, lp, x, cache_layer["k"], cache_layer["v"], lengths, window,
            prefix=prefix, uniform_pos=uniform_pos)
        new_cache.update(k=nk, v=nv)
        attn = L.mm(attn_raw, lp["wo"])
        if cfg.post_norm:
            attn = L.rms_norm(attn, lp["post_ln1"], cfg.norm_eps)
        h = h + attn
    if cfg.is_moe:
        x2 = L.rms_norm(h, lp["ln2"], cfg.norm_eps)
        h = h + L.moe_ffn(cfg, lp, x2, impl=moe_impl)
    elif cfg.d_ff:
        x2 = L.rms_norm(h, lp["ln2"], cfg.norm_eps)
        ff = L.mlp(cfg, x2, lp["wg"], lp["wu"], lp["wd"])
        if cfg.post_norm:
            ff = L.rms_norm(ff, lp["post_ln2"], cfg.norm_eps)
        h = h + ff
    return h, new_cache


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------
def embed_tokens(cfg: ModelConfig, params, tokens: jnp.ndarray) -> jnp.ndarray:
    """tokens: (B, S) int32, or (B, S, Kcb) for multi-codebook audio."""
    emb = params["embed"]  # (Kcb, Vp, D)
    if cfg.num_codebooks == 1:
        h = jnp.take(emb[0], tokens, axis=0)
    else:
        per = [jnp.take(emb[i], tokens[..., i], axis=0)
               for i in range(cfg.num_codebooks)]
        h = sum(per)
    if cfg.emb_scale:
        h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)
    return h


def lm_logits(cfg: ModelConfig, params, h: jnp.ndarray) -> jnp.ndarray:
    """h: (B, S, D) -> logits (B, S, Kcb, Vp) float32."""
    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        w = jnp.swapaxes(params["embed"], 1, 2)  # (Kcb, D, Vp)
    else:
        w = L.dense_w(params["head"])
    logits = jnp.einsum("bsd,kdv->bskv", h, w).astype(jnp.float32)
    if cfg.final_logit_softcap:
        logits = L.softcap(logits, cfg.final_logit_softcap)
    return logits


# ---------------------------------------------------------------------------
# Full-sequence forward (training) and loss
# ---------------------------------------------------------------------------
def _frontend(cfg: ModelConfig, params, batch: Dict[str, jnp.ndarray]):
    """Returns (h, loss_mask) after stub frontends / meta tokens."""
    h = embed_tokens(cfg, params, batch["tokens"])
    B = h.shape[0]
    mask = jnp.ones(h.shape[:2], jnp.float32)
    if cfg.frontend == "vision_stub":
        vis = batch["patch_embeds"].astype(h.dtype)  # (B, Nv, D) — STUB input
        h = jnp.concatenate([vis, h], axis=1)
        mask = jnp.concatenate(
            [jnp.zeros((B, vis.shape[1]), jnp.float32), mask], axis=1)
    if cfg.num_meta_tokens:
        meta = jnp.broadcast_to(
            params["meta"][None], (B,) + params["meta"].shape).astype(h.dtype)
        h = jnp.concatenate([meta, h], axis=1)
        mask = jnp.concatenate(
            [jnp.zeros((B, cfg.num_meta_tokens), jnp.float32), mask], axis=1)
    return h, mask


def forward(cfg: ModelConfig, params, batch: Dict[str, jnp.ndarray], *,
            moe_impl: str = "dense", remat: bool = False) -> jnp.ndarray:
    """Full-sequence logits: (B, S_total, Kcb, Vp)."""
    h, _ = _frontend(cfg, params, batch)
    h = L.hint(h, "dp", None, None)
    S = h.shape[1]
    positions = jnp.arange(S)
    windows = _layer_windows(cfg)

    def block(carry, inp):
        lp, window = inp
        out, _ = _block_prefill(cfg, carry, lp, window, positions,
                                moe_impl=moe_impl, collect_cache=False)
        return out, ()

    if remat:
        block = jax.checkpoint(block, prevent_cse=False,
                               policy=_remat_policy())
    h, _ = _scan(block, h, (params["layers"], windows))
    return lm_logits(cfg, params, h)


def forward_hidden(cfg: ModelConfig, params, batch: Dict[str, jnp.ndarray],
                   *, moe_impl: str = "dense",
                   remat: bool = False) -> jnp.ndarray:
    """Final-normed hidden states (B, S_total, D) — no logits projection."""
    h, _ = _frontend(cfg, params, batch)
    h = L.hint(h, "dp", None, None)
    S = h.shape[1]
    positions = jnp.arange(S)
    windows = _layer_windows(cfg)

    def block(carry, inp):
        lp, window = inp
        out, _ = _block_prefill(cfg, carry, lp, window, positions,
                                moe_impl=moe_impl, collect_cache=False)
        return out, ()

    if remat:
        block = jax.checkpoint(block, prevent_cse=False,
                               policy=_remat_policy())
    h, _ = _scan(block, h, (params["layers"], windows))
    return L.rms_norm(h, params["final_norm"], cfg.norm_eps)


CE_CHUNK = 512  # sequence-chunked cross entropy (keeps logits off HBM)


def loss_fn(cfg: ModelConfig, params, batch: Dict[str, jnp.ndarray], *,
            moe_impl: str = "dense", remat: bool = True,
            z_loss: float = 1e-4):
    """Causal LM loss, padded-vocab masked, computed in sequence chunks so
    the full (B, S, Vp) logits tensor never materializes (the checkpointed
    chunk body recomputes its logits in the backward pass — the standard
    fused-CE memory optimization).  Returns (loss, metrics)."""
    hidden = forward_hidden(cfg, params, batch, moe_impl=moe_impl,
                            remat=remat)
    B, S_total, D = hidden.shape
    labels = batch["labels"]  # (B, S) or (B, S, Kcb)
    if labels.ndim == 2:
        labels = labels[..., None]  # (B, S, 1)
    S = labels.shape[1]
    hidden = hidden[:, S_total - S:, :]  # frontend/meta positions: unlabeled
    if cfg.tie_embeddings:
        w = jnp.swapaxes(params["embed"], 1, 2)  # (Kcb, D, Vp)
    else:
        w = L.dense_w(params["head"])
    Vp = w.shape[-1]
    col_ok = jnp.arange(Vp) < cfg.vocab_size

    def chunk_stats(h_chunk, lab_chunk):
        # h_chunk: (B, ck, D); lab_chunk: (B, ck, Kcb)
        logits = jnp.einsum("bsd,kdv->bskv",
                            h_chunk, w.astype(h_chunk.dtype)
                            ).astype(jnp.float32)
        if cfg.final_logit_softcap:
            logits = L.softcap(logits, cfg.final_logit_softcap)
        logits = jnp.where(col_ok[None, None, None, :], logits, -1e9)
        lse = jax.nn.logsumexp(logits, axis=-1)  # (B, ck, Kcb)
        lab = jnp.take_along_axis(
            logits, lab_chunk[..., None].astype(jnp.int32), axis=-1)[..., 0]
        correct = jnp.argmax(logits, -1) == lab_chunk
        return (jnp.sum(lse - lab), jnp.sum(lse ** 2),
                jnp.sum(correct.astype(jnp.float32)))

    ck = min(CE_CHUNK, S)
    n_chunks, rem = divmod(S, ck)
    body = jax.checkpoint(
        lambda carry, inp: (tuple(
            c + s for c, s in zip(carry, chunk_stats(*inp))), ()),
        prevent_cse=False)
    hs = jnp.moveaxis(
        hidden[:, :n_chunks * ck].reshape(B, n_chunks, ck, D), 1, 0)
    ls = jnp.moveaxis(
        labels[:, :n_chunks * ck].reshape(B, n_chunks, ck, -1), 1, 0)
    zero = jnp.zeros((), jnp.float32)
    (nll_sum, zsq_sum, acc_sum), _ = _scan(body, (zero, zero, zero),
                                           (hs, ls))
    if rem:
        t = chunk_stats(hidden[:, n_chunks * ck:],
                        labels[:, n_chunks * ck:])
        nll_sum, zsq_sum, acc_sum = (nll_sum + t[0], zsq_sum + t[1],
                                     acc_sum + t[2])
    denom = float(B * S * labels.shape[-1])
    nll = nll_sum / denom
    loss = nll
    if z_loss:
        loss = loss + z_loss * zsq_sum / denom
    metrics = {"loss": loss, "nll": nll, "accuracy": acc_sum / denom}
    return loss, metrics


# ---------------------------------------------------------------------------
# Prefill: run the full prompt, build the decode cache
# ---------------------------------------------------------------------------
def prefill(cfg: ModelConfig, params, batch: Dict[str, jnp.ndarray],
            max_len: int, *, moe_impl: str = "dense",
            cache_dtype=jnp.bfloat16, quantize_cache: bool = False):
    """Returns (last-token logits (B, Kcb, Vp), populated cache)."""
    h, _ = _frontend(cfg, params, batch)
    h = L.hint(h, "dp", None, None)
    B, S = h.shape[0], h.shape[1]
    positions = jnp.arange(S)
    windows = _layer_windows(cfg)
    T = max_len + cfg.cache_extra_tokens

    def block(carry, inp):
        lp, window = inp
        out, new_cache = _block_prefill(
            cfg, carry, lp, window, positions,
            moe_impl=moe_impl, collect_cache=True)
        emit = {}
        if "k" in new_cache:
            pad = T - S
            if quantize_cache:
                for nm in ("k", "v"):
                    qv, sv = L.quantize_kv(new_cache[nm])
                    emit[nm] = jnp.pad(
                        qv, ((0, 0), (0, pad), (0, 0), (0, 0)))
                    emit[nm + "_scale"] = jnp.pad(
                        sv, ((0, 0), (0, pad), (0, 0)))
            else:
                emit["k"] = jnp.pad(
                    new_cache["k"], ((0, 0), (0, pad), (0, 0), (0, 0))
                ).astype(cache_dtype)
                emit["v"] = jnp.pad(
                    new_cache["v"], ((0, 0), (0, pad), (0, 0), (0, 0))
                ).astype(cache_dtype)
        if "state" in new_cache:
            emit["state"] = new_cache["state"]
            emit["conv"] = new_cache["conv"].astype(cache_dtype)
        return out, emit

    h, emitted = _scan(block, h, (params["layers"], windows))
    cache = dict(emitted)
    cache["lengths"] = jnp.full((B,), S, jnp.int32)
    logits = lm_logits(cfg, params, h[:, -1:, :])[:, 0]
    return logits, cache


# ---------------------------------------------------------------------------
# Decode: one token for every sequence in the batch
# ---------------------------------------------------------------------------
def decode_step(cfg: ModelConfig, params, cache: PyTree,
                tokens: jnp.ndarray, *, moe_impl: str = "dense",
                uniform_pos: bool = False):
    """tokens: (B,) int32 or (B, Kcb).  Returns (logits (B, Kcb, Vp), cache)."""
    if cfg.num_codebooks == 1:
        tok = tokens[:, None]  # (B, 1)
    else:
        tok = tokens[:, None, :]  # (B, 1, Kcb)
    h = embed_tokens(cfg, params, tok)  # (B, 1, D)
    lengths = cache["lengths"]
    windows = _layer_windows(cfg)
    scan_cache = {k: v for k, v in cache.items() if k != "lengths"}

    def block(carry, inp):
        lp, window, cache_layer = inp
        out, new_cache = _block_decode(
            cfg, carry, lp, window, cache_layer, lengths, moe_impl=moe_impl,
            uniform_pos=uniform_pos)
        return out, new_cache

    h, new_scan_cache = _scan(
        block, h, (params["layers"], windows, scan_cache))
    new_cache = dict(new_scan_cache)
    quantized = "k_scale" in cache
    if (uniform_pos or quantized) and "k" in new_cache:
        # Deferred write: the scan emitted only the per-layer fresh k/v
        # (L, B, KV, hd); commit them with one slice-write per cache.
        pos = lengths[0]
        names = (("k", "v", "k_scale", "v_scale") if quantized
                 else ("k", "v"))
        for name in names:
            fresh = new_cache[name][:, :, None]  # (L, B, 1, KV[, hd])
            start = (0, 0, pos) + (0,) * (fresh.ndim - 3)
            new_cache[name] = lax.dynamic_update_slice(
                scan_cache[name], fresh.astype(scan_cache[name].dtype),
                start)
    new_cache["lengths"] = lengths + 1
    logits = lm_logits(cfg, params, h)[:, 0]  # (B, Kcb, Vp)
    return logits, new_cache


def greedy_token(cfg: ModelConfig, logits: jnp.ndarray) -> jnp.ndarray:
    """logits (B, Kcb, Vp) -> next token ids (B,) or (B, Kcb)."""
    col = jnp.arange(logits.shape[-1])
    masked = jnp.where(col < cfg.vocab_size, logits, -jnp.inf)
    ids = jnp.argmax(masked, axis=-1).astype(jnp.int32)
    return ids[:, 0] if cfg.num_codebooks == 1 else ids
