"""Unified model configuration for the 10 assigned LM-family architectures.

One dataclass covers dense / MoE / SSM / hybrid / audio / vlm families; the
per-arch files in ``repro.configs`` instantiate it with published numbers.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int  # query heads; 0 for attention-free archs
    num_kv_heads: int
    d_ff: int
    vocab_size: int  # logical vocabulary
    head_dim: int = 0  # 0 -> d_model // num_heads

    # --- attention extras -------------------------------------------------
    rope_theta: float = 10000.0
    sliding_window: int = 0  # 0 = full attention everywhere
    # Cycled per-layer kinds. Entries: "global" | "local" | "ssm" | "hybrid".
    layer_pattern: Tuple[str, ...] = ()
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    attn_scale: float = 0.0  # 0 -> 1/sqrt(head_dim)

    # --- MoE ---------------------------------------------------------------
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_d_ff: int = 0  # per-expert hidden width (d_ff used for dense/shared)
    num_shared_experts: int = 0

    # --- SSM (mamba2 / SSD) -------------------------------------------------
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_conv_width: int = 4
    ssm_ngroups: int = 1

    # --- hybrid (hymba) ------------------------------------------------------
    num_meta_tokens: int = 0

    # --- modality frontends (stubs per assignment) ---------------------------
    num_codebooks: int = 1  # musicgen: 4 EnCodec codebooks
    frontend: str = "none"  # none | vision_stub | audio_stub
    num_vision_tokens: int = 0

    # --- misc -----------------------------------------------------------------
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    act: str = "silu"  # silu | gelu
    emb_scale: bool = False  # gemma2 scales embeddings by sqrt(d_model)
    post_norm: bool = False  # gemma2 applies post-block norms
    qk_norm: bool = False
    vocab_pad_multiple: int = 256
    dtype: str = "bfloat16"

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.num_heads:
            return self.d_model // self.num_heads
        return 0

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, self.vocab_pad_multiple)

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        if not self.ssm_state:
            return 0
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def ssm_conv_dim(self) -> int:
        # conv runs over concat(x, B, C) as in Mamba-2.
        return self.ssm_d_inner + 2 * self.ssm_ngroups * self.ssm_state

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer kind tuple of length num_layers (pattern cycled)."""
        if self.family == "ssm":
            return tuple("ssm" for _ in range(self.num_layers))
        if self.family == "hybrid":
            base = list(self.layer_pattern) or ["hybrid"]
            kinds = [base[i % len(base)] for i in range(self.num_layers)]
            return tuple(kinds)
        if not self.layer_pattern:
            return tuple("global" for _ in range(self.num_layers))
        return tuple(
            self.layer_pattern[i % len(self.layer_pattern)]
            for i in range(self.num_layers)
        )

    def window_for_kind(self, kind: str) -> int:
        """KV window length for a layer kind. 0 = unbounded (full)."""
        if kind in ("local", "hybrid") and self.sliding_window:
            return self.sliding_window
        return 0  # "global", "hybrid_full", "ssm"

    @property
    def cache_extra_tokens(self) -> int:
        """Cache slots beyond the text sequence (meta + vision-stub tokens)."""
        return self.num_meta_tokens + self.num_vision_tokens

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def uses_attention(self) -> bool:
        return self.num_heads > 0

    @property
    def uses_ssm(self) -> bool:
        return self.ssm_state > 0

    # ------------------------------------------------------------------
    # Parameter / capacity accounting (used by core.capacity and the
    # model-zoo size math — must agree with init_params shapes).
    # ------------------------------------------------------------------
    def param_count(self) -> int:
        D, F, V = self.d_model, self.d_ff, self.padded_vocab
        hd = self.resolved_head_dim
        H, KV = self.num_heads, self.num_kv_heads
        n = 0
        # embeddings (+ per-codebook for audio)
        n += self.num_codebooks * V * D
        if not self.tie_embeddings:
            n += self.num_codebooks * V * D
        n += D  # final norm
        n += self.num_meta_tokens * D
        # Mirrors models.transformer._layer_param_template exactly
        # (validated by tests/test_models.py::test_param_count_matches_init).
        pl = 0
        hybrid = self.family == "hybrid"
        if self.uses_attention:
            pl += D  # ln1
            pl += D * H * hd + 2 * D * KV * hd + H * hd * D  # qkvo
            if self.post_norm:
                pl += D  # post_ln1
            if self.qk_norm:
                pl += 2 * hd
        if self.uses_ssm:
            di = D if hybrid else self.ssm_d_inner
            nst, nh = self.ssm_state, max(1, di // self.ssm_head_dim)
            convd = di + 2 * self.ssm_ngroups * nst
            if not self.uses_attention:
                pl += D  # ln1
            pl += D * (2 * di + 2 * self.ssm_ngroups * nst + nh)  # ssm_in
            pl += self.ssm_conv_width * convd + convd  # conv w+b
            pl += 3 * nh  # A_log, D_skip, dt_bias
            pl += di  # gated norm
            if not hybrid:
                pl += di * D  # ssm_out
        if hybrid:
            pl += 2 * D  # fuse_na, fuse_ns
        if self.is_moe:
            E, Fe = self.num_experts, self.moe_d_ff
            pl += D  # ln2
            pl += D * E  # router
            pl += E * (2 * D * Fe + Fe * D)
            if self.num_shared_experts:
                pl += self.num_shared_experts * (2 * D * F + F * D)
        elif F:
            pl += D  # ln2
            pl += 2 * D * F + F * D
            if self.post_norm:
                pl += D  # post_ln2
        n += self.num_layers * pl
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed top-k experts)."""
        if not self.is_moe:
            return self.param_count()
        D, Fe = self.d_model, self.moe_d_ff
        E, K = self.num_experts, self.num_experts_per_tok
        inactive_per_layer = (E - K) * (3 * D * Fe)
        return self.param_count() - self.num_layers * inactive_per_layer

    def bytes_for_precision(self, bits: int) -> int:
        """Weight-only footprint of one zoo variant (scales included for int)."""
        n = self.param_count()
        base = n * bits // 8
        if bits < 16:
            # per-channel fp16 scales: ~1 scale per 128 weights, 2B each.
            base += (n // 128) * 2
        return base


SHAPE_SPECS = {
    # name: (seq_len, global_batch, step kind)
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}

# Archs allowed to run long_500k (sub-quadratic attention path).
LONG_CONTEXT_ARCHS = ("mamba2-780m", "hymba-1.5b", "gemma2-2b")


def cell_is_runnable(arch_name: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch_name in LONG_CONTEXT_ARCHS
    return True
