"""The paper's four NN-model eviction policies (§III-B).

Each policy answers one question: application ``app`` needs a model loaded
at time ``now`` — which variant do we load, and which victims' models do we
evict or downgrade to make room?

All policies are pure: they take a :class:`MemoryState` (not mutated) and
return a :class:`ProcurePlan`; the manager enacts plans.  Semantics follow
the paper precisely:

* **LFE** — evict the minimalist app with the *largest* loaded model first,
  repeat; if evicting everything is not enough, retry with the requester's
  next-smaller variant.
* **BFE** — evict the minimalist app whose loaded size is *closest from
  above* to the remaining need (best fit; falls back to largest-below).
* **WS-BFE** — BFE restricted to victims whose request window does NOT
  overlap the requester's, and victims are *downgraded to their
  lowest-precision variant* instead of unloaded — so an unpredicted request
  still warm-starts (the paper's key robustness mechanism).
* **iWS-BFE** (Algorithm 1) — WS-BFE plus an LRU-K-style history filter
  (apps requested during the history window H are not candidates) and a
  Bayesian fitness score (Eq. 3) served from a max-heap:
      Score(A_j) = norm(t_j − now) · [1 − P(r_j | A_i ∈ A*)]
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.memory_state import INF, MemoryState
from repro.core.model_zoo import ModelVariant


@dataclass(frozen=True)
class Eviction:
    app: str
    old: ModelVariant
    new: Optional[ModelVariant]  # None = fully unloaded

    @property
    def freed_mb(self) -> float:
        return self.old.size_mb - (self.new.size_mb if self.new else 0.0)


@dataclass(frozen=True)
class ProcurePlan:
    app: str
    variant: Optional[ModelVariant]  # None => inference failure
    evictions: Tuple[Eviction, ...] = ()

    @property
    def ok(self) -> bool:
        return self.variant is not None


def _free_after(state: MemoryState, app: str,
                evictions: List[Eviction]) -> float:
    """Free memory once evictions are enacted and app's current model (if
    any) is released for replacement."""
    free = state.free_mb + sum(e.freed_mb for e in evictions)
    cur = state.tenants[app].loaded
    if cur is not None:
        free += cur.size_mb
    return free


def _windows_overlap(state: MemoryState, a: str, b: str,
                     delta: float) -> bool:
    ta, tb = state.tenants[a], state.tenants[b]
    lo_a, hi_a = ta.window(delta)
    lo_b, hi_b = tb.window(delta)
    if lo_a is INF or lo_b is INF:
        return False
    return lo_a <= hi_b and lo_b <= hi_a


# ---------------------------------------------------------------------------
# Policy 1: Largest-First Eviction
# ---------------------------------------------------------------------------
def lfe(state: MemoryState, app: str, now: float, *, delta: float,
        history: float = 0.0) -> ProcurePlan:
    victims = [a for a in state.minimalist_set(now, delta)
               if a != app and state.tenants[a].loaded is not None
               and state.tenants[a].inflight_mb == 0.0]
    victims.sort(key=lambda a: -state.tenants[a].loaded.size_mb)
    for variant in state.tenants[app].zoo.variants:
        evictions: List[Eviction] = []
        for v in victims:
            if _free_after(state, app, evictions) >= variant.size_mb:
                break
            evictions.append(Eviction(v, state.tenants[v].loaded, None))
        if _free_after(state, app, evictions) >= variant.size_mb:
            return ProcurePlan(app, variant, tuple(evictions))
    return ProcurePlan(app, None)


# ---------------------------------------------------------------------------
# Policy 2: Best-Fit Eviction
# ---------------------------------------------------------------------------
def bfe(state: MemoryState, app: str, now: float, *, delta: float,
        history: float = 0.0) -> ProcurePlan:
    victims = [a for a in state.minimalist_set(now, delta)
               if a != app and state.tenants[a].loaded is not None
               and state.tenants[a].inflight_mb == 0.0]
    for variant in state.tenants[app].zoo.variants:
        evictions: List[Eviction] = []
        remaining = list(victims)
        while (_free_after(state, app, evictions) < variant.size_mb
               and remaining):
            need = variant.size_mb - _free_after(state, app, evictions)
            # best fit: smallest loaded size that still covers the need;
            # if none covers it, take the largest available.
            covering = [a for a in remaining
                        if state.tenants[a].loaded.size_mb >= need]
            if covering:
                pick = min(covering,
                           key=lambda a: state.tenants[a].loaded.size_mb)
            else:
                pick = max(remaining,
                           key=lambda a: state.tenants[a].loaded.size_mb)
            remaining.remove(pick)
            evictions.append(Eviction(pick, state.tenants[pick].loaded, None))
        if _free_after(state, app, evictions) >= variant.size_mb:
            return ProcurePlan(app, variant, tuple(evictions))
    return ProcurePlan(app, None)


# ---------------------------------------------------------------------------
# Policy 3: Warm-Start-aware Best-Fit Eviction
# ---------------------------------------------------------------------------
def _downgrade_candidates(state: MemoryState, app: str, now: float,
                          delta: float, *, require_history: float = 0.0,
                          include_smallest: bool = False) -> List[str]:
    out = []
    for a in state.minimalist_set(now, delta):
        t = state.tenants[a]
        if a == app or t.loaded is None:
            continue
        if t.inflight_mb > 0.0:
            continue  # mid-staging: a background load owns this tenant's
            # residency until it commits or is cancelled; downgrading it
            # underneath the loader would desync the in-flight charge
        if t.loaded is t.zoo.smallest and not include_smallest:
            continue  # nothing to scavenge (unless unloading outright)
        if _windows_overlap(state, app, a, delta):
            continue  # lowest eviction priority: skip (paper §III-B-4)
        if require_history and t.last_request > now - require_history:
            continue  # LRU-K filter: recently-requested apps are exempt
        out.append(a)
    return out


def _scavenge_best_fit(state: MemoryState, cands: List[str],
                       shortfall: Callable[[List[Eviction]], float]
                       ) -> List[Eviction]:
    """Greedy best-fit downgrade selection shared by WS-BFE and the KV
    headroom path: pick the victim whose scavengeable size (loaded −
    smallest) covers the remaining ``shortfall`` with least waste — or
    the largest available when none covers — until the shortfall is met
    or candidates run out."""
    def scavengeable(a: str) -> float:
        t = state.tenants[a]
        return t.loaded.size_mb - t.zoo.smallest.size_mb

    remaining = list(cands)
    evictions: List[Eviction] = []
    while (need := shortfall(evictions)) > 0 and remaining:
        covering = [a for a in remaining if scavengeable(a) >= need]
        pick = (min(covering, key=scavengeable) if covering
                else max(remaining, key=scavengeable))
        remaining.remove(pick)
        t = state.tenants[pick]
        evictions.append(Eviction(pick, t.loaded, t.zoo.smallest))
    return evictions


def ws_bfe(state: MemoryState, app: str, now: float, *, delta: float,
           history: float = 0.0) -> ProcurePlan:
    cands = _downgrade_candidates(state, app, now, delta)
    for variant in state.tenants[app].zoo.variants:
        evictions = _scavenge_best_fit(
            state, cands,
            lambda evs: variant.size_mb - _free_after(state, app, evs))
        if _free_after(state, app, evictions) >= variant.size_mb:
            return ProcurePlan(app, variant, tuple(evictions))
        # §III-B-1 "high inference demand" fallback: fully unload the
        # already-downgraded victims (this is what separates WS-BFE from
        # iWS-BFE, which per Algorithm 1 only ever *replaces* — WS-BFE's
        # unloads are the cold-starts Fig 5 charges it with).
        evictions = [Eviction(e.app, e.old, None) for e in evictions]
        if _free_after(state, app, evictions) >= variant.size_mb:
            return ProcurePlan(app, variant, tuple(evictions))
    return ProcurePlan(app, None)


# ---------------------------------------------------------------------------
# Policy 4: Intelligent Warm-Start-aware Best-Fit Eviction (Algorithm 1)
# ---------------------------------------------------------------------------
def iws_bfe(state: MemoryState, app: str, now: float, *, delta: float,
            history: float) -> ProcurePlan:
    # Steps 2–3: τ = A′ not requested during H; E = τ non-overlapping with
    # the requester's window.  (_downgrade_candidates applies both filters.)
    cands = _downgrade_candidates(state, app, now, delta,
                                  require_history=history)
    if cands:
        # Step 4: fitness score (Eq. 3).
        dists = {}
        for a in cands:
            tj = state.tenants[a].predicted_next
            dists[a] = (tj - now) if tj is not INF else INF
        finite = [d for d in dists.values() if d is not INF and d > 0]
        dmax = max(finite) if finite else 1.0
        scores = {}
        for a in cands:
            d = dists[a]
            norm = 1.0 if d is INF else max(d, 0.0) / max(dmax, 1e-9)
            scores[a] = norm * (1.0 - state.p_unexpected(a))
        # Step 5: max-heap on fitness.
        heap = [(-scores[a], a) for a in cands]
        heapq.heapify(heap)
    else:
        heap = []

    for variant in state.tenants[app].zoo.variants:
        evictions: List[Eviction] = []
        h = list(heap)  # fresh heap per variant attempt (Steps 6–18 redo)
        while _free_after(state, app, evictions) < variant.size_mb and h:
            _, w = heapq.heappop(h)  # Step 7: extract max-fitness root
            t = state.tenants[w]
            # Step 9: scavenge by replacing with the lowest-precision model.
            evictions.append(Eviction(w, t.loaded, t.zoo.smallest))
        if _free_after(state, app, evictions) >= variant.size_mb:
            # Steps 12–14: enact replacements, load m_i.
            return ProcurePlan(app, variant, tuple(evictions))
        # Step 17–18: retry with next smaller model.
    return ProcurePlan(app, None)  # Step 17: inference request fails


# ---------------------------------------------------------------------------
# KV-cache headroom (serving runtime): scavenge weight memory for caches
# ---------------------------------------------------------------------------
def kv_headroom_plan(state: MemoryState, app: str, now: float,
                     need_mb: float, *, delta: float,
                     history: float = 0.0) -> Tuple[Eviction, ...]:
    """Free ≥ ``need_mb`` of headroom for ``app``'s KV cache by downgrading
    minimalist victims to their smallest variant (same candidate filters as
    iWS-BFE: window-overlap and LRU-K history exempt), best-fit first.

    If downgrades alone cannot cover the need, victims are *unloaded*
    outright — the same "high inference demand" fallback WS-BFE applies
    to weight pressure (§III-B-1), extended to cache pressure: a decode
    cache that cannot fit is a failed inference, which the paper weighs
    strictly worse than a future cold start.  Already-downgraded victims
    go first (their remaining footprint is minimal), then other
    minimalist tenants sitting at their smallest variant, best-fit.

    Unlike the procure policies this never touches the requester's own
    variant — the caller decides whether to self-downgrade if scavenging
    victims is not enough.  The returned evictions may be insufficient;
    the caller re-checks ``free_mb`` after enacting.
    """
    def short(evs: List[Eviction]) -> float:
        return need_mb - state.free_mb - sum(e.freed_mb for e in evs)

    cands = _downgrade_candidates(state, app, now, delta,
                                  require_history=history)
    evictions = list(_scavenge_best_fit(state, cands, short))
    if short(evictions) <= 0:
        return tuple(evictions)
    # Cache-pressure fallback: downgrades were not enough — unload.
    evictions = [Eviction(e.app, e.old, None) for e in evictions]
    taken = {e.app for e in evictions}
    pool = [a for a in _downgrade_candidates(state, app, now, delta,
                                             require_history=history,
                                             include_smallest=True)
            if a not in taken]
    while (need := short(evictions)) > 0 and pool:
        def loaded_mb(a: str) -> float:
            return state.tenants[a].loaded.size_mb
        covering = [a for a in pool if loaded_mb(a) >= need]
        pick = (min(covering, key=loaded_mb) if covering
                else max(pool, key=loaded_mb))
        pool.remove(pick)
        evictions.append(Eviction(pick, state.tenants[pick].loaded, None))
    return tuple(evictions)


def kv_desperation_plan(state: MemoryState, app: str,
                        need_mb: float) -> Tuple[Eviction, ...]:
    """Last resort before rejecting a batch for cache pressure: ignore
    the window-overlap and LRU-K protections and scavenge every other
    tenant — downgrades first (cheapest robustness loss, biggest
    scavengeable first), then outright unloads.  A failed inference
    outranks every warm-start heuristic in the paper's cost model, and
    without this pass a predicting engine is *more* rejection-prone than
    a reactive one (predictions create windows, windows protect victims).
    Tenants mid-staging stay exempt — the loader owns their residency.
    """
    def short(evs: List[Eviction]) -> float:
        return need_mb - state.free_mb - sum(e.freed_mb for e in evs)

    cands = [a for a, t in state.tenants.items()
             if a != app and t.loaded is not None and t.inflight_mb == 0.0]

    def scavengeable(a: str) -> float:
        t = state.tenants[a]
        return t.loaded.size_mb - t.zoo.smallest.size_mb

    evictions: List[Eviction] = []
    for a in sorted(cands, key=scavengeable, reverse=True):
        if short(evictions) <= 0:
            break
        t = state.tenants[a]
        if t.loaded is not t.zoo.smallest:
            evictions.append(Eviction(a, t.loaded, t.zoo.smallest))
    if short(evictions) > 0:
        taken = {e.app for e in evictions}
        evictions = [Eviction(e.app, e.old, None) for e in evictions]
        rest = [a for a in cands if a not in taken]
        for a in sorted(rest, key=lambda a: state.tenants[a].loaded.size_mb,
                        reverse=True):
            if short(evictions) <= 0:
                break
            evictions.append(
                Eviction(a, state.tenants[a].loaded, None))
    return tuple(evictions)


POLICIES: Dict[str, Callable[..., ProcurePlan]] = {
    "lfe": lfe,
    "bfe": bfe,
    "ws-bfe": ws_bfe,
    "iws-bfe": iws_bfe,
}
