"""The paper's four NN-model eviction policies (§III-B).

Each policy answers one question: application ``app`` needs a model loaded
at time ``now`` — which variant do we load, and which victims' models do we
evict or downgrade to make room?

All policies are pure: they take a :class:`MemoryState` (not mutated) and
return a :class:`ProcurePlan`; the manager enacts plans.  Semantics follow
the paper precisely:

* **LFE** — evict the minimalist app with the *largest* loaded model first,
  repeat; if evicting everything is not enough, retry with the requester's
  next-smaller variant.
* **BFE** — evict the minimalist app whose loaded size is *closest from
  above* to the remaining need (best fit; falls back to largest-below).
* **WS-BFE** — BFE restricted to victims whose request window does NOT
  overlap the requester's, and victims are *downgraded to their
  lowest-precision variant* instead of unloaded — so an unpredicted request
  still warm-starts (the paper's key robustness mechanism).
* **iWS-BFE** (Algorithm 1) — WS-BFE plus an LRU-K-style history filter
  (apps requested during the history window H are not candidates) and a
  Bayesian fitness score (Eq. 3) served from a max-heap:
      Score(A_j) = norm(t_j − now) · [1 − P(r_j | A_i ∈ A*)]

Policies are consumed through the class-based :class:`Policy` protocol
(``plan_procure`` / ``plan_prefetch`` / ``plan_demand`` / ``victim_filter``
hooks) and the ``@register_policy`` registry; new policies plug in without
touching the manager (see :class:`BatchAware` for the first plugin).
Resolve a policy by its paper name with :func:`resolve_policy` and
enumerate what is registered with :func:`available_policies`.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass
from typing import Callable, ClassVar, Dict, List, Optional, Tuple, Union

from repro.core import actions as A
# Policy-level plan records live in repro.core.actions (the IR layer);
# re-exported here because this module is their historical home.
from repro.core.actions import Eviction, ProcurePlan
from repro.core.memory_state import INF, MemoryState
from repro.core.model_zoo import ModelVariant


@dataclass(frozen=True)
class DemandContext:
    """What a demand (cold tenant, requests queued) load is planning for.

    ``kv_head_mb`` is the queued head batch's cache need as it looks right
    now; ``kv_full_mb`` is the cache need of the batch the queue could
    produce *by admission time* (a full ``max_batch``-wide batch at the
    queued shapes — under a burst more requests arrive while the weight
    transfer stages, so the head-batch snapshot undershoots).  The base
    protocol plans with the head batch; :class:`BatchAware` plans with the
    full-queue bound.
    """
    kv_head_mb: float
    kv_full_mb: float
    queue_depth: int
    max_batch: int


def variant_score(variant: ModelVariant, idle_ms: float) -> float:
    """The cost-aware ranking score shared by :class:`CostBFE` and the
    elastic drain planner (``repro.serving.elastic.drain_plan``):

        score(v) = accuracy(v) · min(1, idle_ms / load_ms(v))

    ``idle_ms`` is the gap until the tenant's next predicted request;
    the readiness factor is the fraction of ``v``'s (re)load that gap
    could hide.  An unpredicted tenant (``idle_ms`` = ∞) scores pure
    accuracy — there is no known deadline to miss.
    """
    ready = (1.0 if idle_ms == INF
             else min(1.0, max(idle_ms, 0.0) / max(variant.load_ms, 1e-9)))
    return variant.accuracy * ready


def _free_after(state: MemoryState, app: str,
                evictions: List[Eviction]) -> float:
    """Free memory once evictions are enacted and app's current model (if
    any) is released for replacement."""
    free = state.free_mb + sum(e.freed_mb for e in evictions)
    cur = state.tenants[app].loaded
    if cur is not None:
        free += cur.size_mb
    return free


def _windows_overlap(state: MemoryState, a: str, b: str,
                     delta: float) -> bool:
    ta, tb = state.tenants[a], state.tenants[b]
    lo_a, hi_a = ta.window(delta)
    lo_b, hi_b = tb.window(delta)
    if lo_a is INF or lo_b is INF:
        return False
    return lo_a <= hi_b and lo_b <= hi_a


def _downgrade_candidates(state: MemoryState, app: str, now: float,
                          delta: float, *, require_history: float = 0.0,
                          include_smallest: bool = False) -> List[str]:
    out = []
    for a in state.minimalist_set(now, delta):
        t = state.tenants[a]
        if a == app or t.loaded is None:
            continue
        if t.inflight_mb > 0.0:
            continue  # mid-staging: a background load owns this tenant's
            # residency until it commits or is cancelled; downgrading it
            # underneath the loader would desync the in-flight charge
        if t.loaded is t.zoo.smallest and not include_smallest:
            continue  # nothing to scavenge (unless unloading outright)
        if _windows_overlap(state, app, a, delta):
            continue  # lowest eviction priority: skip (paper §III-B-4)
        if require_history and t.last_request > now - require_history:
            continue  # LRU-K filter: recently-requested apps are exempt
        out.append(a)
    return out


def _scavenge_best_fit(state: MemoryState, cands: List[str],
                       shortfall: Callable[[List[Eviction]], float]
                       ) -> List[Eviction]:
    """Greedy best-fit downgrade selection shared by WS-BFE and the KV
    headroom path: pick the victim whose scavengeable size (loaded −
    smallest) covers the remaining ``shortfall`` with least waste — or
    the largest available when none covers — until the shortfall is met
    or candidates run out."""
    def scavengeable(a: str) -> float:
        t = state.tenants[a]
        return t.loaded.size_mb - t.zoo.smallest.size_mb

    remaining = list(cands)
    evictions: List[Eviction] = []
    while (need := shortfall(evictions)) > 0 and remaining:
        covering = [a for a in remaining if scavengeable(a) >= need]
        pick = (min(covering, key=scavengeable) if covering
                else max(remaining, key=scavengeable))
        remaining.remove(pick)
        t = state.tenants[pick]
        evictions.append(Eviction(pick, t.loaded, t.zoo.smallest))
    return evictions


# ---------------------------------------------------------------------------
# Policy protocol + registry
# ---------------------------------------------------------------------------
class Policy:
    """Class-based policy protocol: the manager (and any host runtime)
    talks to policies exclusively through these four hooks plus the
    headroom planner.  All hooks are pure over the passed state — a
    policy never enacts; the manager does.

    * :meth:`victim_filter` — which tenants this policy may evict or
      downgrade for ``app``'s need (the per-policy candidate rule).
    * :meth:`plan_procure` — the paper's procurement: choose a variant
      for ``app`` plus the evictions that fund it.
    * :meth:`plan_prefetch` — speculative (predictor-driven) plan for a
      background load; the default is eviction-free surplus-only, since
      speculation must never destabilize residents.
    * :meth:`plan_demand` — plan a cold tenant's load with its queued
      batch's cache need staged as a planning charge (via
      :class:`DemandContext`); the default charges the head batch.
    * :meth:`plan_headroom` — scavenge weight memory for a cache that no
      longer fits beside the resident weights.

    Subclasses registered with :func:`register_policy` resolve by name
    through :func:`resolve_policy`; instances are stateless, so one
    instance may serve any number of managers.
    """

    name: ClassVar[str] = "?"

    # -- hooks -----------------------------------------------------------
    def victim_filter(self, state: MemoryState, app: str, now: float, *,
                      delta: float, history: float) -> List[str]:
        raise NotImplementedError

    def plan_procure(self, state: MemoryState, app: str, now: float, *,
                     delta: float, history: float) -> ProcurePlan:
        raise NotImplementedError

    def plan_prefetch(self, state: MemoryState, app: str, now: float, *,
                      delta: float, history: float
                      ) -> Optional[ProcurePlan]:
        """Eviction-free proactive plan for the background loader: the
        largest variant whose *marginal* footprint fits in surplus
        memory.  A prefetch is speculation — it must never destabilize
        residents or out-claim real work, so the default refuses plans
        that need evictions (under pressure the demand path, which can
        reclaim a cancelled prefetch's memory, takes over)."""
        t = state.tenants[app]
        if t.loaded is t.zoo.largest or t.inflight_mb > 0.0:
            return None
        cur = t.loaded.size_mb if t.loaded else 0.0
        for v in t.zoo.variants:  # largest first
            if t.loaded is not None and v.size_mb <= cur:
                break  # downgrades are admission-time decisions
            if v.size_mb - cur <= state.free_mb:
                return ProcurePlan(app, v, ())
        return None

    def demand_charge(self, demand: DemandContext) -> float:
        """How much cache need a demand load plans around.  The base
        protocol charges the head batch as it is queued right now."""
        return demand.kv_head_mb

    def plan_demand(self, state: MemoryState, app: str, now: float,
                    demand: DemandContext, *, delta: float,
                    history: float) -> Optional[ProcurePlan]:
        """Plan a load for a *cold* tenant with requests already queued.
        The cache need is staged as a transient planning charge so the
        chosen variant leaves room for it up front (one weight transfer,
        no load-then-downgrade thrash at admission).  Returns None when
        no variant is fundable; the manager's fallback takes over."""
        with state.pending(self.demand_charge(demand)):
            plan = self.plan_procure(state, app, now, delta=delta,
                                     history=history)
        return plan if plan.ok else None

    def plan_headroom(self, state: MemoryState, app: str, now: float,
                      need_mb: float, *, delta: float,
                      history: float) -> Tuple[Eviction, ...]:
        return kv_headroom_plan(state, app, now, need_mb, delta=delta,
                                history=history)


PolicyLike = Union[str, Policy, type]

_REGISTRY: Dict[str, Callable[[], Policy]] = {}


def register_policy(name: str) -> Callable:
    """Register a :class:`Policy` factory (usually the class itself) under
    ``name`` so configs can resolve it declaratively."""
    def deco(factory):
        if isinstance(factory, type):
            factory.name = name
        _REGISTRY[name] = factory
        return factory
    return deco


def available_policies() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def resolve_policy(spec: PolicyLike) -> Policy:
    """Resolve a registry name, a Policy class, or a ready instance to a
    Policy instance.  Unknown names fail loudly with the available set."""
    if isinstance(spec, Policy):
        return spec
    if isinstance(spec, type) and issubclass(spec, Policy):
        return spec()
    if isinstance(spec, str):
        if spec not in _REGISTRY:
            raise KeyError(
                f"unknown policy {spec!r}; registered policies: "
                f"{', '.join(available_policies())}")
        return _REGISTRY[spec]()
    raise TypeError(f"cannot resolve a Policy from {spec!r}")


# ---------------------------------------------------------------------------
# Policy 1: Largest-First Eviction
# ---------------------------------------------------------------------------
@register_policy("lfe")
class LFE(Policy):
    def victim_filter(self, state: MemoryState, app: str, now: float, *,
                      delta: float, history: float) -> List[str]:
        victims = [a for a in state.minimalist_set(now, delta)
                   if a != app and state.tenants[a].loaded is not None
                   and state.tenants[a].inflight_mb == 0.0]
        victims.sort(key=lambda a: -state.tenants[a].loaded.size_mb)
        return victims

    def plan_procure(self, state: MemoryState, app: str, now: float, *,
                     delta: float, history: float) -> ProcurePlan:
        victims = self.victim_filter(state, app, now, delta=delta,
                                     history=history)
        for variant in state.tenants[app].zoo.variants:
            evictions: List[Eviction] = []
            for v in victims:
                if _free_after(state, app, evictions) >= variant.size_mb:
                    break
                evictions.append(Eviction(v, state.tenants[v].loaded, None))
            if _free_after(state, app, evictions) >= variant.size_mb:
                return ProcurePlan(app, variant, tuple(evictions))
        return ProcurePlan(app, None)


# ---------------------------------------------------------------------------
# Policy 2: Best-Fit Eviction
# ---------------------------------------------------------------------------
@register_policy("bfe")
class BFE(Policy):
    def victim_filter(self, state: MemoryState, app: str, now: float, *,
                      delta: float, history: float) -> List[str]:
        return [a for a in state.minimalist_set(now, delta)
                if a != app and state.tenants[a].loaded is not None
                and state.tenants[a].inflight_mb == 0.0]

    @staticmethod
    def _variant_plan(state: MemoryState, app: str,
                      variant: ModelVariant,
                      victims: List[str]) -> Optional[ProcurePlan]:
        """Best-fit eviction set funding one candidate variant: evict the
        victim whose loaded size is closest from above to the remaining
        need (largest-below when none covers), or None when even the
        whole victim pool cannot fund it."""
        evictions: List[Eviction] = []
        remaining = list(victims)
        while (_free_after(state, app, evictions) < variant.size_mb
               and remaining):
            need = variant.size_mb - _free_after(state, app, evictions)
            covering = [a for a in remaining
                        if state.tenants[a].loaded.size_mb >= need]
            if covering:
                pick = min(covering,
                           key=lambda a: state.tenants[a].loaded.size_mb)
            else:
                pick = max(remaining,
                           key=lambda a: state.tenants[a].loaded.size_mb)
            remaining.remove(pick)
            evictions.append(
                Eviction(pick, state.tenants[pick].loaded, None))
        if _free_after(state, app, evictions) >= variant.size_mb:
            return ProcurePlan(app, variant, tuple(evictions))
        return None

    def plan_procure(self, state: MemoryState, app: str, now: float, *,
                     delta: float, history: float) -> ProcurePlan:
        victims = self.victim_filter(state, app, now, delta=delta,
                                     history=history)
        for variant in state.tenants[app].zoo.variants:
            plan = self._variant_plan(state, app, variant, victims)
            if plan is not None:
                return plan
        return ProcurePlan(app, None)


# ---------------------------------------------------------------------------
# Policy 3: Warm-Start-aware Best-Fit Eviction
# ---------------------------------------------------------------------------
@register_policy("ws-bfe")
class WSBFE(Policy):
    def victim_filter(self, state: MemoryState, app: str, now: float, *,
                      delta: float, history: float) -> List[str]:
        # Window-overlap exemption only: WS-BFE has no LRU-K filter.
        return _downgrade_candidates(state, app, now, delta)

    def plan_procure(self, state: MemoryState, app: str, now: float, *,
                     delta: float, history: float) -> ProcurePlan:
        cands = self.victim_filter(state, app, now, delta=delta,
                                   history=history)
        for variant in state.tenants[app].zoo.variants:
            evictions = _scavenge_best_fit(
                state, cands,
                lambda evs: variant.size_mb - _free_after(state, app, evs))
            if _free_after(state, app, evictions) >= variant.size_mb:
                return ProcurePlan(app, variant, tuple(evictions))
            # §III-B-1 "high inference demand" fallback: fully unload the
            # already-downgraded victims (this is what separates WS-BFE
            # from iWS-BFE, which per Algorithm 1 only ever *replaces* —
            # WS-BFE's unloads are the cold-starts Fig 5 charges it with).
            evictions = [Eviction(e.app, e.old, None) for e in evictions]
            if _free_after(state, app, evictions) >= variant.size_mb:
                return ProcurePlan(app, variant, tuple(evictions))
        return ProcurePlan(app, None)


# ---------------------------------------------------------------------------
# Policy 4: Intelligent Warm-Start-aware Best-Fit Eviction (Algorithm 1)
# ---------------------------------------------------------------------------
@register_policy("iws-bfe")
class IWSBFE(Policy):
    def victim_filter(self, state: MemoryState, app: str, now: float, *,
                      delta: float, history: float) -> List[str]:
        # Steps 2–3: τ = A′ not requested during H; E = τ non-overlapping
        # with the requester's window.
        return _downgrade_candidates(state, app, now, delta,
                                     require_history=history)

    def plan_procure(self, state: MemoryState, app: str, now: float, *,
                     delta: float, history: float) -> ProcurePlan:
        cands = self.victim_filter(state, app, now, delta=delta,
                                   history=history)
        if cands:
            # Step 4: fitness score (Eq. 3).
            dists = {}
            for a in cands:
                tj = state.tenants[a].predicted_next
                dists[a] = (tj - now) if tj is not INF else INF
            finite = [d for d in dists.values() if d is not INF and d > 0]
            dmax = max(finite) if finite else 1.0
            scores = {}
            for a in cands:
                d = dists[a]
                norm = 1.0 if d is INF else max(d, 0.0) / max(dmax, 1e-9)
                scores[a] = norm * (1.0 - state.p_unexpected(a))
            # Step 5: max-heap on fitness.
            heap = [(-scores[a], a) for a in cands]
            heapq.heapify(heap)
        else:
            heap = []

        for variant in state.tenants[app].zoo.variants:
            evictions: List[Eviction] = []
            h = list(heap)  # fresh heap per variant attempt (Steps 6–18)
            while _free_after(state, app, evictions) < variant.size_mb and h:
                _, w = heapq.heappop(h)  # Step 7: extract max-fitness root
                t = state.tenants[w]
                # Step 9: scavenge by replacing with the lowest-precision
                # model.
                evictions.append(Eviction(w, t.loaded, t.zoo.smallest))
            if _free_after(state, app, evictions) >= variant.size_mb:
                # Steps 12–14: enact replacements, load m_i.
                return ProcurePlan(app, variant, tuple(evictions))
            # Step 17–18: retry with next smaller model.
        return ProcurePlan(app, None)  # Step 17: inference request fails


# ---------------------------------------------------------------------------
# Plugin: batch-aware procurement (wraps any registered policy)
# ---------------------------------------------------------------------------
class BatchAware(Policy):
    """Batch-aware demand procurement: plan a cold tenant's load for the
    batch the queue will produce *at admission time*, not the head-batch
    snapshot at stage time.

    Under a burst, requests keep arriving while the weight transfer
    stages; head-batch planning sizes the variant beside the cache of
    whatever was queued when staging began, and the (now larger) batch
    that actually admits forces a self-downgrade right after the load
    commits — the exact load-then-downgrade thrash KV-aware procurement
    exists to avoid, reintroduced by queue dynamics.  Planning against
    ``DemandContext.kv_full_mb`` (a full ``max_batch``-wide batch at the
    queued shapes) picks the smaller variant up front: one transfer, no
    wasted large-variant load.

    Every other hook delegates to the wrapped policy, so this composes
    with any registered eviction strategy (``batch-bfe``,
    ``batch-iws-bfe``, or ``BatchAware(MyPolicy())``).
    """

    def __init__(self, inner: PolicyLike = "bfe"):
        self.inner = resolve_policy(inner)
        self.name = f"batch-{self.inner.name}"

    def victim_filter(self, state, app, now, *, delta, history):
        return self.inner.victim_filter(state, app, now, delta=delta,
                                        history=history)

    def plan_procure(self, state, app, now, *, delta, history):
        return self.inner.plan_procure(state, app, now, delta=delta,
                                       history=history)

    def plan_prefetch(self, state, app, now, *, delta, history):
        return self.inner.plan_prefetch(state, app, now, delta=delta,
                                        history=history)

    def plan_headroom(self, state, app, now, need_mb, *, delta, history):
        return self.inner.plan_headroom(state, app, now, need_mb,
                                        delta=delta, history=history)

    def demand_charge(self, demand: DemandContext) -> float:
        return max(demand.kv_head_mb, demand.kv_full_mb)


@register_policy("batch-bfe")
def _batch_bfe() -> Policy:
    return BatchAware("bfe")


@register_policy("batch-iws-bfe")
def _batch_iws_bfe() -> Policy:
    return BatchAware("iws-bfe")


# ---------------------------------------------------------------------------
# Plugin: cost-aware procurement over simulated plan candidates
# ---------------------------------------------------------------------------
@register_policy("cost-bfe")
class CostBFE(BFE):
    """Cost-aware BFE: rank candidate plans by what the variant is
    *worth by the time it is ready*, not just by size.

    BFE always procures the largest fundable variant — even when the
    requester's next predicted request lands mid-transfer, so the big
    load cannot finish in time and a smaller variant would have served
    warmer for free.  This plugin enumerates one candidate plan per zoo
    variant (the same best-fit eviction machinery), validates each with
    ``MemoryState.simulate`` — plans are cheap, frozen data — and scores

        score(v) = accuracy(v) · min(1, idle_ms / load_ms(v))

    where ``idle_ms`` is the gap to the tenant's next predicted request
    (∞ when unpredicted, which makes the score pure accuracy and the
    choice identical to BFE).  The highest-scoring feasible plan wins;
    ties keep the larger variant.  First post-IR payoff: a policy is
    now a pure plan-emitting function ranked by simulate, no enactment
    logic anywhere."""

    def plan_procure(self, state: MemoryState, app: str, now: float, *,
                     delta: float, history: float) -> ProcurePlan:
        victims = self.victim_filter(state, app, now, delta=delta,
                                     history=history)
        t = state.tenants[app]
        pred = t.predicted_next
        idle = INF if pred is INF else (pred - now)
        best: Optional[ProcurePlan] = None
        best_score = -INF
        for variant in t.zoo.variants:  # largest first
            plan = self._variant_plan(state, app, variant, victims)
            if plan is None:
                continue
            rplan = A.ResidencyPlan(
                A.eviction_actions(plan.evictions)
                + (A.staged_load_action(state, app, variant),))
            if state.simulate(rplan) is not None:
                # Not actually fundable as a transfer — e.g. a shard
                # over its chip's budget, which the device-blind
                # eviction math above cannot see.
                continue
            score = variant_score(variant, idle)
            if score > best_score + 1e-12:
                best, best_score = plan, score
        return best if best is not None else ProcurePlan(app, None)


# ---------------------------------------------------------------------------
# KV-cache headroom (serving runtime): scavenge weight memory for caches
# ---------------------------------------------------------------------------
def kv_headroom_plan(state: MemoryState, app: str, now: float,
                     need_mb: float, *, delta: float,
                     history: float = 0.0) -> Tuple[Eviction, ...]:
    """Free ≥ ``need_mb`` of headroom for ``app``'s KV cache by downgrading
    minimalist victims to their smallest variant (same candidate filters as
    iWS-BFE: window-overlap and LRU-K history exempt), best-fit first.

    If downgrades alone cannot cover the need, victims are *unloaded*
    outright — the same "high inference demand" fallback WS-BFE applies
    to weight pressure (§III-B-1), extended to cache pressure: a decode
    cache that cannot fit is a failed inference, which the paper weighs
    strictly worse than a future cold start.  Already-downgraded victims
    go first (their remaining footprint is minimal), then other
    minimalist tenants sitting at their smallest variant, best-fit.

    Unlike the procure policies this never touches the requester's own
    variant — the caller decides whether to self-downgrade if scavenging
    victims is not enough.  The returned evictions may be insufficient;
    the caller re-checks ``free_mb`` after enacting.
    """
    def short(evs: List[Eviction]) -> float:
        return need_mb - state.free_mb - sum(e.freed_mb for e in evs)

    cands = _downgrade_candidates(state, app, now, delta,
                                  require_history=history)
    evictions = list(_scavenge_best_fit(state, cands, short))
    if short(evictions) <= 0:
        return tuple(evictions)
    # Cache-pressure fallback: downgrades were not enough — unload.
    evictions = [Eviction(e.app, e.old, None) for e in evictions]
    taken = {e.app for e in evictions}
    pool = [a for a in _downgrade_candidates(state, app, now, delta,
                                             require_history=history,
                                             include_smallest=True)
            if a not in taken]
    while (need := short(evictions)) > 0 and pool:
        def loaded_mb(a: str) -> float:
            return state.tenants[a].loaded.size_mb
        covering = [a for a in pool if loaded_mb(a) >= need]
        pick = (min(covering, key=loaded_mb) if covering
                else max(pool, key=loaded_mb))
        pool.remove(pick)
        evictions.append(Eviction(pick, state.tenants[pick].loaded, None))
    return tuple(evictions)


def kv_desperation_plan(state: MemoryState, app: str,
                        need_mb: float) -> Tuple[Eviction, ...]:
    """Last resort before rejecting a batch for cache pressure: ignore
    the window-overlap and LRU-K protections and scavenge every other
    tenant — downgrades first (cheapest robustness loss, biggest
    scavengeable first), then outright unloads.  A failed inference
    outranks every warm-start heuristic in the paper's cost model, and
    without this pass a predicting engine is *more* rejection-prone than
    a reactive one (predictions create windows, windows protect victims).
    Tenants mid-staging stay exempt — the loader owns their residency.
    """
    def short(evs: List[Eviction]) -> float:
        return need_mb - state.free_mb - sum(e.freed_mb for e in evs)

    cands = [a for a, t in state.tenants.items()
             if a != app and t.loaded is not None and t.inflight_mb == 0.0]

    def scavengeable(a: str) -> float:
        t = state.tenants[a]
        return t.loaded.size_mb - t.zoo.smallest.size_mb

    evictions: List[Eviction] = []
    for a in sorted(cands, key=scavengeable, reverse=True):
        if short(evictions) <= 0:
            break
        t = state.tenants[a]
        if t.loaded is not t.zoo.smallest:
            evictions.append(Eviction(a, t.loaded, t.zoo.smallest))
    if short(evictions) > 0:
        taken = {e.app for e in evictions}
        evictions = [Eviction(e.app, e.old, None) for e in evictions]
        rest = [a for a in cands if a not in taken]
        for a in sorted(rest, key=lambda a: state.tenants[a].loaded.size_mb,
                        reverse=True):
            if short(evictions) <= 0:
                break
            evictions.append(
                Eviction(a, state.tenants[a].loaded, None))
    return tuple(evictions)


def kv_page_victim_plan(state: MemoryState, app: str, *,
                        need_mb: float, need_pages: int,
                        extra_free_mb: float = 0.0
                        ) -> Tuple["A.EvictKV", ...]:
    """Cold-KV-pages as a victim class: free *other* tenants' sequences'
    pages until ``app``'s charge is fundable — both in MB (the global
    budget) and in pages (the pool's free lists).  Victims are whole
    sequences, youngest allocation first: the sequence with the least
    decode progress loses the least work when the engine requeues it.

    ``extra_free_mb`` is headroom the caller's *same plan* will free
    before these evictions apply (weight downgrades/unloads), so the two
    victim classes compose into one atomic
    :class:`~repro.core.actions.ResidencyPlan`.  Returns ``()`` when the
    pool cannot cover the shortfall — preempting sequences that still
    would not admit the requester is pure thrash.
    """
    pool = state.kv_pool
    if pool is None:
        return ()
    acts: List[A.EvictKV] = []
    freed_pages = 0

    def covered() -> bool:
        free_mb = (state.free_mb + extra_free_mb
                   + freed_pages * pool.page_mb)
        free_pages = pool.free_pages + freed_pages
        return free_mb >= need_mb - 1e-9 and free_pages >= need_pages

    for vapp, seq, pages in pool.victim_seqs(exclude=app):
        if covered():
            break
        acts.append(A.EvictKV(vapp, pages * pool.page_mb, seq=seq))
        freed_pages += pages
    if not covered():
        return ()
    return tuple(acts)


# ---------------------------------------------------------------------------
# Composable fallback: what backstops a policy when its plan is unfundable
# ---------------------------------------------------------------------------
class FallbackPolicy:
    """Protocol for the manager's last-resort eviction source: when the
    configured :class:`Policy` cannot fund a plan (weights or cache), the
    manager asks the fallback for evictions and enacts them.  ``None``
    disables the backstop entirely — failures then surface as counted
    rejections, the pure paper behaviour."""

    name: ClassVar[str] = "?"

    def plan(self, state: MemoryState, app: str,
             need_mb: float) -> Tuple[Eviction, ...]:
        raise NotImplementedError


class DesperationFallback(FallbackPolicy):
    """The serving runtime's default backstop (previously a manager
    special case): window/history protections yield before an inference
    fails — see :func:`kv_desperation_plan` for the full rationale."""

    name = "desperation"

    def plan(self, state: MemoryState, app: str,
             need_mb: float) -> Tuple[Eviction, ...]:
        return kv_desperation_plan(state, app, need_mb)


def resolve_fallback(spec: Union[str, FallbackPolicy, None]
                     ) -> Optional[FallbackPolicy]:
    if spec is None or isinstance(spec, FallbackPolicy):
        return spec
    if spec == "desperation":
        return DesperationFallback()
    if spec == "none":
        return None
    raise KeyError(f"unknown fallback policy {spec!r}; "
                   f"expected 'desperation', 'none', or a FallbackPolicy")
