"""Memory tier state (§III-A "Memory Tier"): tracks loaded variants, live
KV-cache charges, free space, and per-tenant request/prediction bookkeeping.

``used_mb`` counts weights *and* per-tenant KV caches: admission and
eviction decisions see runtime memory, not just model residency, so a
tenant mid-decode cannot be silently overcommitted by a procurement.

This is deliberately a plain-Python, side-effect-free data layer so the
eviction policies are pure functions over it — which is what lets the
hypothesis property tests drive millions of random schedules through the
invariant "Σ loaded sizes ≤ budget, always".

Mutations go through the residency-action IR: callers build a
:class:`~repro.core.actions.ResidencyPlan` and hand it to
:meth:`MemoryState.simulate` (validate without mutating) or
:meth:`MemoryState.apply` (commit all-or-nothing).  The per-primitive
methods (``load`` / ``reserve_kv`` / ``reserve_inflight`` / …) remain
public for tests and as the applier's internals, but ``apply`` is the
only entry point the framework itself uses.
"""
from __future__ import annotations

import contextlib
import math
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core import actions as A
from repro.core.model_zoo import ModelVariant, ModelZoo

INF = math.inf


class KVPagePool:
    """Fixed-size KV pages with per-tenant, per-sequence page tables.

    The pool makes the KV cache a first-class paged resource: a sequence
    charges ``ceil(need / page_mb)`` pages at admission and frees exactly
    those pages at retirement, so the accounting unit is the request, not
    the batch, and a release can never drift from its charge.  Page ids
    are partitioned across devices (``device_pages[d]`` pages own a
    contiguous id range), so on a mesh an allocation validates per-chip
    page capacity the same way weight shards validate per-chip budgets —
    through :meth:`MemoryState.simulate` / :meth:`MemoryState.apply`,
    which snapshot and restore the pool alongside the ledger.

    Allocation is deterministic: pages come from the device with the most
    free pages (ties to the lowest device), lowest free id first, so two
    identical schedules produce identical page tables.
    """

    def __init__(self, page_mb: float, n_pages: Optional[int] = None, *,
                 device_pages: Optional[Tuple[int, ...]] = None):
        if page_mb <= 0:
            raise ValueError(f"bad page size: {page_mb}MB")
        if device_pages is None:
            if n_pages is None or n_pages <= 0:
                raise ValueError(f"bad page count: {n_pages}")
            device_pages = (int(n_pages),)
        if any(p < 0 for p in device_pages):
            raise ValueError(f"bad device page counts: {device_pages}")
        self.page_mb = float(page_mb)
        self.device_pages = tuple(int(p) for p in device_pages)
        self.n_devices = len(self.device_pages)
        starts, off = [], 0
        for p in self.device_pages:
            starts.append(off)
            off += p
        self._starts = tuple(starts)
        # Sorted free-page ids per device (ascending: lowest id first).
        self.free: List[List[int]] = [
            list(range(s, s + p))
            for s, p in zip(self._starts, self.device_pages)]
        # app -> seq (request id) -> allocated page ids.
        self.tables: Dict[str, Dict[int, Tuple[int, ...]]] = {}
        # Monotone allocation stamps: victim selection preempts the
        # youngest sequence first (least decode progress lost).
        self._stamp = 0
        self._stamps: Dict[Tuple[str, int], int] = {}
        # Free pages of offline devices (chip loss): stashed out of the
        # allocatable lists until the device is restored, so a page
        # freed on a dead chip never funds a new allocation there.
        self._offline_free: Dict[int, List[int]] = {}

    # -- queries ---------------------------------------------------------
    @property
    def n_pages(self) -> int:
        return sum(self.device_pages)

    @property
    def free_pages(self) -> int:
        return sum(len(f) for f in self.free)

    @property
    def used_pages(self) -> int:
        return self.n_pages - self.free_pages

    def pages_for(self, mb: float) -> int:
        """Pages needed to hold ``mb`` (page-rounded, never zero for a
        positive need)."""
        if mb <= 0:
            return 0
        return max(1, int(math.ceil(mb / self.page_mb - 1e-9)))

    def device_of(self, pid: int) -> int:
        for d in range(self.n_devices - 1, -1, -1):
            if pid >= self._starts[d]:
                return d
        raise ValueError(f"bad page id {pid}")

    def held_pages(self, app: str) -> int:
        return sum(len(p) for p in self.tables.get(app, {}).values())

    def seq_pages(self, app: str, seq: int) -> Tuple[int, ...]:
        return self.tables.get(app, {}).get(seq, ())

    def seqs_on_device(self, device: int) -> List[Tuple[str, int]]:
        """Sequences holding at least one page on ``device`` (sorted for
        determinism) — the chip-loss drain planner's eviction set."""
        out = []
        for app in sorted(self.tables):
            for seq in sorted(self.tables[app]):
                if any(self.device_of(p) == device
                       for p in self.tables[app][seq]):
                    out.append((app, seq))
        return out

    def victim_seqs(self, exclude: str = "") -> List[Tuple[str, int, int]]:
        """Preemption candidates ``(app, seq, n_pages)``, youngest
        allocation first, excluding the requester's own sequences."""
        out = [(stamp, app, seq)
               for (app, seq), stamp in self._stamps.items()
               if app != exclude]
        out.sort(reverse=True)
        return [(app, seq, len(self.tables[app][seq]))
                for _, app, seq in out]

    # -- mutations -------------------------------------------------------
    def allocate(self, app: str, seq: int, n: int) -> Tuple[int, ...]:
        """Allocate ``n`` pages for ``(app, seq)``; raises
        :class:`~repro.core.actions.PlanError` when the pool cannot fund
        them (a full pool is a planning decision, like a full chip)."""
        if n <= 0:
            raise A.PlanError(f"bad page allocation for {app}/{seq}: {n}")
        if seq in self.tables.get(app, {}):
            raise A.PlanError(f"sequence {app}/{seq} already holds pages")
        if self.free_pages < n:
            raise A.PlanError(
                f"KV pool exhausted: {app}/{seq} needs {n} pages, "
                f"{self.free_pages} free of {self.n_pages}")
        got: List[int] = []
        for _ in range(n):
            d = max(range(self.n_devices), key=lambda i: len(self.free[i]))
            got.append(self.free[d].pop(0))
        self.tables.setdefault(app, {})[seq] = tuple(got)
        self._stamps[(app, seq)] = self._stamp
        self._stamp += 1
        return tuple(got)

    def release(self, app: str, seq: int) -> int:
        """Free a sequence's pages; returns the page count (0 when the
        pool holds nothing for it — the caller accounts the drift)."""
        pages = self.tables.get(app, {}).pop(seq, ())
        if not self.tables.get(app):
            self.tables.pop(app, None)
        self._stamps.pop((app, seq), None)
        for pid in pages:
            d = self.device_of(pid)
            dest = (self._offline_free[d] if d in self._offline_free
                    else self.free[d])
            dest.append(pid)
            dest.sort()
        return len(pages)

    def release_app(self, app: str) -> int:
        """Crash-release every sequence a tenant holds (a failed batch
        must not leak pages)."""
        total = 0
        for seq in tuple(self.tables.get(app, {})):
            total += self.release(app, seq)
        return total

    # -- elastic mesh ----------------------------------------------------
    def offline_device(self, device: int) -> None:
        """Chip loss: pull the device's free pages out of the allocatable
        lists.  Pages still *held* on the chip stay in their tables — the
        drain planner evicts those sequences, and :meth:`release` routes
        their pages into the offline stash instead of back into play."""
        if device in self._offline_free:
            return
        self._offline_free[device] = sorted(self.free[device])
        self.free[device] = []

    def restore_device(self, device: int) -> None:
        """Chip recovery: the stashed pages become allocatable again."""
        stash = self._offline_free.pop(device, None)
        if stash is None:
            return
        self.free[device] = sorted(self.free[device] + stash)

    def check_invariant(self) -> None:
        held = sum(self.held_pages(a) for a in self.tables)
        offline = sum(len(f) for f in self._offline_free.values())
        if held + self.free_pages + offline != self.n_pages:
            raise AssertionError(
                f"page conservation violated: {held} held + "
                f"{self.free_pages} free + {offline} offline "
                f"!= {self.n_pages} total")

    # -- transactional support ------------------------------------------
    def _snapshot(self) -> Tuple[Any, ...]:
        return ([list(f) for f in self.free],
                {a: dict(t) for a, t in self.tables.items()},
                self._stamp, dict(self._stamps),
                {d: list(f) for d, f in self._offline_free.items()})

    def _restore(self, snap: Tuple[Any, ...]) -> None:
        free, tables, stamp, stamps, offline = snap
        self.free = [list(f) for f in free]
        self.tables = {a: dict(t) for a, t in tables.items()}
        self._stamp = stamp
        self._stamps = dict(stamps)
        self._offline_free = {d: list(f) for d, f in offline.items()}


class DeviceLedger:
    """Per-device memory accounting for a sharded (multi-chip) mesh.

    The global ``MemoryState`` budget answers "does it fit on the box";
    this ledger answers "does every *shard* fit on its chip" — tensor
    parallelism replicates some leaves (norms, odd-width projections), so
    a tenant's per-device footprint is ``split_fn(app, variant)[d]``, not
    ``size_mb / n``.  The sharded loader checks :meth:`fits` before
    claiming, charges whole-load claims up front, and releases them
    shard-by-shard on cancel; committed weights are re-derived from the
    loaded variant on every :meth:`on_load` so evictions and downgrades
    enacted by *any* caller (policies, desperation, admission) stay in
    sync without those callers knowing devices exist.

    Per-device budgets bound weights + in-flight claims; KV caches are a
    global charge against the ``MemoryState`` budget, with per-chip page
    *placement* tracked by the :class:`KVPagePool` when one is installed
    (the pool partitions its page ids across devices, so page-granular
    ``ChargeKV`` validates per-chip capacity like a shard claim).
    """

    def __init__(self, budgets_mb: Tuple[float, ...],
                 split_fn: Callable[[str, ModelVariant],
                                    Tuple[float, ...]]):
        if not budgets_mb or any(b < 0 for b in budgets_mb):
            raise ValueError(f"bad device budgets: {budgets_mb}")
        self.budgets_mb = tuple(float(b) for b in budgets_mb)
        self.split_fn = split_fn
        self.n_devices = len(self.budgets_mb)
        # Committed weight shards per app (re-derived on every load).
        self.weights: Dict[str, Tuple[float, ...]] = {}
        # In-flight claims per app per device (sharded loads mid-staging).
        self.inflight: Dict[str, List[float]] = {}
        # Shards moved between chips by MigrateShard actions (stats).
        self.shards_migrated = 0
        # Original budgets of offline chips (chip loss): budget drops to
        # zero while the chip is down, restored verbatim on recovery.
        self._offline: Dict[int, float] = {}

    # -- queries ---------------------------------------------------------
    def split(self, app: str, variant: Optional[ModelVariant]
              ) -> Tuple[float, ...]:
        if variant is None:
            return (0.0,) * self.n_devices
        shards = tuple(self.split_fn(app, variant))
        if len(shards) != self.n_devices:
            raise ValueError(
                f"split_fn returned {len(shards)} shards for "
                f"{self.n_devices} devices")
        return shards

    def used_mb(self, device: int) -> float:
        return (sum(w[device] for w in self.weights.values())
                + sum(c[device] for c in self.inflight.values()))

    def device_used(self) -> Tuple[float, ...]:
        """Weights + in-flight claims per device (the invariant's LHS)."""
        return tuple(self.used_mb(d) for d in range(self.n_devices))

    def free_mb(self, device: int) -> float:
        return self.budgets_mb[device] - self.used_mb(device)

    def fits(self, claims: Tuple[float, ...]) -> bool:
        """Would charging ``claims[d]`` on each device stay in budget?
        One overfull shard fails the whole load — cleanly, before any
        claim lands."""
        return all(self.free_mb(d) >= claims[d] - 1e-9
                   for d in range(self.n_devices))

    def held(self, app: str, variant: Optional[ModelVariant] = None
             ) -> Tuple[float, ...]:
        """Actual per-device holdings — the migrated layout when one
        exists; falls back to ``variant``'s canonical split when the
        ledger has not seen a load for ``app`` yet."""
        cur = self.weights.get(app)
        if cur is not None:
            return tuple(cur)
        return self.split(app, variant)

    def projected(self, app: str, variant: Optional[ModelVariant]
                  ) -> Tuple[float, ...]:
        """Per-device holdings after swapping ``app``'s weights to
        ``variant``: the *current* (possibly migrated) layout scaled to
        the new total — a migrated victim keeps its layout, so the chip
        it vacated stays vacated through downgrades and upgrades, and a
        per-chip budget that held keeps holding.  Canonical split when
        nothing is held (a cold load re-derives the canonical layout).
        For never-migrated tenants the current layout *is* canonical,
        so this is exactly the old re-derivation."""
        if variant is None:
            return (0.0,) * self.n_devices
        canonical = self.split(app, variant)
        cur = self.weights.get(app)
        total = sum(cur) if cur else 0.0
        if not cur or total <= 1e-12:
            return canonical
        scale = sum(canonical) / total
        return tuple(w * scale for w in cur)

    def fits_variant(self, app: str, variant: Optional[ModelVariant]
                     ) -> bool:
        """Would swapping ``app``'s committed weights to ``variant`` keep
        every device in budget (admission-path downgrade check)?  The
        projection preserves a migrated layout, so the check validates
        exactly what :meth:`on_load` will commit."""
        if variant is None:
            return True
        cur = self.weights.get(app, (0.0,) * self.n_devices)
        new = self.projected(app, variant)
        return all(self.free_mb(d) + cur[d] >= new[d] - 1e-9
                   for d in range(self.n_devices))

    # -- mutations -------------------------------------------------------
    def on_load(self, app: str, variant: Optional[ModelVariant]) -> None:
        """``MemoryState.load`` observed a (re)load: re-derive the app's
        committed shard footprint — the current layout scaled to the new
        variant (see :meth:`projected`), canonical from cold."""
        if variant is None:
            self.weights.pop(app, None)
        else:
            self.weights[app] = self.projected(app, variant)

    def reserve_inflight(self, app: str, claims: Tuple[float, ...]) -> None:
        """Claim a whole sharded load's per-device footprint at enqueue
        (callers check :meth:`fits` first — an unfundable shard is a
        planning decision, never an assert)."""
        cur = self.inflight.setdefault(app, [0.0] * self.n_devices)
        for d, mb in enumerate(claims):
            if mb < 0:
                raise ValueError(f"negative shard claim: {claims}")
            cur[d] += mb

    def release_inflight_shard(self, app: str, device: int,
                               mb: float) -> None:
        """Return one shard's claim to its device pool (commit converts
        it to weights via :meth:`on_load`; cancel walks shards in device
        order releasing each)."""
        cur = self.inflight.get(app)
        if cur is None:
            return
        cur[device] = max(0.0, cur[device] - mb)
        if all(c <= 1e-12 for c in cur):
            del self.inflight[app]

    def move_shard(self, app: str, src: int, dst: int, mb: float) -> None:
        """Enact one :class:`~repro.core.actions.MigrateShard`: move
        ``mb`` of ``app``'s committed weights from ``src`` to ``dst``.
        The destination must stay in budget — migration is planned, and
        an unfundable move fails the whole plan, never lands partially."""
        cur = list(self.weights.get(app, (0.0,) * self.n_devices))
        if mb < 0 or cur[src] < mb - 1e-9:
            raise A.PlanError(
                f"{app} holds {cur[src]:.2f}MB on device {src}, "
                f"cannot migrate {mb:.2f}MB")
        if self.used_mb(dst) + mb > self.budgets_mb[dst] + 1e-6:
            raise A.PlanError(
                f"device {dst} cannot absorb {mb:.2f}MB of {app} "
                f"({self.used_mb(dst):.2f}/{self.budgets_mb[dst]:.2f}MB)")
        cur[src] -= mb
        cur[dst] += mb
        self.weights[app] = tuple(cur)
        self.shards_migrated += 1

    # -- elastic mesh ----------------------------------------------------
    @property
    def offline_devices(self) -> Tuple[int, ...]:
        return tuple(sorted(self._offline))

    def offline(self, device: int) -> None:
        """Chip loss: the device's budget drops to zero.  Weights and
        claims still homed there are now over budget — the caller (the
        elastic drain planner) owes one plan that vacates them before the
        next :meth:`check_invariant`."""
        if device in self._offline:
            return
        budgets = list(self.budgets_mb)
        self._offline[device] = budgets[device]
        budgets[device] = 0.0
        self.budgets_mb = tuple(budgets)

    def online(self, device: int) -> None:
        """Chip recovery: restore the original budget verbatim."""
        orig = self._offline.pop(device, None)
        if orig is None:
            return
        budgets = list(self.budgets_mb)
        budgets[device] = orig
        self.budgets_mb = tuple(budgets)

    def check_invariant(self) -> None:
        for d in range(self.n_devices):
            if self.used_mb(d) > self.budgets_mb[d] + 1e-6:
                raise AssertionError(
                    f"device {d} over budget: {self.used_mb(d):.2f}MB "
                    f"> {self.budgets_mb[d]:.2f}MB")


@dataclass
class TenantState:
    zoo: ModelZoo
    loaded: Optional[ModelVariant] = None
    kv_mb: float = 0.0  # live KV/decode-cache MB charged to this tenant
    inflight_mb: float = 0.0  # MB claimed by a background load mid-staging
    last_request: float = -INF  # time of most recent actual request
    predicted_next: float = INF  # next predicted request time (INF = none)
    requests: int = 0
    unexpected: int = 0  # requests that arrived outside a predicted window

    def window(self, delta: float, theta: float = 0.0) -> Tuple[float, float]:
        """Predicted request window [t−Δ−θ, t+Δ] (paper Fig. 3)."""
        if self.predicted_next is INF:
            return (INF, INF)
        return (self.predicted_next - delta - theta,
                self.predicted_next + delta)


@dataclass
class MemoryState:
    budget_mb: float
    tenants: Dict[str, TenantState] = field(default_factory=dict)
    # Transient planning charge: an admission-in-flight's KV need.  It is
    # subtracted from free_mb so procure policies pick variants that leave
    # room for the cache, but excluded from used_mb/check_invariant — it
    # is a reservation *request*, not committed memory.
    pending_mb: float = 0.0
    # Per-device shard accounting for a sharded mesh (None = single
    # device).  ``load`` keeps it in sync; the global invariant stays the
    # authority here because admission may transiently overshoot a single
    # chip mid-downgrade — per-device limits are enforced at reservation
    # time (sharded loader) and at admission resolution (manager).
    devices: Optional[DeviceLedger] = None
    # Paged KV accounting (None = scalar KV charges).  When installed,
    # ChargeKV/EvictKV actions carrying a ``seq`` allocate and free
    # fixed-size pages through the pool; the MB charge stays on the
    # tenant so the global invariant is unchanged.
    kv_pool: Optional[KVPagePool] = None
    # Clamped over-release drift (satellite of the paging work): MB that
    # EvictKV/release_kv tried to return beyond what the tenant held.
    # Counted always; raises when ``strict_kv`` is set so accounting
    # drift fails tests instead of vanishing into the clamp.
    kv_overrelease_mb: float = 0.0
    strict_kv: bool = False
    # Audit hook: called as on_audit(kind, app, mb) when drift is
    # clamped (suppressed during simulate, which always rolls back).
    on_audit: Optional[Callable[[str, str, float], None]] = None
    _simulating: bool = field(default=False, repr=False)

    @property
    def weights_mb(self) -> float:
        return sum(t.loaded.size_mb for t in self.tenants.values()
                   if t.loaded is not None)

    @property
    def kv_mb(self) -> float:
        return sum(t.kv_mb for t in self.tenants.values())

    @property
    def inflight_mb(self) -> float:
        """MB claimed by background loads that have not yet committed —
        prefetched weights mid-staging.  Committed memory the instant the
        load lands (``load`` + ``release_inflight``), or returned to the
        pool if the prefetch is cancelled."""
        return sum(t.inflight_mb for t in self.tenants.values())

    @property
    def used_mb(self) -> float:
        """Weights + live KV caches: *runtime* memory, not just weights."""
        return self.weights_mb + self.kv_mb

    @property
    def free_mb(self) -> float:
        return (self.budget_mb - self.used_mb - self.pending_mb
                - self.inflight_mb)

    def loaded_variant(self, app: str) -> Optional[ModelVariant]:
        return self.tenants[app].loaded

    def check_invariant(self) -> None:
        if self.used_mb + self.inflight_mb > self.budget_mb + 1e-6:
            raise AssertionError(
                f"memory invariant violated: {self.used_mb:.1f}MB used "
                f"+ {self.inflight_mb:.1f}MB in-flight "
                f"> {self.budget_mb:.1f}MB budget")
        if self.strict_kv and self.kv_overrelease_mb > 1e-9:
            raise AssertionError(
                f"KV accounting drift: {self.kv_overrelease_mb:.3f}MB "
                f"over-released (strict_kv)")
        if self.kv_pool is not None:
            self.kv_pool.check_invariant()

    # -- mutations (the manager calls these after a policy decision) -------
    def load(self, app: str, variant: Optional[ModelVariant]) -> None:
        self.tenants[app].loaded = variant
        if self.devices is not None:
            self.devices.on_load(app, variant)
        self.check_invariant()

    def reserve_kv(self, app: str, mb: float) -> None:
        """Charge a batch's KV cache to the tenant.  Callers must verify
        ``free_mb >= mb`` first — an over-budget admit is an admission
        decision (downgrade / reject), never an invariant violation."""
        if mb < 0:
            raise ValueError(f"negative KV reservation: {mb}")
        self.tenants[app].kv_mb += mb
        self.check_invariant()

    def release_kv(self, app: str, mb: float) -> None:
        """Return a retired batch's KV memory to the pool.  Over-release
        (more MB than the tenant holds) is clamped but *counted* in
        ``kv_overrelease_mb`` — and raises under ``strict_kv`` — so KV
        accounting drift surfaces instead of silently vanishing."""
        self._drain_kv(app, mb)

    def _drain_kv(self, app: str, mb: float) -> None:
        t = self.tenants[app]
        over = mb - t.kv_mb
        if over > 1e-9:
            self.kv_overrelease_mb += over
            if self.on_audit is not None and not self._simulating:
                self.on_audit("kv_overrelease", app, over)
            if self.strict_kv:
                raise AssertionError(
                    f"KV over-release: {app} returning {mb:.3f}MB while "
                    f"holding {t.kv_mb:.3f}MB ({over:.3f}MB drift)")
        t.kv_mb = max(0.0, t.kv_mb - mb)

    def reserve_inflight(self, app: str, mb: float) -> None:
        """Claim memory for a background load mid-staging.  The charge is
        what the completed load will *add* over the tenant's currently
        loaded variant, so eviction/procurement (which plan against
        ``free_mb``) cannot double-book memory a prefetch already owns.
        Callers must verify ``free_mb >= mb`` first — an unfundable
        prefetch is a planning decision, never an invariant violation."""
        if mb < 0:
            raise ValueError(f"negative in-flight reservation: {mb}")
        self.tenants[app].inflight_mb += mb
        self.check_invariant()

    def release_inflight(self, app: str, mb: float) -> None:
        """A background load committed or was cancelled: return its
        in-flight claim to the pool (commit re-charges it as weights)."""
        t = self.tenants[app]
        t.inflight_mb = max(0.0, t.inflight_mb - mb)

    def in_window(self, app: str, now: float, delta: float,
                  theta: float = 0.0) -> bool:
        lo, hi = self.tenants[app].window(delta, theta)
        return lo <= now <= hi

    def maximalist_set(self, now: float, delta: float) -> Tuple[str, ...]:
        """A*: apps inside their predicted request window."""
        return tuple(a for a in self.tenants
                     if self.in_window(a, now, delta,
                                       self._theta(a)))

    def minimalist_set(self, now: float, delta: float) -> Tuple[str, ...]:
        """A′: apps outside their predicted request window."""
        return tuple(a for a in self.tenants
                     if not self.in_window(a, now, delta, self._theta(a)))

    def _theta(self, app: str) -> float:
        """Load-time overhead θ_i of the app's largest model, in the same
        time units as the simulation (ms)."""
        return self.tenants[app].zoo.largest.load_ms

    def p_unexpected(self, app: str) -> float:
        """Laplace-smoothed P(unexpected request | window) from history."""
        t = self.tenants[app]
        return (t.unexpected + 1.0) / (t.requests + 2.0)

    # ------------------------------------------------------------------
    # The transactional plan applier: the framework's only mutation path
    # ------------------------------------------------------------------
    @contextlib.contextmanager
    def pending(self, mb: float):
        """Scope a transient planning charge: procurement inside the
        block plans around ``mb`` of reserved-but-uncommitted memory
        (a KV need, typically), and the charge always comes back off."""
        self.pending_mb += mb
        try:
            yield self
        finally:
            self.pending_mb -= mb

    def _snapshot(self) -> Tuple[Any, ...]:
        tenants = {a: (t.loaded, t.kv_mb, t.inflight_mb)
                   for a, t in self.tenants.items()}
        dev = None
        if self.devices is not None:
            dev = ({a: tuple(w) for a, w in self.devices.weights.items()},
                   {a: list(c) for a, c in self.devices.inflight.items()},
                   self.devices.shards_migrated,
                   self.devices.budgets_mb,
                   dict(self.devices._offline))
        pool = self.kv_pool._snapshot() if self.kv_pool is not None else None
        return tenants, self.pending_mb, dev, pool, self.kv_overrelease_mb

    def _restore(self, snap: Tuple[Any, ...]) -> None:
        tenants, pending, dev, pool, overrelease = snap
        for a, (loaded, kv, inflight) in tenants.items():
            t = self.tenants[a]
            t.loaded, t.kv_mb, t.inflight_mb = loaded, kv, inflight
        self.pending_mb = pending
        if dev is not None:
            weights, inflight, migrated, budgets, offline = dev
            self.devices.weights = dict(weights)
            self.devices.inflight = {a: list(c) for a, c in inflight.items()}
            self.devices.shards_migrated = migrated
            self.devices.budgets_mb = budgets
            self.devices._offline = dict(offline)
        if pool is not None:
            self.kv_pool._restore(pool)
        self.kv_overrelease_mb = overrelease

    def simulate(self, plan: "A.ResidencyPlan") -> Optional[str]:
        """Validate a plan without mutating: returns None when every
        action is feasible in sequence (budget and per-device ledgers
        included), else the first failure's reason.  ``simulate`` runs
        the *same* per-action code as :meth:`apply` against a snapshot,
        so a plan that simulates clean is guaranteed to apply."""
        snap = self._snapshot()
        self._simulating = True
        try:
            for act in plan:
                self._apply_action(act)
            return None
        except A.PlanError as e:
            return str(e)
        finally:
            self._simulating = False
            self._restore(snap)

    def apply(self, plan: "A.ResidencyPlan") -> "A.ResidencyPlan":
        """Commit a plan all-or-nothing: actions apply in order, each
        re-validated; the first infeasible action rolls back everything
        already applied (claims released, weights restored) and raises
        :class:`~repro.core.actions.PlanError`.  Returns the plan so
        callers can chain into physical staging."""
        snap = self._snapshot()
        try:
            for act in plan:
                self._apply_action(act)
        except A.PlanError:
            self._restore(snap)
            raise
        return plan

    def _apply_action(self, act: "A.Action") -> None:
        if act.app not in self.tenants:
            raise A.PlanError(f"unknown tenant {act.app!r}")
        t = self.tenants[act.app]
        if isinstance(act, A.Load):
            if act.staged:
                load = A.concretize_load(act, self)
                if self.free_mb < load.claim_mb - 1e-9:
                    raise A.PlanError(
                        f"staged load {act.app} needs {load.claim_mb:.2f}MB"
                        f" > {self.free_mb:.2f}MB free")
                if load.shard_claims is not None and self.devices is not None:
                    if not self.devices.fits(load.shard_claims):
                        raise A.PlanError(
                            f"staged load {act.app}: a shard does not fit "
                            f"its chip {load.shard_claims}")
                    self.devices.reserve_inflight(act.app, load.shard_claims)
                t.inflight_mb += load.claim_mb
            else:
                # Commit: the claim converts to weights in one
                # transaction (net zero on free_mb for staged loads).
                if act.claim_mb:
                    t.inflight_mb = max(0.0, t.inflight_mb - act.claim_mb)
                if act.shard_claims is not None and self.devices is not None:
                    for d, mb in enumerate(act.shard_claims):
                        self.devices.release_inflight_shard(act.app, d, mb)
                t.loaded = act.variant
                if self.devices is not None:
                    self.devices.on_load(act.app, act.variant)
                # Global budget only: an admission load may transiently
                # overshoot one chip mid-downgrade (policies are
                # device-blind); per-device limits are enforced at
                # reservation (staged) and at admission resolution.
                try:
                    self.check_invariant()
                except AssertionError as e:
                    raise A.PlanError(str(e)) from None
        elif isinstance(act, A.Downgrade):
            if t.loaded is not None and \
                    act.variant.size_mb > t.loaded.size_mb + 1e-9:
                raise A.PlanError(
                    f"downgrade {act.app} to {act.variant.size_mb:.2f}MB "
                    f"> loaded {t.loaded.size_mb:.2f}MB")
            if act.in_place:
                # In-place requantization derives the target weights
                # from the resident leaves: there must *be* resident
                # leaves, and only a strictly lower-bits sibling is
                # derivable (int8/int4 from wider — never back up).
                if t.loaded is None:
                    raise A.PlanError(
                        f"in-place downgrade {act.app}: nothing resident")
                if act.variant.bits >= t.loaded.bits:
                    raise A.PlanError(
                        f"in-place downgrade {act.app}: {act.variant.bits}"
                        f"-bit target not below resident "
                        f"{t.loaded.bits}-bit")
            t.loaded = act.variant
            if self.devices is not None:
                self.devices.on_load(act.app, act.variant)
        elif isinstance(act, A.Unload):
            t.loaded = None
            if self.devices is not None:
                self.devices.on_load(act.app, None)
        elif isinstance(act, A.Shrink):
            if act.release_mb < 0:
                raise A.PlanError(f"negative shrink release: {act}")
            t.inflight_mb = max(0.0, t.inflight_mb - act.release_mb)
        elif isinstance(act, A.CancelPrefetch):
            t.inflight_mb = max(0.0, t.inflight_mb - act.claim_mb)
            if act.shard_claims is not None and self.devices is not None:
                # Device order, shard by shard: the accounting primitive
                # cross-device migration rides.
                for d, mb in enumerate(act.shard_claims):
                    self.devices.release_inflight_shard(act.app, d, mb)
        elif isinstance(act, A.ChargeKV):
            if act.mb < 0:
                raise A.PlanError(f"negative KV reservation: {act.mb}")
            if self.kv_pool is not None and act.seq is not None:
                # Page-granular: allocate fixed-size pages for the
                # sequence (validated against the pool's free lists, per
                # device) and charge the page-rounded footprint.
                n = (act.pages if act.pages is not None
                     else self.kv_pool.pages_for(act.mb))
                self.kv_pool.allocate(act.app, act.seq, n)
                t.kv_mb += n * self.kv_pool.page_mb
            else:
                t.kv_mb += act.mb
            try:
                self.check_invariant()
            except AssertionError as e:
                raise A.PlanError(str(e)) from None
        elif isinstance(act, A.EvictKV):
            try:
                if self.kv_pool is not None and act.seq is not None:
                    freed = self.kv_pool.release(act.app, act.seq)
                    self._drain_kv(act.app, freed * self.kv_pool.page_mb)
                else:
                    self._drain_kv(act.app, act.mb)
            except AssertionError as e:
                raise A.PlanError(str(e)) from None
        elif isinstance(act, A.MigrateShard):
            if self.devices is None:
                raise A.PlanError("MigrateShard without a DeviceLedger")
            self.devices.move_shard(act.app, act.src, act.dst, act.mb)
        else:
            raise A.PlanError(f"unknown action {act!r}")
