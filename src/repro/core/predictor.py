"""The paper's two lightweight many-to-one vanilla RNN predictors
(§III-A "NN Model Manager"), implemented and trained in pure JAX:

* **Request predictor** — consumes the recent inter-arrival history of one
  application and predicts the next inter-arrival gap (hence the next
  request time).
* **Memory predictor** — consumes the recent sequence of memory-usage
  samples and predicts availability at the next decision point.

Both are the same tiny architecture (the paper calls it "edge-friendly"):
one tanh RNN cell + linear head, trained with AdamW on sliding windows.
No Pallas kernel is warranted here — the model is a few thousand FLOPs.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.training.optim import AdamW


def init_rnn(key: jax.Array, hidden: int = 32) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wx": jax.random.normal(k1, (1, hidden), jnp.float32) * 0.5,
        "wh": jax.random.normal(k2, (hidden, hidden), jnp.float32)
        * (hidden ** -0.5),
        "b": jnp.zeros((hidden,), jnp.float32),
        "wo": jax.random.normal(k3, (hidden, 1), jnp.float32)
        * (hidden ** -0.5),
        "bo": jnp.zeros((1,), jnp.float32),
    }


def rnn_forward(params: dict, xs: jnp.ndarray) -> jnp.ndarray:
    """xs: (B, T) normalized series -> (B,) prediction (many-to-one)."""
    B, T = xs.shape
    h0 = jnp.zeros((B, params["wh"].shape[0]), jnp.float32)

    def cell(h, x):
        h = jnp.tanh(x[:, None] @ params["wx"] + h @ params["wh"]
                     + params["b"])
        return h, ()

    h, _ = jax.lax.scan(cell, h0, jnp.moveaxis(xs, 1, 0))
    return (h @ params["wo"] + params["bo"])[:, 0]


@functools.partial(jax.jit, static_argnames=("steps",))
def _fit(params, opt_state, xs, ys, *, steps: int = 200):
    opt = AdamW(lr=1e-2, weight_decay=0.0, clip_norm=1.0)

    def loss_fn(p):
        pred = rnn_forward(p, xs)
        return jnp.mean((pred - ys) ** 2)

    def step(carry, _):
        p, s = carry
        loss, g = jax.value_and_grad(loss_fn)(p)
        p, s, _ = opt.update(g, s, p)
        return (p, s), loss

    (params, opt_state), losses = jax.lax.scan(
        step, (params, opt_state), None, length=steps)
    return params, opt_state, losses


@dataclass
class SeriesPredictor:
    """Sliding-window RNN regressor over a scalar series.

    ``min_fit_samples`` / ``refit_interval`` drive the serving runtime's
    *background* training schedule: once the history holds at least
    ``min_fit_samples`` observations, :meth:`fit_due` turns true, and
    again every ``refit_interval`` further observations — the server
    hands due predictors to the loader's staging worker
    (``BackgroundLoader.submit_fit``) so training never blocks the
    serving loop.
    """
    context: int = 16
    hidden: int = 32
    seed: int = 0
    min_fit_samples: int = 24
    refit_interval: int = 16
    fit_steps: int = 150  # AdamW steps per background fit

    def __post_init__(self):
        self.params = init_rnn(jax.random.key(self.seed), self.hidden)
        self.opt_state = AdamW(lr=1e-2, weight_decay=0.0).init(self.params)
        self.mean = 1.0
        self.history: list[float] = []
        self.losses: Optional[np.ndarray] = None
        self.fits = 0  # completed fit() calls
        self._fit_len = 0  # history length at the last completed fit
        # Pre-refactor reference cost model: materialize the whole
        # history per predict() (see predict's comment).
        self.full_history_predict = False

    def observe(self, value: float) -> None:
        self.history.append(float(value))

    def fit_due(self) -> bool:
        """Enough new history to (re)train?  False until
        ``min_fit_samples`` accumulate, then true every
        ``refit_interval`` observations past the previous fit."""
        n = len(self.history)
        if n < max(self.min_fit_samples, self.context + 2):
            return False
        return self._fit_len == 0 or n - self._fit_len >= self.refit_interval

    def fit(self, steps: int = 200) -> float:
        """Train on all (context -> next) windows in the history.
        Returns the final training loss.  Safe to run off-thread while
        the owner keeps observing: the history is snapshotted, and the
        trained parameters land in one reference swap."""
        h = np.asarray(list(self.history), np.float32)
        if len(h) < self.context + 2:
            return float("nan")
        self.mean = float(np.mean(h)) or 1.0
        hn = h / self.mean
        windows = np.lib.stride_tricks.sliding_window_view(
            hn, self.context + 1)
        xs = jnp.asarray(windows[:, :-1])
        ys = jnp.asarray(windows[:, -1])
        self.params, self.opt_state, losses = _fit(
            self.params, self.opt_state, xs, ys, steps=steps)
        self.losses = np.asarray(losses)
        self.fits += 1
        self._fit_len = len(h)
        return float(losses[-1])

    def predict(self) -> float:
        """Predict the next value from the trailing context.

        The normalizer is recomputed from the trailing context rather than
        taken from ``self.mean``: the history keeps growing between
        ``fit()`` calls (the serving engine observes every arrival), so
        the fit-time mean goes stale and a drifting series would be fed to
        the RNN at the wrong scale.  Before the first ``fit()`` the RNN
        weights are random, so the running mean of the context *is* the
        prediction — the same fallback used while history is short.
        """
        # Only the trailing context is ever read, so only it is
        # materialized — the history list grows unboundedly under the
        # serving engine, and converting all of it per call would make
        # each prediction O(history).  Bit-identical: the slice holds
        # the same elements the full-array path reads, so either branch
        # returns the same floats.  ``full_history_predict`` keeps the
        # pre-refactor O(history) materialization — the serving
        # engine's ``scheduler="linear"`` reference path sets it so the
        # fast-path A/B measures against a cost-faithful baseline.
        if self.full_history_predict:
            h = np.asarray(self.history, np.float32)
        else:
            h = np.asarray(self.history[-self.context:], np.float32)
        if len(h) < self.context:
            return float(np.mean(h)) if len(h) else self.mean
        ctx = h[-self.context:]
        mean = float(np.mean(ctx)) or 1.0
        if self.losses is None:  # never fit: untrained RNN is noise
            return mean
        xs = jnp.asarray(ctx / mean)[None]
        return float(rnn_forward(self.params, xs)[0] * mean)


class RequestPredictor(SeriesPredictor):
    """Predicts the next request *time* of one application from its
    inter-arrival history."""

    def __init__(self, **kw):
        super().__init__(**kw)
        self.last_time: Optional[float] = None

    def observe_request(self, t: float) -> None:
        if self.last_time is not None:
            self.observe(max(t - self.last_time, 1e-6))
        self.last_time = t

    def predict_next_time(self) -> float:
        if self.last_time is None:
            return float("inf")
        gap = max(self.predict(), 1e-6)
        return self.last_time + gap


class MemoryPredictor(SeriesPredictor):
    """Predicts near-future memory availability from recent usage samples."""

    def predict_free(self, budget: float) -> float:
        used = self.predict()
        return max(budget - used, 0.0)
