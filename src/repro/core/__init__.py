"""Edge-MultiAI core: the paper's contribution.

Layers: model zoos (per-tenant precision variants) → memory state →
eviction policies (LFE / BFE / WS-BFE / iWS-BFE) → manager (predictors +
memory optimizer + loader) → E2C-style simulator for the paper's
evaluation protocol.
"""
from repro.core.actions import (CancelPrefetch, ChargeKV, Downgrade,
                                EvictKV, Load, MigrateShard, PlanError,
                                ResidencyPlan, Shrink, Unload, Eviction,
                                eviction_actions, plan_migration, plan_of,
                                procure_actions, staged_load_action)
from repro.core.manager import (BatchAdmission, EdgeMultiAI,
                                InferenceRecord, Metrics)
from repro.core.memory_state import MemoryState, TenantState
from repro.core.model_zoo import ModelVariant, ModelZoo, zoo_from_config
from repro.core.policies import (BatchAware, DemandContext,
                                 DesperationFallback, FallbackPolicy,
                                 Policy, ProcurePlan, available_policies,
                                 kv_headroom_plan, register_policy,
                                 resolve_policy)
from repro.core.predictor import MemoryPredictor, RequestPredictor
from repro.core.simulator import (SimResult, Workload, generate_workload,
                                  generate_zoo, simulate, sweep_policies)

__all__ = [
    "BatchAdmission", "EdgeMultiAI", "InferenceRecord", "Metrics",
    "MemoryState", "TenantState", "ModelVariant", "ModelZoo",
    "Load", "Unload", "Downgrade", "Shrink", "CancelPrefetch",
    "ChargeKV", "EvictKV", "MigrateShard", "ResidencyPlan", "PlanError",
    "Eviction", "plan_of", "plan_migration", "procure_actions",
    "eviction_actions", "staged_load_action",
    "zoo_from_config", "ProcurePlan", "kv_headroom_plan",
    "Policy", "BatchAware", "DemandContext", "DesperationFallback",
    "FallbackPolicy", "available_policies", "register_policy",
    "resolve_policy",
    "MemoryPredictor", "RequestPredictor", "SimResult", "Workload",
    "generate_workload", "generate_zoo", "simulate", "sweep_policies",
]
