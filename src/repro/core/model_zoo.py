"""Model zoos — the paper's per-application repository of NN model variants
at different precision levels (§III-A "Application Tier").

Two constructors:
  * :func:`repro.configs.paper_edge.paper_zoos` — the paper's Table II zoos
    (simulation entities with published sizes/accuracies).
  * :func:`zoo_from_config` — real zoos for the 10 assigned LM architectures,
    with sizes from exact parameter math (``ModelConfig.bytes_for_precision``)
    and accuracy stand-ins from measured quantization fidelity.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

from repro.models.config import ModelConfig

# Host→HBM staging bandwidth used for TPU cold-start load times (PCIe-class).
HOST_TO_HBM_GBPS = 8.0


@dataclass(frozen=True, order=True)
class ModelVariant:
    """One precision level of one application's model."""
    name: str
    bits: int
    size_mb: float
    accuracy: float  # task accuracy %, or fidelity proxy for LM archs
    load_ms: float

    @property
    def size_bytes(self) -> int:
        return int(self.size_mb * 1024 * 1024)


@dataclass(frozen=True)
class ModelZoo:
    """All variants of one application, largest (highest precision) first."""
    app_name: str
    variants: Tuple[ModelVariant, ...]

    def __post_init__(self):
        ordered = tuple(
            sorted(self.variants, key=lambda v: -v.size_mb))
        object.__setattr__(self, "variants", ordered)
        if not ordered:
            raise ValueError(f"empty zoo for {self.app_name}")

    @property
    def largest(self) -> ModelVariant:
        return self.variants[0]

    @property
    def smallest(self) -> ModelVariant:
        return self.variants[-1]

    def next_smaller(self, v: ModelVariant) -> Optional[ModelVariant]:
        idx = self.variants.index(v)
        return self.variants[idx + 1] if idx + 1 < len(self.variants) else None

    def by_bits(self, bits: int) -> ModelVariant:
        for v in self.variants:
            if v.bits == bits:
                return v
        raise KeyError(f"{self.app_name}: no {bits}-bit variant")


def zoo_from_config(
    cfg: ModelConfig,
    *,
    precisions: Tuple[int, ...] = (16, 8, 4),
    fidelity: Optional[dict] = None,
    chips: int = 1,
) -> ModelZoo:
    """Build a real zoo for an LM architecture.

    ``fidelity`` maps bits -> accuracy-proxy in [0, 100] (top-1 agreement vs
    the bf16 reference, measured by benchmarks/quant_fidelity).  Defaults are
    placeholders refined by that benchmark.  ``chips`` divides the load time
    (per-chip shards stream in parallel from their hosts).
    """
    fidelity = fidelity or {16: 100.0, 8: 99.0, 4: 95.0}
    variants = []
    for bits in precisions:
        size_bytes = cfg.bytes_for_precision(bits)
        size_mb = size_bytes / (1024 * 1024)
        load_ms = size_bytes / (HOST_TO_HBM_GBPS * 1e9) / max(chips, 1) * 1e3
        variants.append(
            ModelVariant(
                name=f"{cfg.name}-{bits}bit",
                bits=bits,
                size_mb=size_mb,
                accuracy=fidelity.get(bits, 90.0),
                load_ms=load_ms,
            ))
    return ModelZoo(app_name=cfg.name, variants=tuple(variants))
