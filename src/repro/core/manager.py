"""The NN Model Manager (§III-A): request/memory predictors + memory
optimizer + model loader, orchestrating the eviction policies.

``EdgeMultiAI`` is the framework object: it owns the MemoryState, enacts
ProcurePlans, and does the warm/cold accounting.  It is used two ways:

* driven by the **simulator** (paper-faithful evaluation, Figs 4–10) with
  an externally generated predicted workload, and
* driven by the **serving runtime** (repro.serving) with live RNN
  predictors, where "load" means staging real tenant weights to device.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.core.memory_state import INF, MemoryState, TenantState
from repro.core.model_zoo import ModelVariant, ModelZoo
from repro.core.policies import POLICIES, ProcurePlan

# Inference time is load_ms/12 by default: the 8–17× load/infer asymmetry
# measured in the paper's Table I (midpoint), which is what makes
# cold-starts catastrophic and this whole framework worthwhile.
LOAD_OVER_INFER = 12.0


@dataclass
class InferenceRecord:
    app: str
    t: float
    warm: bool
    failed: bool
    expected: bool  # arrived inside a predicted window
    bits: Optional[int]
    accuracy: float
    latency_ms: float


class EdgeMultiAI:
    """Framework facade: policy-driven multi-tenant model management."""

    def __init__(
        self,
        zoos: Dict[str, ModelZoo],
        budget_mb: float,
        policy: str = "iws-bfe",
        delta_ms: float = 500.0,
        history_ms: float = 3000.0,
        loader: Optional[Callable[[str, Optional[ModelVariant]], None]] = None,
    ):
        self.state = MemoryState(
            budget_mb=budget_mb,
            tenants={a: TenantState(zoo=z) for a, z in zoos.items()})
        if policy not in POLICIES and policy != "none":
            raise KeyError(f"unknown policy {policy!r}")
        self.policy_name = policy
        self.delta = delta_ms
        self.history = history_ms
        self.records: List[InferenceRecord] = []
        self._loader = loader  # real weight mover (serving runtime)

    # ------------------------------------------------------------------
    def _enact(self, plan: ProcurePlan) -> None:
        for ev in plan.evictions:
            self.state.load(ev.app, ev.new)
            if self._loader:
                self._loader(ev.app, ev.new)
        self.state.load(plan.app, plan.variant)
        if self._loader:
            self._loader(plan.app, plan.variant)

    def _procure(self, app: str, now: float) -> ProcurePlan:
        fn = POLICIES[self.policy_name]
        return fn(self.state, app, now, delta=self.delta,
                  history=self.history)

    # ------------------------------------------------------------------
    def set_prediction(self, app: str, t_pred: float) -> None:
        self.state.tenants[app].predicted_next = t_pred

    def proactive_load(self, app: str, now: float) -> None:
        """Fires at t_pred − Δ − θ: stage the highest-precision model that
        fits, ahead of the predicted request (the maximalist promotion)."""
        if self.policy_name == "none":
            return
        t = self.state.tenants[app]
        if t.loaded is t.zoo.largest:
            return
        plan = self._procure(app, now)
        if plan.ok:
            self._enact(plan)

    def on_request(self, app: str, now: float) -> InferenceRecord:
        t = self.state.tenants[app]
        expected = self.state.in_window(app, now, self.delta,
                                        t.zoo.largest.load_ms)
        t.requests += 1
        if not expected:
            t.unexpected += 1

        if t.loaded is not None:
            variant = t.loaded
            warm, failed = True, False
            # §III-A: upon each request the memory optimizer re-determines
            # the highest-precision model loadable.  For *expected* requests
            # the load was already fired θ early (proactive), so an upgrade
            # here overlaps the Δ slack; unexpected requests must be served
            # immediately by whatever is resident (the WS mechanism).
            if expected and self.policy_name != "none" \
                    and variant is not t.zoo.largest:
                plan = self._procure(app, now)
                if plan.ok and plan.variant.size_mb > variant.size_mb:
                    self._enact(plan)
                    variant = plan.variant
            latency = variant.load_ms / LOAD_OVER_INFER
        elif self.policy_name == "none":
            # No framework: on-demand FP32 load, no eviction authority.
            big = t.zoo.largest
            if self.state.free_mb >= big.size_mb:
                self.state.load(app, big)
                variant, warm, failed = big, False, False
                latency = big.load_ms + big.load_ms / LOAD_OVER_INFER
            else:
                variant, warm, failed = None, False, True
                latency = math.inf
        else:
            plan = self._procure(app, now)
            if plan.ok:
                self._enact(plan)
                variant, warm, failed = plan.variant, False, False
                latency = (variant.load_ms
                           + variant.load_ms / LOAD_OVER_INFER)
            else:
                variant, warm, failed = None, False, True
                latency = math.inf

        t.last_request = now
        rec = InferenceRecord(
            app=app, t=now, warm=warm, failed=failed, expected=expected,
            bits=variant.bits if variant else None,
            accuracy=variant.accuracy if variant else 0.0,
            latency_ms=latency)
        self.records.append(rec)
        return rec

    # ------------------------------------------------------------------
    def metrics(self) -> "Metrics":
        return Metrics(self.records, self.state)


@dataclass
class Metrics:
    records: List[InferenceRecord]
    state: MemoryState

    @property
    def total(self) -> int:
        return len(self.records)

    @property
    def warm_ratio(self) -> float:
        return (sum(r.warm for r in self.records) / self.total
                if self.total else 0.0)

    @property
    def cold_ratio(self) -> float:
        return (sum((not r.warm) and (not r.failed) for r in self.records)
                / self.total if self.total else 0.0)

    @property
    def fail_ratio(self) -> float:
        return (sum(r.failed for r in self.records) / self.total
                if self.total else 0.0)

    def mean_accuracy(self, normalize: bool = True) -> float:
        """Mean inference accuracy; min-max normalized per app (Fig 6)."""
        vals = []
        for r in self.records:
            if r.failed:
                continue
            if normalize:
                zoo = self.state.tenants[r.app].zoo
                lo = min(v.accuracy for v in zoo.variants)
                hi = max(v.accuracy for v in zoo.variants)
                vals.append((r.accuracy - lo) / max(hi - lo, 1e-9))
            else:
                vals.append(r.accuracy / 100.0)
        return sum(vals) / len(vals) if vals else 0.0

    def robustness(self) -> float:
        """Paper Eq. 4: R = mean_i [ (warm_i / total_i) · ψ_i ]."""
        apps = {r.app for r in self.records}
        terms = []
        for a in apps:
            rs = [r for r in self.records if r.app == a]
            warm = sum(r.warm for r in rs) / len(rs)
            psi = sum(r.expected for r in rs) / len(rs)
            terms.append(warm * psi)
        return sum(terms) / len(terms) if terms else 0.0

    def per_app(self) -> Dict[str, dict]:
        out = {}
        for a in sorted({r.app for r in self.records}):
            rs = [r for r in self.records if r.app == a]
            ok = [r for r in rs if not r.failed]
            zoo = self.state.tenants[a].zoo
            lo = min(v.accuracy for v in zoo.variants)
            hi = max(v.accuracy for v in zoo.variants)
            out[a] = {
                "requests": len(rs),
                "warm_ratio": sum(r.warm for r in rs) / len(rs),
                "cold_ratio": sum(not r.warm and not r.failed
                                  for r in rs) / len(rs),
                "fail_ratio": sum(r.failed for r in rs) / len(rs),
                "accuracy": (sum(r.accuracy for r in ok) / len(ok)
                             if ok else 0.0),
                "norm_accuracy": (sum((r.accuracy - lo) / max(hi - lo, 1e-9)
                                      for r in ok) / len(ok) if ok else 0.0),
                "max_accuracy": hi,
                "mean_latency_ms": (sum(r.latency_ms for r in ok) / len(ok)
                                    if ok else float("inf")),
            }
        return out
