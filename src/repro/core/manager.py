"""The NN Model Manager (§III-A): request/memory predictors + memory
optimizer + model loader, orchestrating the eviction policies.

``EdgeMultiAI`` is the framework object: it owns the MemoryState and does
the warm/cold accounting.  Every residency decision it makes — admission
procurement, KV headroom scavenging, self-downgrade, the desperation
backstop, cross-device migration — is *built* as a
:class:`~repro.core.actions.ResidencyPlan` and *enacted* through the one
transactional applier, ``MemoryState.apply``; physical weight moves
mirror the applied actions through the ``loader`` callback.  It is used
two ways:

* driven by the **simulator** (paper-faithful evaluation, Figs 4–10) with
  an externally generated predicted workload, and
* driven by the **serving runtime** (repro.serving) with live RNN
  predictors, where "load" means staging real tenant weights to device.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Dict, Iterable, List, Optional

from repro.core import actions as A
from repro.core.memory_state import INF, MemoryState, TenantState
from repro.core.model_zoo import ModelVariant, ModelZoo
from repro.core.policies import (DemandContext, FallbackPolicy, Policy,
                                 PolicyLike, ProcurePlan,
                                 kv_page_victim_plan, resolve_fallback,
                                 resolve_policy)

# Inference time is load_ms/12 by default: the 8–17× load/infer asymmetry
# measured in the paper's Table I (midpoint), which is what makes
# cold-starts catastrophic and this whole framework worthwhile.
LOAD_OVER_INFER = 12.0


@dataclass
class BatchAdmission:
    """Outcome of admitting one serving batch: weights resident (possibly
    after procurement) *and* its KV cache charged against the budget."""
    app: str
    t: float
    kv_mb: float  # charged KV MB (0 when failed)
    warm: bool
    failed: bool
    bits: Optional[int]
    self_downgraded: bool = False  # requester shrank to fit its own cache
    kv_rejected: bool = False  # failed specifically for cache pressure


@dataclass
class InferenceRecord:
    app: str
    t: float
    warm: bool
    failed: bool
    expected: bool  # arrived inside a predicted window
    bits: Optional[int]
    accuracy: float
    latency_ms: float


class EdgeMultiAI:
    """Framework facade: policy-driven multi-tenant model management."""

    #: EWMA weight for the arrival-residual estimate behind the adaptive
    #: prediction window (satellite of the plan-IR PR).
    RESID_ALPHA = 0.3

    def __init__(
        self,
        zoos: Dict[str, ModelZoo],
        budget_mb: float,
        policy: PolicyLike = "iws-bfe",
        delta_ms: float = 500.0,
        history_ms: float = 3000.0,
        loader: Optional[Callable[[str, Optional[ModelVariant]], None]] = None,
        fallback: "FallbackPolicy | str | None" = "desperation",
        adaptive_delta: bool = False,
        migrate: bool = True,
    ):
        self.state = MemoryState(
            budget_mb=budget_mb,
            tenants={a: TenantState(zoo=z) for a, z in zoos.items()})
        # ``policy`` resolves through the registry: a name, a Policy class,
        # or a ready instance; "none" (the paper's unmanaged baseline)
        # disables procurement entirely.
        self.policy: Optional[Policy] = (
            None if policy == "none" else resolve_policy(policy))
        self.policy_name = (policy if isinstance(policy, str)
                            else self.policy.name)
        # What backstops an unfundable plan in the serving runtime; the
        # unmanaged baseline has no eviction authority, so no fallback.
        self.fallback: Optional[FallbackPolicy] = (
            None if self.policy is None else resolve_fallback(fallback))
        self.delta = delta_ms
        self.history = history_ms
        # Adaptive prediction window: per-tenant Δ from the EWMA of
        # measured arrival residuals |t_actual − t_pred| (off by default
        # — the paper's fixed Δ).  ``delta_for`` is the single read path.
        self.adaptive_delta = adaptive_delta
        self._residuals: Dict[str, float] = {}
        # Cross-device victim migration: when a chip's budget blocks an
        # admission load while neighbors idle, move a resident victim's
        # shards instead of downgrading/failing (sharded mesh only).
        self.migrate = migrate
        self.records: List[InferenceRecord] = []
        self.kv_rejections = 0  # batches rejected for KV pressure
        # Paged-KV preemption (continuous batching): sequences whose
        # pages were evicted as victims of another tenant's admission.
        # The engine drains ``take_preempted`` and requeues them.
        self.kv_preemptions = 0
        self._preempted: List[tuple] = []
        self._loader = loader  # real weight mover (serving runtime)
        # Admission-path migration observer (t_ms, app, mb): the serving
        # runtime wires this to the loader's event hook so MigrateShard
        # moves show up in the engine's audit trail like loader-path
        # migrations do.
        self.on_migrate: Optional[Callable[[float, str, float],
                                           None]] = None

    # ------------------------------------------------------------------
    def _apply_actions(self, actions: Iterable[A.Action],
                       now: Optional[float] = None) -> None:
        """Enact residency actions: one transactional ``state.apply``,
        then mirror each action to the physical loader in the same order
        the accounting committed them (a migrated victim is restaged so
        device contents track the ledger; a same-variant restage is a
        no-op for the runtime)."""
        actions = tuple(actions)
        if not actions:
            return
        self.state.apply(A.ResidencyPlan(actions))
        for act in actions:
            if isinstance(act, A.RESIDENCY_ACTIONS):
                if self._loader:
                    self._loader(act.app, act.variant)
            elif isinstance(act, A.MigrateShard):
                if self._loader:
                    self._loader(act.app,
                                 self.state.tenants[act.app].loaded)
                if self.on_migrate is not None and now is not None:
                    self.on_migrate(now, act.app, act.mb)

    def _enact(self, plan: ProcurePlan) -> None:
        self._apply_actions(A.procure_actions(plan))

    def _procure(self, app: str, now: float) -> ProcurePlan:
        return self.policy.plan_procure(self.state, app, now,
                                        delta=self.delta_for(app),
                                        history=self.history)

    # ------------------------------------------------------------------
    def delta_for(self, app: str) -> float:
        """The prediction-window half-width Δ for one tenant: the
        configured constant, or — with ``adaptive_delta`` — twice the
        EWMA of the tenant's measured arrival residuals, clamped to
        [Δ/4, 2Δ] so a lucky streak cannot collapse the window to zero
        nor a noisy tenant inflate it without bound."""
        if not self.adaptive_delta:
            return self.delta
        r = self._residuals.get(app)
        if r is None:
            return self.delta
        return min(max(2.0 * r, 0.25 * self.delta), 2.0 * self.delta)

    def _observe_residual(self, app: str, now: float) -> None:
        t = self.state.tenants[app]
        if t.predicted_next is INF or math.isinf(t.predicted_next):
            return
        resid = abs(now - t.predicted_next)
        prev = self._residuals.get(app)
        self._residuals[app] = (
            resid if prev is None
            else self.RESID_ALPHA * resid + (1 - self.RESID_ALPHA) * prev)

    def set_prediction(self, app: str, t_pred: float) -> None:
        self.state.tenants[app].predicted_next = t_pred

    def plan_proactive(self, app: str, now: float) -> Optional[ProcurePlan]:
        """The planning half of :meth:`proactive_load`: decide what a
        t_pred − Δ − θ trigger would stage, without enacting it.  The
        serving runtime routes the returned plan to the background loader
        so the weight transfer happens off the hot path; the simulator
        keeps the synchronous :meth:`proactive_load` wrapper."""
        if self.policy is None:
            return None
        t = self.state.tenants[app]
        if t.loaded is t.zoo.largest or t.inflight_mb > 0.0:
            return None
        plan = self._procure(app, now)
        return plan if plan.ok else None

    def proactive_load(self, app: str, now: float) -> None:
        """Fires at t_pred − Δ − θ: stage the highest-precision model that
        fits, ahead of the predicted request (the maximalist promotion)."""
        plan = self.plan_proactive(app, now)
        if plan is not None:
            self._enact(plan)

    def plan_prefetch(self, app: str, now: float) -> Optional[ProcurePlan]:
        """Speculative plan for the background loader — delegated to the
        policy's ``plan_prefetch`` hook (default: eviction-free,
        surplus-only; see :class:`~repro.core.policies.Policy`)."""
        if self.policy is None:
            return None
        return self.policy.plan_prefetch(self.state, app, now,
                                         delta=self.delta_for(app),
                                         history=self.history)

    def plan_demand(self, app: str, now: float, kv_mb: float = 0.0,
                    demand: Optional[DemandContext] = None
                    ) -> Optional[ProcurePlan]:
        """Plan a load for a *cold* tenant with requests already queued,
        for the background loader: the engine stages the weights off the
        loop and keeps serving other tenants instead of blocking inside
        the admit path.  ``demand`` carries the waiting queue's cache
        needs (head batch and full-queue bound); the policy's
        ``plan_demand`` hook stages its chosen charge as a pending
        planning reservation so the variant leaves room for the cache
        (no load-then-downgrade thrash at admission).  ``kv_mb`` is the
        pre-protocol shorthand for a head-batch-only context.  Returns
        None when the tenant is already resident/mid-staging or no
        variant fits (admission will then record the counted failure).
        """
        if self.policy is None:
            return None
        t = self.state.tenants[app]
        if t.loaded is not None or t.inflight_mb > 0.0:
            return None
        if demand is None:
            demand = DemandContext(kv_head_mb=kv_mb, kv_full_mb=kv_mb,
                                   queue_depth=1, max_batch=1)
        plan = self.policy.plan_demand(self.state, app, now, demand,
                                       delta=self.delta_for(app),
                                       history=self.history)
        if plan is None and self.fallback is not None:
            # Serving never fails what the fallback can fund: free the
            # smallest variant's footprint ignoring window/history
            # protections, then load exactly that — a maximalist
            # re-procure here would snowball the evictions it just
            # forced into an even bigger claim.  (The fallback's
            # evictions are enacted here as one atomic plan: the pure
            # policies stay pure over the *current* state.)
            with self.state.pending(self.policy.demand_charge(demand)):
                self._desperate_evict(app, t.zoo.smallest.size_mb)
                if self.state.free_mb >= t.zoo.smallest.size_mb:
                    plan = ProcurePlan(app, t.zoo.smallest)
        return plan if plan is not None and plan.ok else None

    def _desperate_evict(self, app: str, need_mb: float, *,
                         seq: Optional[int] = None,
                         now: Optional[float] = None) -> None:
        """Enact the fallback policy's evictions for ``app``'s need —
        built as one plan, applied all-or-nothing.  With a KV page pool
        installed and a page-granular charge (``seq`` set), cold KV
        pages join the victim class: whole-model evictions and other
        sequences' page evictions compose into the *same* atomic plan,
        and the preempted sequences are recorded for the engine to
        requeue."""
        evs = (self.fallback.plan(self.state, app, need_mb)
               if self.fallback is not None else ())
        acts: tuple = A.eviction_actions(evs)
        pool = self.state.kv_pool
        if pool is not None and seq is not None:
            acts += kv_page_victim_plan(
                self.state, app, need_mb=need_mb,
                need_pages=pool.pages_for(need_mb),
                extra_free_mb=sum(e.freed_mb for e in evs))
        if not acts:
            return
        self._apply_actions(acts, now=now)
        for act in acts:
            if isinstance(act, A.EvictKV) and act.seq is not None:
                self.kv_preemptions += 1
                self._preempted.append((act.app, act.seq))

    def take_preempted(self) -> tuple:
        """Drain the (app, seq) pairs evicted as page victims since the
        last call — the engine requeues their requests."""
        out = tuple(self._preempted)
        self._preempted.clear()
        return out

    def on_request(self, app: str, now: float) -> InferenceRecord:
        t = self.state.tenants[app]
        expected = self.state.in_window(app, now, self.delta_for(app),
                                        t.zoo.largest.load_ms)
        # Close the predictor-quality loop *after* the window check: the
        # adapted Δ a request sees comes from prior residuals, then this
        # arrival's |t_actual − t_pred| feeds the EWMA for the next one.
        self._observe_residual(app, now)
        t.requests += 1
        if not expected:
            t.unexpected += 1

        if t.loaded is not None:
            variant = t.loaded
            warm, failed = True, False
            # §III-A: upon each request the memory optimizer re-determines
            # the highest-precision model loadable.  For *expected* requests
            # the load was already fired θ early (proactive), so an upgrade
            # here overlaps the Δ slack; unexpected requests must be served
            # immediately by whatever is resident (the WS mechanism).
            if expected and self.policy is not None \
                    and variant is not t.zoo.largest:
                plan = self._procure(app, now)
                if plan.ok and plan.variant.size_mb > variant.size_mb:
                    self._enact(plan)
                    variant = plan.variant
            latency = variant.load_ms / LOAD_OVER_INFER
        elif self.policy is None:
            # No framework: on-demand FP32 load, no eviction authority.
            big = t.zoo.largest
            if self.state.free_mb >= big.size_mb:
                self._apply_actions((A.Load(app, big),))
                variant, warm, failed = big, False, False
                latency = big.load_ms + big.load_ms / LOAD_OVER_INFER
            else:
                variant, warm, failed = None, False, True
                latency = math.inf
        else:
            plan = self._procure(app, now)
            if plan.ok:
                self._enact(plan)
                variant, warm, failed = plan.variant, False, False
                latency = (variant.load_ms
                           + variant.load_ms / LOAD_OVER_INFER)
            else:
                variant, warm, failed = None, False, True
                latency = math.inf

        t.last_request = now
        rec = InferenceRecord(
            app=app, t=now, warm=warm, failed=failed, expected=expected,
            bits=variant.bits if variant else None,
            accuracy=variant.accuracy if variant else 0.0,
            latency_ms=latency)
        self.records.append(rec)
        return rec

    # ------------------------------------------------------------------
    # KV-cache residency (serving runtime): batches charge their decode
    # caches against the same budget the eviction policies manage.
    # ------------------------------------------------------------------
    def _kv_short(self, kv_mb: float, seq: Optional[int]) -> bool:
        """Would charging ``kv_mb`` fail right now?  Global budget always;
        with a page pool and a page-granular charge, the pool's free
        pages must cover the rounded page count too (fragmentation the
        scalar check cannot see)."""
        if self.state.free_mb < kv_mb:
            return True
        pool = self.state.kv_pool
        if pool is not None and seq is not None:
            return pool.free_pages < pool.pages_for(kv_mb)
        return False

    def admit_batch(self, app: str, now: float, kv_mb: float,
                    demand_cold: bool = False,
                    seq: Optional[int] = None) -> BatchAdmission:
        """Admit one batch: ensure weights are resident (procuring if
        needed), then charge ``kv_mb`` of cache.  The KV need is staged as
        a pending planning charge during procurement so the policies pick
        a variant that leaves room for the cache up front (one weight
        transfer, no load-then-downgrade thrash).  If pressure remains
        (e.g. the tenant was already warm at a large variant), scavenge
        victims' weight memory, then downgrade the requester itself; if
        the cache still cannot fit, the batch is rejected and counted —
        never an invariant assert.

        ``demand_cold``: the weights are only resident because a
        demand-triggered background load just committed for this very
        batch — the request waited out the transfer, so the serve is
        recorded as a cold start (latency includes the load) even though
        ``loaded`` is non-None by admission time."""
        t = self.state.tenants[app]
        with self.state.pending(kv_mb):
            rec = self.on_request(app, now)
            if rec.failed and self.policy is not None:
                # The pure policies refuse to unload (iWS-BFE only ever
                # replaces), but in the serving runtime a failure is
                # strictly worse than evicting an idle tenant: free the
                # smallest variant's footprint ignoring protections and
                # serve degraded (smallest only — not a maximalist
                # re-procure, which would snowball the forced evictions
                # into an even bigger claim).
                self._desperate_evict(app, t.zoo.smallest.size_mb)
                small = t.zoo.smallest
                if self.state.free_mb >= small.size_mb:
                    self._enact(ProcurePlan(app, small))
                    rec.failed, rec.warm = False, False
                    rec.bits = small.bits
                    rec.accuracy = small.accuracy
                    rec.latency_ms = (small.load_ms
                                      * (1.0 + 1.0 / LOAD_OVER_INFER))
        if rec.failed:
            # Attribute the failure: if weights alone would have been
            # procurable without the staged KV need, this is cache
            # pressure, not weight capacity.
            if self.policy is None:
                kv_rej = self.state.free_mb >= t.zoo.largest.size_mb
            else:
                kv_rej = kv_mb > 0 and self._procure(app, now).ok
            if kv_rej:
                self.kv_rejections += 1
            return BatchAdmission(app, now, 0.0, rec.warm, True, None,
                                  kv_rejected=kv_rej)
        if self._kv_short(kv_mb, seq) and self.policy is not None:
            self._apply_actions(A.eviction_actions(
                self.policy.plan_headroom(self.state, app, now, kv_mb,
                                          delta=self.delta_for(app),
                                          history=self.history)))
        self_downgraded = False
        if self.policy is not None and t.loaded is not None \
                and self.state.free_mb < kv_mb:
            # Self-downgrade, planned: walk the zoo down until the freed
            # weight difference funds the cache, then apply one
            # Downgrade to the final variant (identical resolution to
            # the old step-by-step loop, one transaction and one
            # physical restage instead of N).
            v, freed = t.loaded, 0.0
            while (self.state.free_mb + freed < kv_mb
                   and (nxt := t.zoo.next_smaller(v)) is not None):
                freed += v.size_mb - nxt.size_mb
                v = nxt
            if v is not t.loaded:
                self._apply_actions(
                    (A.downgrade_action(app, t.loaded, v),))
                self_downgraded = True
        if (self.policy is not None and self.state.devices is not None
                and t.loaded is not None and self.migrate
                and not self.state.devices.fits_variant(app, t.loaded)):
            # Cross-device victim migration: the admission load was
            # planned against the *global* budget (policies are
            # device-blind) and one chip overflowed while neighbors
            # idle.  Before downgrading the whole load, try moving
            # resident victims' shards to the free chips — simulate
            # first, then commit the moves as one atomic plan.
            moves = A.plan_migration(
                self.state, app,
                (0.0,) * self.state.devices.n_devices)
            if moves is not None and \
                    self.state.simulate(A.ResidencyPlan(moves)) is None:
                self._apply_actions(moves, now=now)
        if (self.policy is not None and self.state.devices is not None
                and t.loaded is not None
                and not self.state.devices.fits_variant(app, t.loaded)):
            # Sharded mesh fallback: no migration could relieve the
            # chip, so downgrade until every shard fits its device —
            # the same resolution an unfundable sharded background load
            # feeds into.  Planned as one Downgrade to the first
            # fitting variant.
            v = t.loaded
            while (v is not None
                   and not self.state.devices.fits_variant(app, v)):
                v = t.zoo.next_smaller(v)
            if v is not None and v is not t.loaded:
                self._apply_actions(
                    (A.downgrade_action(app, t.loaded, v),))
                self_downgraded = True
        if (self.state.devices is not None and t.loaded is not None
                and not self.state.devices.fits_variant(app, t.loaded)):
            # Even the smallest shard overflows its chip: reject rather
            # than commit over-budget per-device state (the global-path
            # analogue is an unprocurable plan — a counted weight
            # failure, never an invariant violation later).
            self._apply_actions((A.Unload(app),))
            rec.warm, rec.failed, rec.bits = False, True, None
            rec.accuracy, rec.latency_ms = 0.0, math.inf
            return BatchAdmission(app, now, 0.0, False, True, None,
                                  self_downgraded, kv_rejected=False)
        if self._kv_short(kv_mb, seq) and self.policy is not None:
            # Desperation: rejecting the batch is the worst outcome, so
            # the window/history protections yield before the cache does
            # — and, page-granular, other tenants' cold KV pages join
            # the victim class in the same plan.
            self._desperate_evict(app, kv_mb, seq=seq, now=now)
        if self._kv_short(kv_mb, seq):
            self.kv_rejections += 1
            # The inference never executes: retract the success record
            # on_request logged so Metrics agree with the engine (a
            # rejected request is neither warm nor served).
            rec.warm, rec.failed, rec.bits = False, True, None
            rec.accuracy, rec.latency_ms = 0.0, math.inf
            return BatchAdmission(app, now, 0.0, False, True, None,
                                  self_downgraded, kv_rejected=True)
        # Scavenging/self-downgrade may have swapped the serving variant
        # after on_request recorded it: sync the record to what actually
        # serves so Metrics report the right bits/accuracy.
        final = t.loaded
        if rec.bits != final.bits:
            rec.bits, rec.accuracy = final.bits, final.accuracy
            rec.latency_ms = (
                final.load_ms / LOAD_OVER_INFER if rec.warm
                else final.load_ms + final.load_ms / LOAD_OVER_INFER)
        if demand_cold and rec.warm:
            rec.warm = False
            rec.latency_ms = (final.load_ms
                              + final.load_ms / LOAD_OVER_INFER)
        try:
            self._apply_actions((A.ChargeKV(app, kv_mb, seq=seq),))
        except A.PlanError:
            # Page-granular only: the scalar checks passed but the pool
            # could not fund the rounded page count (e.g. a concurrent
            # holder).  A counted rejection, never an invariant assert.
            self.kv_rejections += 1
            rec.warm, rec.failed, rec.bits = False, True, None
            rec.accuracy, rec.latency_ms = 0.0, math.inf
            return BatchAdmission(app, now, 0.0, False, True, None,
                                  self_downgraded, kv_rejected=True)
        return BatchAdmission(app, now, kv_mb, rec.warm, False,
                              final.bits, self_downgraded)

    def release_kv(self, app: str, kv_mb: float,
                   seq: Optional[int] = None) -> None:
        """A batch retired: return its cache memory to the pool.  With a
        ``seq``, the page pool frees exactly that sequence's pages."""
        self._apply_actions((A.EvictKV(app, kv_mb, seq=seq),))

    # ------------------------------------------------------------------
    def metrics(self) -> "Metrics":
        return Metrics(self.records, self.state)


@dataclass
class Metrics:
    records: List[InferenceRecord]
    state: MemoryState

    @property
    def total(self) -> int:
        return len(self.records)

    @property
    def warm_ratio(self) -> float:
        return (sum(r.warm for r in self.records) / self.total
                if self.total else 0.0)

    @property
    def cold_ratio(self) -> float:
        return (sum((not r.warm) and (not r.failed) for r in self.records)
                / self.total if self.total else 0.0)

    @property
    def fail_ratio(self) -> float:
        return (sum(r.failed for r in self.records) / self.total
                if self.total else 0.0)

    def mean_accuracy(self, normalize: bool = True) -> float:
        """Mean inference accuracy; min-max normalized per app (Fig 6)."""
        vals = []
        for r in self.records:
            if r.failed:
                continue
            if normalize:
                zoo = self.state.tenants[r.app].zoo
                lo = min(v.accuracy for v in zoo.variants)
                hi = max(v.accuracy for v in zoo.variants)
                vals.append((r.accuracy - lo) / max(hi - lo, 1e-9))
            else:
                vals.append(r.accuracy / 100.0)
        return sum(vals) / len(vals) if vals else 0.0

    def robustness(self) -> float:
        """Paper Eq. 4: R = mean_i [ (warm_i / total_i) · ψ_i ]."""
        apps = {r.app for r in self.records}
        terms = []
        for a in apps:
            rs = [r for r in self.records if r.app == a]
            warm = sum(r.warm for r in rs) / len(rs)
            psi = sum(r.expected for r in rs) / len(rs)
            terms.append(warm * psi)
        return sum(terms) / len(terms) if terms else 0.0

    def per_app(self) -> Dict[str, dict]:
        out = {}
        for a in sorted({r.app for r in self.records}):
            rs = [r for r in self.records if r.app == a]
            ok = [r for r in rs if not r.failed]
            zoo = self.state.tenants[a].zoo
            lo = min(v.accuracy for v in zoo.variants)
            hi = max(v.accuracy for v in zoo.variants)
            out[a] = {
                "requests": len(rs),
                "warm_ratio": sum(r.warm for r in rs) / len(rs),
                "cold_ratio": sum(not r.warm and not r.failed
                                  for r in rs) / len(rs),
                "fail_ratio": sum(r.failed for r in rs) / len(rs),
                "accuracy": (sum(r.accuracy for r in ok) / len(ok)
                             if ok else 0.0),
                "norm_accuracy": (sum((r.accuracy - lo) / max(hi - lo, 1e-9)
                                      for r in ok) / len(ok) if ok else 0.0),
                "max_accuracy": hi,
                "mean_latency_ms": (sum(r.latency_ms for r in ok) / len(ok)
                                    if ok else float("inf")),
            }
        return out
