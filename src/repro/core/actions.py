"""The residency-action IR: one transactional plan/apply layer for every
memory mutation in the framework.

Before this layer, the decision logic that is Edge-MultiAI's actual
contribution — *which* NN variants occupy the contended edge memory —
was enacted by five call sites each hand-mutating :class:`MemoryState`
with its own partial invariant checks (admission downgrade loops, the
desperation fallback, the loaders' enqueue/cancel/shrink paths, the
sharded shard-fit failure path).  Composite mutations were not atomic:
a plan that went stale mid-enactment left its evictions behind.

This module makes residency changes *data*: small frozen action records
composed into a :class:`ResidencyPlan`, validated and committed by
exactly one applier — ``MemoryState.simulate(plan)`` (checks budget +
per-device ledgers without mutating) and ``MemoryState.apply(plan)``
(all-or-nothing: any infeasible action rolls the whole plan back and
raises :class:`PlanError`).  Policies and the manager *build* plans; the
serving loaders *translate* applied actions into their physical stage
ops.  Because a plan is pure data over a simulatable state, enumerating
and scoring candidate plans is cheap — which is what the cost-aware
policy plugin and the cross-device migration planner below rely on.

Action vocabulary:

* :class:`Load` — make ``variant`` resident (a synchronous load or a
  staged-load commit), or with ``staged=True`` reserve the in-flight
  claim a background transfer will convert to weights.
* :class:`Unload` / :class:`Downgrade` — evict a victim outright or
  replace it with a smaller variant (the policies' eviction verbs).
* :class:`Shrink` — shrink an in-flight claim to a smaller variant's
  (single-stream loader; the sharded loader expresses a shrink as
  ``CancelPrefetch`` + ``Load(staged=True)`` in one atomic plan).
* :class:`CancelPrefetch` — release an in-flight claim (global and, on
  a mesh, shard-by-shard in device order).
* :class:`ChargeKV` / :class:`EvictKV` — charge a batch's decode cache
  against the budget / return it on retirement.
* :class:`MigrateShard` — move one resident tenant's per-device shard
  between chips of the :class:`~repro.core.memory_state.DeviceLedger`
  (the cross-device victim-migration primitive).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import TYPE_CHECKING, Iterator, List, Optional, Tuple, Union

from repro.core.model_zoo import ModelVariant

if TYPE_CHECKING:  # pragma: no cover - typing only, no runtime cycle
    from repro.core.memory_state import MemoryState

INF = math.inf
EPS = 1e-9


class PlanError(RuntimeError):
    """A plan failed validation; ``MemoryState.apply`` raises this *after*
    rolling back every action it had already applied."""


# ---------------------------------------------------------------------------
# Policy-level plan records (moved here from repro.core.policies, which
# re-exports them: a ProcurePlan is the policies' answer, and
# ``procure_actions`` compiles it onto the action IR for enactment).
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Eviction:
    """One victim decision from a policy: replace ``app``'s resident
    ``old`` variant with ``new`` (``None`` = unload outright).  Compiled
    to :class:`Unload`/:class:`Downgrade` actions by
    :func:`eviction_actions`.

    >>> from repro.core.model_zoo import ModelVariant
    >>> old = ModelVariant("m-16bit", 16, 100.0, 0.9, 50.0)
    >>> new = ModelVariant("m-8bit", 8, 50.0, 0.85, 25.0)
    >>> Eviction("m", old, new).freed_mb
    50.0
    """
    app: str
    old: ModelVariant
    new: Optional[ModelVariant]  # None = fully unloaded

    @property
    def freed_mb(self) -> float:
        return self.old.size_mb - (self.new.size_mb if self.new else 0.0)


@dataclass(frozen=True)
class ProcurePlan:
    """A policy's full answer to "procure weights for ``app``": the
    variant to load (``None`` = declared inference failure) plus the
    victim evictions that fund it.  :func:`procure_actions` compiles it
    onto the action IR."""
    app: str
    variant: Optional[ModelVariant]  # None => inference failure
    evictions: Tuple[Eviction, ...] = ()

    @property
    def ok(self) -> bool:
        return self.variant is not None


# ---------------------------------------------------------------------------
# Actions
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Load:
    """Make ``variant`` resident for ``app``.

    ``staged=False`` (default) commits: ``claim_mb`` / ``shard_claims``
    — the in-flight claim a background load held — are released in the
    same transaction the weights are charged, so a commit is net-zero on
    ``free_mb`` and can never trip the budget.  Synchronous (admission
    path) loads simply leave the claim fields at zero/None.

    ``staged=True`` reserves instead of committing: the claim is charged
    (globally, and per chip when ``shard_claims`` is set) so planning
    against ``free_mb`` cannot double-book memory the transfer already
    owns.  ``claim_mb=None`` means "the marginal footprint over the
    currently loaded variant", resolved by the loader at execute time.
    """
    app: str
    variant: ModelVariant
    staged: bool = False
    claim_mb: Optional[float] = None
    shard_claims: Optional[Tuple[float, ...]] = None


@dataclass(frozen=True)
class Unload:
    """Evict ``app``'s resident variant outright (the policies' and the
    drain planner's last-resort verb); its weights and per-device shards
    are released in the same transaction."""
    app: str
    variant = None  # uniform `.variant` access for stage callbacks


@dataclass(frozen=True)
class Downgrade:
    """Replace ``app``'s resident variant with the smaller ``variant``.

    ``in_place=True`` declares that the switch is an **in-place
    requantization**: ``variant`` is a lower-bits sibling of the resident
    variant, so the new weights are derived from the resident leaves via
    the ``quant_matmul`` int8 machinery — zero bytes move over the
    host→chip link.  The residency/ledger effect is identical either way
    (the ``DeviceLedger`` scales the tenant's current layout to the new
    total atomically); only the physical staging cost differs, which the
    loader channels count (``inplace_downgrades`` vs ``wire_mb_staged``).
    ``MemoryState`` validates the claim: an in-place downgrade to a
    variant that is not strictly lower-bits than the resident one — or
    with nothing resident at all — is a :class:`PlanError`.

    >>> from repro.core.model_zoo import ModelVariant
    >>> v8 = ModelVariant("m-8bit", 8, 50.0, 0.85, 25.0)
    >>> Downgrade("m", v8, in_place=True).in_place
    True
    """
    app: str
    variant: ModelVariant
    in_place: bool = False


@dataclass(frozen=True)
class Shrink:
    """Shrink an in-flight claim to ``variant``'s marginal footprint,
    releasing ``release_mb`` back to the pool (single-stream loader)."""
    app: str
    variant: ModelVariant
    release_mb: float


@dataclass(frozen=True)
class CancelPrefetch:
    """Release an in-flight load's claim: ``claim_mb`` globally, plus
    one claim per device (walked in device order) on a sharded mesh."""
    app: str
    claim_mb: float
    shard_claims: Optional[Tuple[float, ...]] = None


@dataclass(frozen=True)
class ChargeKV:
    """Charge a decode cache to ``app``.

    Scalar form (``seq=None``): ``mb`` is a whole-batch charge, the
    pre-paging accounting unit.  Page-granular form (``seq`` set, a
    request id): when the state has a
    :class:`~repro.core.memory_state.KVPagePool` installed, the charge
    allocates fixed-size pages for that sequence — ``pages`` explicitly,
    else ``ceil(mb / page_mb)`` — and the charged MB is the page-rounded
    footprint.  Page allocation validates against the pool's free lists
    (and per-device page capacity on a mesh) exactly like weight shards:
    an unfundable allocation raises ``PlanError`` under simulate/apply.
    """
    app: str
    mb: float
    seq: Optional[int] = None
    pages: Optional[int] = None


@dataclass(frozen=True)
class EvictKV:
    """Return a retired decode cache.  Scalar form releases ``mb``;
    page-granular form (``seq`` set) frees exactly the pages the pool
    holds for that sequence, deriving the MB from the page table — so a
    release can never drift from its charge."""
    app: str
    mb: float
    seq: Optional[int] = None


@dataclass(frozen=True)
class MigrateShard:
    """Move ``mb`` of ``app``'s committed weights from chip ``src`` to
    chip ``dst``: the cross-device victim-migration primitive.  The
    moved layout persists until the tenant's next (re)load re-derives
    the canonical split — by which point the weights are restaged
    anyway."""
    app: str
    src: int
    dst: int
    mb: float


Action = Union[Load, Unload, Downgrade, Shrink, CancelPrefetch,
               ChargeKV, EvictKV, MigrateShard]

# Actions that change which variant is resident — the ones a physical
# stage callback must mirror to the device.
RESIDENCY_ACTIONS = (Load, Downgrade, Unload)


@dataclass(frozen=True)
class ResidencyPlan:
    """An ordered, atomic group of residency actions.  ``simulate``
    validates the whole sequence against the budget and the per-device
    ledger without mutating; ``apply`` commits all-or-nothing."""
    actions: Tuple[Action, ...]

    def __iter__(self) -> Iterator[Action]:
        return iter(self.actions)

    def __len__(self) -> int:
        return len(self.actions)

    def __add__(self, other: "ResidencyPlan") -> "ResidencyPlan":
        return ResidencyPlan(self.actions + other.actions)

    @property
    def apps(self) -> Tuple[str, ...]:
        return tuple(dict.fromkeys(a.app for a in self.actions))


def plan_of(*actions: Action) -> ResidencyPlan:
    """Convenience constructor: ``plan_of(Downgrade(...), Load(...))``."""
    return ResidencyPlan(tuple(actions))


# ---------------------------------------------------------------------------
# Builders: compile policy-level plans onto the action IR
# ---------------------------------------------------------------------------
def downgrade_action(app: str, old: Optional[ModelVariant],
                     new: ModelVariant) -> Downgrade:
    """A :class:`Downgrade` that requantizes **in place** whenever it
    can: ``new`` strictly lower-bits than the resident ``old`` means the
    target weights are derivable from the resident leaves (int8/int4
    from wider), so the variant switch moves zero bytes over the link.
    Every planner that emits downgrades compiles through here, so the
    preference is uniform across cost-bfe, desperation, KV headroom,
    self-downgrade, and the elastic drain."""
    in_place = old is not None and new.bits < old.bits
    return Downgrade(app, new, in_place=in_place)


def eviction_actions(evictions) -> Tuple[Action, ...]:
    """Victim evictions as actions: ``new=None`` unloads, else downgrades
    (in place when the target is a lower-bits sibling of the resident
    variant — see :func:`downgrade_action`)."""
    return tuple(Unload(e.app) if e.new is None
                 else downgrade_action(e.app, e.old, e.new)
                 for e in evictions)


def procure_actions(plan: ProcurePlan, *, staged: bool = False
                    ) -> Tuple[Action, ...]:
    """A :class:`ProcurePlan` as actions: the victims' evictions followed
    by the requester's load (``staged=True`` for a background transfer,
    whose claim the loader resolves to the marginal footprint)."""
    acts = eviction_actions(plan.evictions)
    if plan.variant is not None:
        acts += (Load(plan.app, plan.variant, staged=staged),)
    return acts


def concretize_load(act: Load, state: "MemoryState") -> Load:
    """Resolve a staged :class:`Load`'s ``claim_mb=None`` to the marginal
    footprint over what ``state`` says is loaded."""
    if not act.staged or act.claim_mb is not None:
        return act
    loaded = state.tenants[act.app].loaded
    charge = act.variant.size_mb - (loaded.size_mb if loaded else 0.0)
    return replace(act, claim_mb=max(0.0, charge))


def staged_load_action(state: "MemoryState", app: str,
                       variant: ModelVariant) -> Load:
    """A fully concrete staged :class:`Load`: marginal global claim plus,
    when a :class:`DeviceLedger` is installed, the per-device marginal
    shard claims from the ledger's own split — so simulating the action
    answers "would this transfer fit *every* chip", which device-blind
    eviction math cannot."""
    act = concretize_load(Load(app, variant, staged=True), state)
    led = state.devices
    if led is not None:
        cur = led.held(app, state.tenants[app].loaded)
        new = led.projected(app, variant)
        act = replace(act, shard_claims=tuple(
            max(0.0, n - c) for n, c in zip(new, cur)))
    return act


# ---------------------------------------------------------------------------
# Cross-device victim migration planner
# ---------------------------------------------------------------------------
def plan_migration(state: "MemoryState", app: str,
                   claims: Tuple[float, ...], *,
                   exclude: Tuple[str, ...] = ()
                   ) -> Optional[Tuple[MigrateShard, ...]]:
    """When ``app``'s per-device ``claims`` do not fit the ledger, move
    resident *victims'* shards off the over-committed chips onto chips
    with spare room, instead of failing or downgrading the whole load.

    Pure over ``state`` (returns actions; the caller simulates/applies).
    Victims are whole per-device shards, best-fit per chip (the smallest
    shard that covers the remaining need, else the largest available),
    name-tiebroken for determinism.  The requester itself and any tenant
    with a load mid-staging (the loader owns its residency) never move.
    A destination chip must absorb the shard *on top of* its own share
    of the incoming claim.  Returns None when migration cannot cover the
    shortfall — the caller falls back to the existing downgrade /
    clean-failure path.
    """
    led = state.devices
    if led is None:
        return None
    n = led.n_devices
    if len(claims) != n:
        raise ValueError(f"{len(claims)} claims for {n} devices")
    frozen = {app, *exclude}
    for a, t in state.tenants.items():
        if t.inflight_mb > 0.0:
            frozen.add(a)
    used = [led.used_mb(d) for d in range(n)]
    weights = {a: list(w) for a, w in led.weights.items()}

    def room(d: int) -> float:
        return led.budgets_mb[d] - used[d] - claims[d]

    moves: List[MigrateShard] = []
    for d in range(n):
        while (need := claims[d] - (led.budgets_mb[d] - used[d])) > EPS:
            cands = []
            for a in sorted(weights):
                mb = weights[a][d]
                if a in frozen or mb <= EPS:
                    continue
                dsts = [j for j in range(n)
                        if j != d and room(j) >= mb - EPS]
                if dsts:
                    cands.append((a, mb, max(dsts, key=room)))
            if not cands:
                return None  # this chip cannot be relieved
            covering = [c for c in cands if c[1] >= need]
            a, mb, dst = (min(covering, key=lambda c: c[1]) if covering
                          else max(cands, key=lambda c: c[1]))
            moves.append(MigrateShard(a, d, dst, mb))
            weights[a][d] = 0.0
            weights[a][dst] += mb
            used[d] -= mb
            used[dst] += mb
    return tuple(moves) if moves else None
