"""E2C-style discrete-event workload simulator (§IV-A).

Reproduces the paper's evaluation protocol:

* per-application request streams with exponential inter-arrival times,
  equal request counts per app;
* a *predicted* workload derived from the actual one with a controlled
  deviation knob ``d`` — per-request Gaussian jitter of std ``d·IAT`` plus
  prediction drop-outs with probability ``d/2`` (the paper's "unexpected
  requests"); the realized divergence is reported as KL between actual
  and predicted inter-arrival distributions, as in the paper;
* Δ estimated from prediction residuals as ``D + α·σ`` (Fig 7 sweeps α);
* an event loop that fires proactive-load triggers at ``t_pred − Δ − θ``
  and actual requests in timestamp order.
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.manager import EdgeMultiAI, Metrics
from repro.core.model_zoo import ModelZoo


@dataclass
class Workload:
    requests: List[Tuple[float, str]]  # (t, app) sorted by t
    predictions: Dict[str, List[float]]  # app -> predicted request times
    horizon_ms: float
    deviation: float
    delta_D: float  # mean |residual| over matched prediction pairs
    delta_sigma: float  # std of residuals
    kl: float  # realized KL(actual ‖ predicted) inter-arrival divergence

    def delta(self, alpha: float = 1.0) -> float:
        return self.delta_D + alpha * self.delta_sigma

    @property
    def mean_iat(self) -> float:
        per_app: Dict[str, List[float]] = {}
        for t, a in self.requests:
            per_app.setdefault(a, []).append(t)
        gaps = []
        for ts in per_app.values():
            ts = sorted(ts)
            gaps += [b - a for a, b in zip(ts, ts[1:])]
        return float(np.mean(gaps)) if gaps else 1.0


def _predict_times(times, rng, deviation: float, scale_ms: float,
                   residuals: List[float]) -> List[float]:
    """The paper's prediction protocol over one tenant's arrival times:
    drop each with probability ``deviation/2`` (unexpected requests),
    jitter the rest by N(0, ``deviation·scale_ms``).  Draw order is part
    of the seeded contract — one ``rng.random()`` then (if kept) one
    ``rng.normal()`` per arrival."""
    preds: List[float] = []
    for t in times:
        if rng.random() < deviation / 2:
            continue  # dropped prediction -> unexpected request
        jitter = rng.normal(0.0, deviation * scale_ms)
        preds.append(float(t + jitter))
        residuals.append(abs(jitter))
    preds.sort()
    return preds


def _finalize(requests: List[Tuple[float, str]],
              predictions: Dict[str, List[float]],
              residuals: List[float], actual_iats: List[float],
              pred_iats: List[float], tail_ms: float,
              deviation: float) -> Workload:
    requests.sort()
    horizon = max(t for t, _ in requests) + tail_ms
    D = float(np.mean(residuals)) if residuals else 0.0
    sigma = float(np.std(residuals)) if residuals else 0.0
    kl = _kl_divergence(np.asarray(actual_iats), np.asarray(pred_iats))
    return Workload(requests, predictions, horizon, deviation, D, sigma, kl)


def generate_workload(
    apps: List[str],
    *,
    requests_per_app: int = 60,
    mean_iat_ms: float = 8000.0,
    deviation: float = 0.3,
    seed: int = 0,
) -> Workload:
    rng = np.random.default_rng(seed)
    requests: List[Tuple[float, str]] = []
    predictions: Dict[str, List[float]] = {}
    residuals: List[float] = []
    actual_iats: List[float] = []
    pred_iats: List[float] = []
    for a in apps:
        gaps = rng.exponential(mean_iat_ms, requests_per_app)
        times = np.cumsum(gaps)
        actual_iats += list(gaps)
        requests += [(float(t), a) for t in times]
        predictions[a] = _predict_times(times, rng, deviation,
                                        mean_iat_ms, residuals)
        pred_iats += list(np.diff(predictions[a]))
    return _finalize(requests, predictions, residuals, actual_iats,
                     pred_iats, mean_iat_ms, deviation)


def generate_flash_crowd(
    apps: List[str],
    *,
    requests_per_app: int = 20,
    base_iat_ms: float = 8000.0,
    burst_app: Optional[str] = None,
    burst_at_ms: Optional[float] = None,
    burst_requests: int = 40,
    burst_iat_ms: float = 100.0,
    deviation: float = 0.3,
    seed: int = 0,
) -> Workload:
    """Poisson baseline plus one tenant's flash crowd: a dense burst of
    ``burst_requests`` arrivals at ``burst_iat_ms`` mean spacing,
    starting at ``burst_at_ms`` (default: a quarter into the trace), on
    ``burst_app`` (default: the first app).

    The burst is part of the *actual* stream but never of the predicted
    one — a flash crowd is by definition the load the per-tenant
    predictor did not see coming, which is exactly what the cluster
    tier's spill/hand-off path exists to absorb.
    """
    rng = np.random.default_rng(seed)
    requests: List[Tuple[float, str]] = []
    predictions: Dict[str, List[float]] = {}
    residuals: List[float] = []
    actual_iats: List[float] = []
    pred_iats: List[float] = []
    target = burst_app if burst_app is not None else apps[0]
    if target not in apps:
        raise ValueError(f"burst_app {target!r} not in apps")
    start = (burst_at_ms if burst_at_ms is not None
             else 0.25 * requests_per_app * base_iat_ms)
    for a in apps:
        gaps = rng.exponential(base_iat_ms, requests_per_app)
        times = list(np.cumsum(gaps))
        actual_iats += list(gaps)
        predictions[a] = _predict_times(times, rng, deviation,
                                        base_iat_ms, residuals)
        pred_iats += list(np.diff(predictions[a]))
        if a == target:
            bgaps = rng.exponential(burst_iat_ms, burst_requests)
            times = sorted(times + list(start + np.cumsum(bgaps)))
            actual_iats += list(bgaps)
        requests += [(float(t), a) for t in times]
    return _finalize(requests, predictions, residuals, actual_iats,
                     pred_iats, base_iat_ms, deviation)


def generate_diurnal(
    apps: List[str],
    *,
    requests_per_app: int = 60,
    mean_iat_ms: float = 8000.0,
    period_ms: Optional[float] = None,
    amplitude: float = 0.8,
    deviation: float = 0.3,
    seed: int = 0,
) -> Workload:
    """Diurnal (sinusoidal-rate) Poisson arrivals by thinning: the
    instantaneous rate is ``(1 + amplitude·sin(2πt/period)) /
    mean_iat_ms``, so load swells and ebbs around the Poisson baseline
    — the edge fleet's day/night cycle.  ``period_ms`` defaults to
    ``20·mean_iat_ms`` (a few peaks per trace).  Predictions follow the
    same protocol as :func:`generate_workload` over the thinned stream.
    """
    if not 0.0 <= amplitude < 1.0:
        raise ValueError(f"amplitude must be in [0, 1), got {amplitude}")
    period = period_ms if period_ms is not None else 20.0 * mean_iat_ms
    rng = np.random.default_rng(seed)
    requests: List[Tuple[float, str]] = []
    predictions: Dict[str, List[float]] = {}
    residuals: List[float] = []
    actual_iats: List[float] = []
    pred_iats: List[float] = []
    lam_max = (1.0 + amplitude) / mean_iat_ms
    for a in apps:
        times: List[float] = []
        t = 0.0
        prev = 0.0
        while len(times) < requests_per_app:
            t += rng.exponential(1.0 / lam_max)
            lam = (1.0 + amplitude * math.sin(2.0 * math.pi * t / period)
                   ) / mean_iat_ms
            if rng.random() < lam / lam_max:
                times.append(t)
                actual_iats.append(t - prev)
                prev = t
        requests += [(float(tt), a) for tt in times]
        predictions[a] = _predict_times(times, rng, deviation,
                                        mean_iat_ms, residuals)
        pred_iats += list(np.diff(predictions[a]))
    return _finalize(requests, predictions, residuals, actual_iats,
                     pred_iats, mean_iat_ms, deviation)


def generate_zoo(
    apps: List[str],
    *,
    requests_per_app: int = 60,
    mean_iat_ms: float = 8000.0,
    period_ms: Optional[float] = None,
    amplitude: float = 0.5,
    burst_app: Optional[str] = None,
    burst_at_ms: Optional[float] = None,
    burst_requests: int = 0,
    burst_iat_ms: float = 100.0,
    deviation: float = 0.3,
    seed: int = 0,
) -> Workload:
    """Vectorized workload zoo: diurnal (sinusoidal-rate) Poisson
    arrivals for every tenant plus an optional flash crowd on one — the
    mixed stream large-scale engine replays use.  All draws are batched
    numpy calls, so a 10^5-request trace materializes in milliseconds
    instead of the per-arrival python loops of
    :func:`generate_diurnal` / :func:`generate_flash_crowd` (whose
    seeded draw orders are contractual and therefore untouched).

    Draw-order contract (seeded, per app in ``apps`` order): rounds of
    one ``rng.exponential(1/λmax, K)`` batch then one ``rng.random(K)``
    batch until ``requests_per_app`` thinned arrivals accumulate; then
    one ``rng.random(n)`` batch and one ``rng.normal(0, σ, n)`` batch
    for the prediction protocol (jitter is drawn for every arrival and
    masked, unlike the scalar generators' draw-per-kept); finally, for
    the burst tenant, one ``rng.exponential(burst_iat_ms,
    burst_requests)`` batch.  Like :func:`generate_flash_crowd`, burst
    arrivals never enter the predicted stream.
    """
    if not 0.0 <= amplitude < 1.0:
        raise ValueError(f"amplitude must be in [0, 1), got {amplitude}")
    period = period_ms if period_ms is not None else 20.0 * mean_iat_ms
    target = burst_app if burst_app is not None else apps[0]
    if burst_requests and target not in apps:
        raise ValueError(f"burst_app {target!r} not in apps")
    start = (burst_at_ms if burst_at_ms is not None
             else 0.25 * requests_per_app * mean_iat_ms)
    rng = np.random.default_rng(seed)
    lam_max = (1.0 + amplitude) / mean_iat_ms
    # Candidate batch sized so one round almost always suffices: the
    # thinning acceptance rate averages 1/(1+amplitude).
    batch = int(requests_per_app * (1.0 + amplitude) * 1.25) + 16
    requests: List[Tuple[float, str]] = []
    predictions: Dict[str, List[float]] = {}
    residuals: List[float] = []
    actual_iats: List[float] = []
    pred_iats: List[float] = []
    for a in apps:
        kept = np.empty(0)
        t0 = 0.0
        while kept.size < requests_per_app:
            cand = t0 + np.cumsum(rng.exponential(1.0 / lam_max, batch))
            lam = (1.0 + amplitude * np.sin(2.0 * np.pi * cand / period)
                   ) / mean_iat_ms
            kept = np.concatenate(
                [kept, cand[rng.random(batch) < lam / lam_max]])
            t0 = float(cand[-1])
        times = kept[:requests_per_app]
        actual_iats += list(np.diff(times, prepend=0.0))
        # Vectorized prediction protocol: drop w.p. deviation/2, jitter
        # the survivors by N(0, deviation·mean_iat).
        keep = rng.random(times.size) >= deviation / 2
        jitter = rng.normal(0.0, deviation * mean_iat_ms, times.size)
        preds = np.sort((times + jitter)[keep])
        residuals += list(np.abs(jitter[keep]))
        predictions[a] = [float(p) for p in preds]
        pred_iats += list(np.diff(preds))
        if burst_requests and a == target:
            bgaps = rng.exponential(burst_iat_ms, burst_requests)
            times = np.sort(np.concatenate(
                [times, start + np.cumsum(bgaps)]))
            actual_iats += list(bgaps)
        requests += [(float(t), a) for t in times]
    return _finalize(requests, predictions, residuals, actual_iats,
                     pred_iats, mean_iat_ms, deviation)


def _kl_divergence(p_samples: np.ndarray, q_samples: np.ndarray,
                   bins: int = 30) -> float:
    """Histogram KL(actual ‖ predicted) over inter-arrival distributions."""
    if len(p_samples) == 0 or len(q_samples) == 0:
        return float("inf")
    hi = float(max(p_samples.max(), q_samples.max()))
    edges = np.linspace(0.0, hi + 1e-9, bins + 1)
    p, _ = np.histogram(p_samples, edges)
    q, _ = np.histogram(q_samples, edges)
    p = (p + 1e-3) / (p.sum() + 1e-3 * bins)
    q = (q + 1e-3) / (q.sum() + 1e-3 * bins)
    return float(np.sum(p * np.log(p / q)))


# ---------------------------------------------------------------------------
@dataclass
class SimResult:
    metrics: Metrics
    workload: Workload
    mean_concurrency: float
    policy: str


def simulate(
    zoos: Dict[str, ModelZoo],
    workload: Workload,
    *,
    policy: str = "iws-bfe",
    budget_mb: float = 1200.0,
    alpha: float = 1.0,
    delta_ms: Optional[float] = None,
    history_ms: Optional[float] = None,
) -> SimResult:
    # Δ is a *system* parameter profiled at nominal prediction accuracy
    # (the paper: "obtained from profiling past request predictions");
    # the robustness experiments then vary the *test* deviation while Δ
    # stays fixed.  When not supplied, calibrate from this workload.
    delta = (delta_ms if delta_ms is not None
             else max(workload.delta(alpha), 1.0))
    # H = mean inter-arrival of the *merged* request stream (the LRU-K
    # "recently requested" horizon): per-app IAT divided by tenant count.
    history = (history_ms if history_ms is not None
               else workload.mean_iat / max(len(zoos), 1))
    mgr = EdgeMultiAI(zoos, budget_mb, policy=policy, delta_ms=delta,
                      history_ms=history)

    # Build the event heap: (t, priority, kind, app, payload)
    events: List[Tuple[float, int, str, str, float]] = []
    for t, a in workload.requests:
        heapq.heappush(events, (t, 1, "request", a, t))
    for a, preds in workload.predictions.items():
        theta = zoos[a].largest.load_ms
        for tp in preds:
            trig = tp - delta - theta
            heapq.heappush(events, (trig, 0, "proactive", a, tp))

    # Lazily advance each tenant's "next prediction" pointer.
    pred_ptr = {a: 0 for a in zoos}

    def refresh_predictions(now: float) -> None:
        for a, preds in workload.predictions.items():
            i = pred_ptr[a]
            while i < len(preds) and preds[i] + delta < now:
                i += 1
            pred_ptr[a] = i
            mgr.set_prediction(a, preds[i] if i < len(preds) else math.inf)

    # Mean concurrency = time-average of |A*| (apps inside their window).
    conc_acc, conc_t, last_t = 0.0, 0.0, 0.0

    while events:
        t, _, kind, app, payload = heapq.heappop(events)
        refresh_predictions(t)
        n_act = len(mgr.state.maximalist_set(t, delta))
        conc_acc += n_act * max(t - last_t, 0.0)
        conc_t += max(t - last_t, 0.0)
        last_t = t
        if kind == "proactive":
            mgr.set_prediction(app, payload)
            mgr.proactive_load(app, t)
        else:
            mgr.on_request(app, t)

    mean_conc = conc_acc / conc_t if conc_t else 0.0
    return SimResult(mgr.metrics(), workload, mean_conc, policy)


def sweep_policies(
    zoos: Dict[str, ModelZoo],
    *,
    deviations: Tuple[float, ...] = (0.0, 0.3, 0.6, 0.9),
    policies: Tuple[str, ...] = ("lfe", "bfe", "ws-bfe", "iws-bfe"),
    budget_mb: float = 1200.0,
    requests_per_app: int = 60,
    mean_iat_ms: float = 8000.0,
    seeds: Tuple[int, ...] = (0, 1, 2),
) -> Dict[str, Dict[float, dict]]:
    """Cross product used by the Fig 5/6/8 benchmarks."""
    out: Dict[str, Dict[float, dict]] = {p: {} for p in policies}
    apps = list(zoos)
    # Fixed system Δ: calibrated once at the nominal deviation (the
    # production predictor's accuracy), then held while test deviation
    # sweeps — this is what the paper's robustness axis measures.
    calib = generate_workload(
        apps, requests_per_app=requests_per_app,
        mean_iat_ms=mean_iat_ms, deviation=0.15, seed=max(seeds) + 1)
    delta_ms = calib.delta(1.0)
    for d in deviations:
        for p in policies:
            agg = {"cold": [], "warm": [], "fail": [], "acc": [],
                   "rob": [], "kl": []}
            for s in seeds:
                wl = generate_workload(
                    apps, requests_per_app=requests_per_app,
                    mean_iat_ms=mean_iat_ms, deviation=d, seed=s)
                res = simulate(zoos, wl, policy=p, budget_mb=budget_mb,
                               delta_ms=delta_ms)
                m = res.metrics
                agg["cold"].append(m.cold_ratio)
                agg["warm"].append(m.warm_ratio)
                agg["fail"].append(m.fail_ratio)
                agg["acc"].append(m.mean_accuracy())
                agg["rob"].append(m.robustness())
                agg["kl"].append(wl.kl)
            out[p][d] = {k: float(np.mean(v)) for k, v in agg.items()}
    return out
