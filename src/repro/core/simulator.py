"""E2C-style discrete-event workload simulator (§IV-A).

Reproduces the paper's evaluation protocol:

* per-application request streams with exponential inter-arrival times,
  equal request counts per app;
* a *predicted* workload derived from the actual one with a controlled
  deviation knob ``d`` — per-request Gaussian jitter of std ``d·IAT`` plus
  prediction drop-outs with probability ``d/2`` (the paper's "unexpected
  requests"); the realized divergence is reported as KL between actual
  and predicted inter-arrival distributions, as in the paper;
* Δ estimated from prediction residuals as ``D + α·σ`` (Fig 7 sweeps α);
* an event loop that fires proactive-load triggers at ``t_pred − Δ − θ``
  and actual requests in timestamp order.
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.manager import EdgeMultiAI, Metrics
from repro.core.model_zoo import ModelZoo


@dataclass
class Workload:
    requests: List[Tuple[float, str]]  # (t, app) sorted by t
    predictions: Dict[str, List[float]]  # app -> predicted request times
    horizon_ms: float
    deviation: float
    delta_D: float  # mean |residual| over matched prediction pairs
    delta_sigma: float  # std of residuals
    kl: float  # realized KL(actual ‖ predicted) inter-arrival divergence

    def delta(self, alpha: float = 1.0) -> float:
        return self.delta_D + alpha * self.delta_sigma

    @property
    def mean_iat(self) -> float:
        per_app: Dict[str, List[float]] = {}
        for t, a in self.requests:
            per_app.setdefault(a, []).append(t)
        gaps = []
        for ts in per_app.values():
            ts = sorted(ts)
            gaps += [b - a for a, b in zip(ts, ts[1:])]
        return float(np.mean(gaps)) if gaps else 1.0


def generate_workload(
    apps: List[str],
    *,
    requests_per_app: int = 60,
    mean_iat_ms: float = 8000.0,
    deviation: float = 0.3,
    seed: int = 0,
) -> Workload:
    rng = np.random.default_rng(seed)
    requests: List[Tuple[float, str]] = []
    predictions: Dict[str, List[float]] = {}
    residuals: List[float] = []
    actual_iats: List[float] = []
    pred_iats: List[float] = []
    for a in apps:
        gaps = rng.exponential(mean_iat_ms, requests_per_app)
        times = np.cumsum(gaps)
        actual_iats += list(gaps)
        requests += [(float(t), a) for t in times]
        preds = []
        for t in times:
            if rng.random() < deviation / 2:
                continue  # dropped prediction -> unexpected request
            jitter = rng.normal(0.0, deviation * mean_iat_ms)
            preds.append(float(t + jitter))
            residuals.append(abs(jitter))
        preds.sort()
        predictions[a] = preds
        pred_iats += list(np.diff(preds))
    requests.sort()
    horizon = max(t for t, _ in requests) + mean_iat_ms
    D = float(np.mean(residuals)) if residuals else 0.0
    sigma = float(np.std(residuals)) if residuals else 0.0
    kl = _kl_divergence(np.asarray(actual_iats), np.asarray(pred_iats))
    return Workload(requests, predictions, horizon, deviation, D, sigma, kl)


def _kl_divergence(p_samples: np.ndarray, q_samples: np.ndarray,
                   bins: int = 30) -> float:
    """Histogram KL(actual ‖ predicted) over inter-arrival distributions."""
    if len(p_samples) == 0 or len(q_samples) == 0:
        return float("inf")
    hi = float(max(p_samples.max(), q_samples.max()))
    edges = np.linspace(0.0, hi + 1e-9, bins + 1)
    p, _ = np.histogram(p_samples, edges)
    q, _ = np.histogram(q_samples, edges)
    p = (p + 1e-3) / (p.sum() + 1e-3 * bins)
    q = (q + 1e-3) / (q.sum() + 1e-3 * bins)
    return float(np.sum(p * np.log(p / q)))


# ---------------------------------------------------------------------------
@dataclass
class SimResult:
    metrics: Metrics
    workload: Workload
    mean_concurrency: float
    policy: str


def simulate(
    zoos: Dict[str, ModelZoo],
    workload: Workload,
    *,
    policy: str = "iws-bfe",
    budget_mb: float = 1200.0,
    alpha: float = 1.0,
    delta_ms: Optional[float] = None,
    history_ms: Optional[float] = None,
) -> SimResult:
    # Δ is a *system* parameter profiled at nominal prediction accuracy
    # (the paper: "obtained from profiling past request predictions");
    # the robustness experiments then vary the *test* deviation while Δ
    # stays fixed.  When not supplied, calibrate from this workload.
    delta = (delta_ms if delta_ms is not None
             else max(workload.delta(alpha), 1.0))
    # H = mean inter-arrival of the *merged* request stream (the LRU-K
    # "recently requested" horizon): per-app IAT divided by tenant count.
    history = (history_ms if history_ms is not None
               else workload.mean_iat / max(len(zoos), 1))
    mgr = EdgeMultiAI(zoos, budget_mb, policy=policy, delta_ms=delta,
                      history_ms=history)

    # Build the event heap: (t, priority, kind, app, payload)
    events: List[Tuple[float, int, str, str, float]] = []
    for t, a in workload.requests:
        heapq.heappush(events, (t, 1, "request", a, t))
    for a, preds in workload.predictions.items():
        theta = zoos[a].largest.load_ms
        for tp in preds:
            trig = tp - delta - theta
            heapq.heappush(events, (trig, 0, "proactive", a, tp))

    # Lazily advance each tenant's "next prediction" pointer.
    pred_ptr = {a: 0 for a in zoos}

    def refresh_predictions(now: float) -> None:
        for a, preds in workload.predictions.items():
            i = pred_ptr[a]
            while i < len(preds) and preds[i] + delta < now:
                i += 1
            pred_ptr[a] = i
            mgr.set_prediction(a, preds[i] if i < len(preds) else math.inf)

    # Mean concurrency = time-average of |A*| (apps inside their window).
    conc_acc, conc_t, last_t = 0.0, 0.0, 0.0

    while events:
        t, _, kind, app, payload = heapq.heappop(events)
        refresh_predictions(t)
        n_act = len(mgr.state.maximalist_set(t, delta))
        conc_acc += n_act * max(t - last_t, 0.0)
        conc_t += max(t - last_t, 0.0)
        last_t = t
        if kind == "proactive":
            mgr.set_prediction(app, payload)
            mgr.proactive_load(app, t)
        else:
            mgr.on_request(app, t)

    mean_conc = conc_acc / conc_t if conc_t else 0.0
    return SimResult(mgr.metrics(), workload, mean_conc, policy)


def sweep_policies(
    zoos: Dict[str, ModelZoo],
    *,
    deviations: Tuple[float, ...] = (0.0, 0.3, 0.6, 0.9),
    policies: Tuple[str, ...] = ("lfe", "bfe", "ws-bfe", "iws-bfe"),
    budget_mb: float = 1200.0,
    requests_per_app: int = 60,
    mean_iat_ms: float = 8000.0,
    seeds: Tuple[int, ...] = (0, 1, 2),
) -> Dict[str, Dict[float, dict]]:
    """Cross product used by the Fig 5/6/8 benchmarks."""
    out: Dict[str, Dict[float, dict]] = {p: {} for p in policies}
    apps = list(zoos)
    # Fixed system Δ: calibrated once at the nominal deviation (the
    # production predictor's accuracy), then held while test deviation
    # sweeps — this is what the paper's robustness axis measures.
    calib = generate_workload(
        apps, requests_per_app=requests_per_app,
        mean_iat_ms=mean_iat_ms, deviation=0.15, seed=max(seeds) + 1)
    delta_ms = calib.delta(1.0)
    for d in deviations:
        for p in policies:
            agg = {"cold": [], "warm": [], "fail": [], "acc": [],
                   "rob": [], "kl": []}
            for s in seeds:
                wl = generate_workload(
                    apps, requests_per_app=requests_per_app,
                    mean_iat_ms=mean_iat_ms, deviation=d, seed=s)
                res = simulate(zoos, wl, policy=p, budget_mb=budget_mb,
                               delta_ms=delta_ms)
                m = res.metrics
                agg["cold"].append(m.cold_ratio)
                agg["warm"].append(m.warm_ratio)
                agg["fail"].append(m.fail_ratio)
                agg["acc"].append(m.mean_accuracy())
                agg["rob"].append(m.robustness())
                agg["kl"].append(wl.kl)
            out[p][d] = {k: float(np.mean(v)) for k, v in agg.items()}
    return out
