"""Dispatching wrappers around the perf-critical kernels.

``impl`` resolution:
  * "pallas"    — the Pallas TPU kernels (compiled on TPU; ``interpret=True``
                  execution on CPU for validation).
  * "reference" — the pure-jnp oracles in :mod:`repro.kernels.ref`.
  * "auto"      — pallas on TPU backends, reference elsewhere.  The dry-run /
                  roofline path always lowers the reference graph (Pallas TPU
                  kernels cannot lower on the CPU backend), which is
                  mathematically identical.

Models call these entry points only; nothing below this layer leaks upward.
"""
from __future__ import annotations

import os
from typing import Optional

import jax

from . import ref

_FORCED: Optional[str] = os.environ.get("REPRO_KERNEL_IMPL") or None


def set_impl(impl: Optional[str]) -> None:
    """Force "pallas" / "reference" globally (None restores auto)."""
    global _FORCED
    _FORCED = impl


def resolve_impl(impl: str = "auto") -> str:
    if _FORCED is not None:
        return _FORCED
    if impl != "auto":
        return impl
    return "pallas" if jax.default_backend() == "tpu" else "reference"


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0, scale=0.0,
                    q_offset=0, prefix=0, impl="auto"):
    if resolve_impl(impl) == "pallas":
        from . import flash_attention as fa

        return fa.flash_attention(
            q, k, v, causal=causal, window=window, softcap=softcap,
            scale=scale, q_offset=q_offset, prefix=prefix,
            interpret=_interpret())
    return ref.flash_attention(
        q, k, v, causal=causal, window=window, softcap=softcap, scale=scale,
        q_offset=q_offset, prefix=prefix)


def decode_attention(q, k_cache, v_cache, lengths, *, window=0, softcap=0.0,
                     scale=0.0, prefix=0, impl="auto"):
    if resolve_impl(impl) == "pallas":
        from . import decode_attention as da

        return da.decode_attention(
            q, k_cache, v_cache, lengths, window=window, softcap=softcap,
            scale=scale, prefix=prefix, interpret=_interpret())
    return ref.decode_attention(
        q, k_cache, v_cache, lengths, window=window, softcap=softcap,
        scale=scale, prefix=prefix)


def paged_decode_attention(q, k_pages, v_pages, page_table, lengths, *,
                           window=0, softcap=0.0, scale=0.0, prefix=0,
                           impl="auto"):
    if resolve_impl(impl) == "pallas":
        from . import decode_attention as da

        return da.paged_decode_attention(
            q, k_pages, v_pages, page_table, lengths, window=window,
            softcap=softcap, scale=scale, prefix=prefix,
            interpret=_interpret())
    return ref.paged_decode_attention(
        q, k_pages, v_pages, page_table, lengths, window=window,
        softcap=softcap, scale=scale, prefix=prefix)


def quant_matmul(x, w_q, scales, *, out_dtype=None, impl="auto"):
    if resolve_impl(impl) == "pallas":
        from . import quant_matmul as qm

        return qm.quant_matmul(
            x, w_q, scales, out_dtype=out_dtype, interpret=_interpret())
    return ref.quant_matmul(x, w_q, scales, out_dtype=out_dtype)


def ssd_scan(x, dt, A, Bm, Cm, D, *, init_state=None, return_state=False,
             chunk=256, impl="auto"):
    if resolve_impl(impl) == "pallas":
        from . import ssd_scan as ssd

        return ssd.ssd_scan(
            x, dt, A, Bm, Cm, D, init_state=init_state,
            return_state=return_state, chunk=chunk, interpret=_interpret())
    return ref.ssd_scan_chunked(
        x, dt, A, Bm, Cm, D, init_state=init_state,
        return_state=return_state, chunk=chunk)


# Thin passthroughs (no kernel needed; kept here so models never import ref).
ssd_step = ref.ssd_step
causal_conv1d = ref.causal_conv1d
causal_conv1d_step = ref.causal_conv1d_step
quantize_weights = ref.quantize_weights
