"""Pallas TPU Mamba-2 SSD scan (chunked state-space duality).

The paper's SSM tenants (mamba2, hymba) spend their FLOPs here.  The SSD
trick converts the elementwise recurrence into MXU-shaped work: a
quadratic *intra-chunk* block (attention-like (Q,Q)·(Q,P) matmuls) plus a
linear *inter-chunk* state recurrence — this kernel fuses both so the
(H, P, N) state never round-trips to HBM between chunks.

TPU mapping
-----------
* Grid ``(B, H, nc)`` with the chunk index innermost; the per-(b, h) SSM
  state (P, N) lives in VMEM scratch across the whole chunk loop.
* Per-head decay scalars A[h], D[h] arrive via SMEM scalar prefetch.
* Tiles at (Q, P, N) = (256, 64, 128): x 256·64·4B + B/C 2·256·128·4B +
  decay matrix 256·256·4B + state 64·128·4B ≈ 0.7 MB VMEM.
* The intra-chunk cumulative decay uses a lower-triangular ones matmul
  (MXU) rather than a lane scan.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(a_ref, d_ref, x_ref, dt_ref, b_ref, c_ref, init_ref,
                y_ref, state_ref, state_scr, *, nc, Q):
    h, ic = pl.program_id(1), pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_scr[...] = init_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, 0].astype(jnp.float32)  # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32).reshape(Q, 1)  # (Q, 1)
    Bm = b_ref[0, 0].astype(jnp.float32)  # (Q, N)
    Cm = c_ref[0, 0].astype(jnp.float32)  # (Q, N)
    A = a_ref[h]
    Dk = d_ref[h]

    a = dt * A  # (Q, 1) log-decay per step
    # Inclusive cumulative sum via lower-triangular ones matmul (MXU).
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    tril = (ii >= jj).astype(jnp.float32)
    a_cum = jax.lax.dot_general(
        tril, a, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)  # (Q, 1)

    # Intra-chunk (attention-like) term.
    seg = a_cum - a_cum.reshape(1, Q)  # (Qi, Qj)
    L = jnp.where(ii >= jj, jnp.exp(seg), 0.0)
    cb = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)  # (Q, Q)
    M = cb * L * dt.reshape(1, Q)  # dt at the key position
    y = jax.lax.dot_general(
        M, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)  # (Q, P)

    # Inter-chunk contribution from the carried state.
    state = state_scr[...]  # (P, N)
    y += jnp.exp(a_cum) * jax.lax.dot_general(
        Cm, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)  # (Q, P)

    # State update: decay to chunk end + new outer products.
    a_end = a_cum[Q - 1:Q, :]  # (1, 1)
    w = jnp.exp(a_end - a_cum) * dt  # (Q, 1)
    state_scr[...] = jnp.exp(a_end) * state + jax.lax.dot_general(
        x, Bm * w, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)  # (P, N)

    y_ref[0, 0] = (y + x * Dk).astype(y_ref.dtype)

    @pl.when(ic == nc - 1)
    def _finish():
        state_ref[0, 0] = state_scr[...]


def ssd_scan(
    x: jnp.ndarray,  # (B, S, H, P)
    dt: jnp.ndarray,  # (B, S, H)
    A: jnp.ndarray,  # (H,)
    Bm: jnp.ndarray,  # (B, S, G, N)
    Cm: jnp.ndarray,  # (B, S, G, N)
    D: jnp.ndarray,  # (H,)
    *,
    init_state: Optional[jnp.ndarray] = None,
    return_state: bool = False,
    chunk: int = 256,
    interpret: bool = False,
):
    Bb, S0, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Q = min(chunk, S0)
    pad = (Q - S0 % Q) % Q
    xt = jnp.moveaxis(x, (0, 2, 1, 3), (0, 1, 2, 3))  # (B, H, S, P)
    dtt = jnp.moveaxis(dt, (0, 2, 1), (0, 1, 2))  # (B, H, S)
    bt = jnp.moveaxis(Bm, (0, 2, 1, 3), (0, 1, 2, 3))  # (B, G, S, N)
    ct = jnp.moveaxis(Cm, (0, 2, 1, 3), (0, 1, 2, 3))
    if pad:
        # dt=0 padding is exact: decay 1, zero contribution.
        xt = jnp.pad(xt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        dtt = jnp.pad(dtt, ((0, 0), (0, 0), (0, pad)))
        bt = jnp.pad(bt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        ct = jnp.pad(ct, ((0, 0), (0, 0), (0, pad), (0, 0)))
    S = S0 + pad
    nc = S // Q
    if init_state is None:
        init_state = jnp.zeros((Bb, H, P, N), jnp.float32)

    kernel = functools.partial(_ssd_kernel, nc=nc, Q=Q)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(Bb, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, h, c, *_: (b, h, c, 0)),
            pl.BlockSpec((1, 1, Q), lambda b, h, c, *_: (b, h, c)),
            pl.BlockSpec((1, 1, Q, N),
                         lambda b, h, c, *_, rep=rep: (b, h // rep, c, 0)),
            pl.BlockSpec((1, 1, Q, N),
                         lambda b, h, c, *_, rep=rep: (b, h // rep, c, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c, *_: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, h, c, *_: (b, h, c, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c, *_: (b, h, 0, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
    )
    y, state = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((Bb, H, S, P), x.dtype),
            jax.ShapeDtypeStruct((Bb, H, P, N), jnp.float32),
        ],
        interpret=interpret,
    )(A.astype(jnp.float32), D.astype(jnp.float32),
      xt, dtt, bt, ct, init_state)
    y = jnp.moveaxis(y[:, :, :S0, :], (0, 1, 2, 3), (0, 2, 1, 3))
    if return_state:
        return y, state
    return y
