"""Pallas TPU decode attention: one query token per sequence against a long
KV cache (GQA), with per-sequence valid lengths, sliding window and softcap.

TPU mapping
-----------
* Grid ``(B, KV, nT)``: the KV-cache sequence dim iterates innermost in
  blocks of ``block_t``; the (G, D) query group for this kv-head rides in
  VMEM the whole time.  Running max / denom / accumulator scratch carries
  the online softmax across KV blocks — a single pass over the cache, the
  memory-bound regime decode lives in (roofline: bytes ≈ KV-cache size).
* Per-sequence ``lengths`` arrive via scalar prefetch (SMEM) so the mask
  needs no HBM traffic; fully-invalid tail blocks still iterate but write
  nothing (a block-skip map is a future optimization, noted in §Perf).
* G·D and block_t are lane-aligned; with (G, D, bt) = (8, 128, 512) the
  VMEM working set is ≈ 0.8 MB.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.3819763e38


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *,
                   bt, nt, scale, window, softcap, prefix):
    b, it = pl.program_id(0), pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale  # (G, D)
    k = k_ref[0, 0].astype(jnp.float32)  # (bt, D)
    v = v_ref[0, 0].astype(jnp.float32)  # (bt, D)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)  # (G, bt)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap

    length = len_ref[b]
    kv_pos = it * bt + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = kv_pos < length
    if window:
        win_ok = kv_pos >= length - window
        if prefix:
            win_ok |= kv_pos < prefix
        mask &= win_ok
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(it == nt - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def decode_attention(
    q: jnp.ndarray,  # (B, H, D)
    k_cache: jnp.ndarray,  # (B, T, KV, D)
    v_cache: jnp.ndarray,  # (B, T, KV, D)
    lengths: jnp.ndarray,  # (B,) int32
    *,
    window: int = 0,
    softcap: float = 0.0,
    scale: float = 0.0,
    prefix: int = 0,
    block_t: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    B, H, D = q.shape
    _, T, KV, _ = k_cache.shape
    G = H // KV
    if scale == 0.0:
        scale = D ** -0.5
    bt = min(block_t, T)
    Tp = math.ceil(T / bt) * bt
    qg = q.reshape(B, KV, G, D)
    kt = jnp.moveaxis(k_cache, (0, 2, 1, 3), (0, 1, 2, 3))  # (B, KV, T, D)
    vt = jnp.moveaxis(v_cache, (0, 2, 1, 3), (0, 1, 2, 3))
    if Tp != T:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, Tp - T), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, Tp - T), (0, 0)))
    nt = Tp // bt

    kernel = functools.partial(
        _decode_kernel, bt=bt, nt=nt, scale=scale, window=window,
        softcap=softcap, prefix=prefix)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, KV, nt),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, t, *_: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bt, D), lambda b, h, t, *_: (b, h, t, 0)),
            pl.BlockSpec((1, 1, bt, D), lambda b, h, t, *_: (b, h, t, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, t, *_: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, D), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), qg, kt, vt)
    return out.reshape(B, H, D)


# ---------------------------------------------------------------------------
# Paged variant: the KV cache lives in a shared page pool instead of one
# contiguous (B, T, ...) buffer, and each sequence names its pages through
# a page table.  Same online-softmax body — the only change is *where*
# each KV block comes from: the k/v index maps gather through the
# scalar-prefetched table, so block ``t`` of sequence ``b`` reads physical
# page ``page_table[b, t]``.  Blocks past a sequence's valid length are
# masked exactly like the dense kernel's padded tail, so table entries
# beyond the last real page may point anywhere valid (tests use page 0).
# ---------------------------------------------------------------------------
def _paged_decode_kernel(table_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                         m_scr, l_scr, acc_scr, **kw):
    # The table ref is consumed by the BlockSpec index maps; the body is
    # the dense online-softmax pass over whatever block landed in VMEM.
    del table_ref
    _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, **kw)


def paged_decode_attention(
    q: jnp.ndarray,  # (B, H, D)
    k_pages: jnp.ndarray,  # (P, KV, page_size, D) — shared physical pool
    v_pages: jnp.ndarray,  # (P, KV, page_size, D)
    page_table: jnp.ndarray,  # (B, NP) int32 — logical block -> page id
    lengths: jnp.ndarray,  # (B,) int32 — valid tokens per sequence
    *,
    window: int = 0,
    softcap: float = 0.0,
    scale: float = 0.0,
    prefix: int = 0,
    interpret: bool = False,
) -> jnp.ndarray:
    B, H, D = q.shape
    _, KV, ps, _ = k_pages.shape
    NP = page_table.shape[1]
    G = H // KV
    if scale == 0.0:
        scale = D ** -0.5
    qg = q.reshape(B, KV, G, D)

    kernel = functools.partial(
        _paged_decode_kernel, bt=ps, nt=NP, scale=scale, window=window,
        softcap=softcap, prefix=prefix)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # page_table, lengths
        grid=(B, KV, NP),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, t, *_: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, ps, D),
                         lambda b, h, t, tab, lens: (tab[b, t], h, 0, 0)),
            pl.BlockSpec((1, 1, ps, D),
                         lambda b, h, t, tab, lens: (tab[b, t], h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, t, *_: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, D), q.dtype),
        interpret=interpret,
    )(page_table.astype(jnp.int32), lengths.astype(jnp.int32), qg,
      k_pages, v_pages)
    return out.reshape(B, H, D)


def paginate_kv(k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                lengths: jnp.ndarray, page_size: int, *,
                permute: bool = True):
    """Scatter a dense (B, T, KV, D) cache into a shared page pool.

    Test/bridge helper: returns ``(k_pages, v_pages, page_table)`` with
    pages laid out ``(P, KV, page_size, D)``.  With ``permute=True`` the
    physical page order is a deterministic non-identity permutation
    (stride walk), so kernel tests actually exercise the gather instead
    of reading pages in logical order.  Unused table entries point at
    page 0 (masked by ``lengths`` in the kernel)."""
    import numpy as np

    B, T, KV, D = k_cache.shape
    NP = math.ceil(T / page_size)
    Tp = NP * page_size
    if Tp != T:
        pad = ((0, 0), (0, Tp - T), (0, 0), (0, 0))
        k_cache = jnp.pad(k_cache, pad)
        v_cache = jnp.pad(v_cache, pad)
    # (B, NP, ps, KV, D) -> (B*NP, KV, ps, D): logical page (b, t) sits at
    # physical slot b*NP + t before permutation.
    k_lin = jnp.moveaxis(
        k_cache.reshape(B, NP, page_size, KV, D), 3, 2
    ).reshape(B * NP, KV, page_size, D)
    v_lin = jnp.moveaxis(
        v_cache.reshape(B, NP, page_size, KV, D), 3, 2
    ).reshape(B * NP, KV, page_size, D)
    P = B * NP
    if permute and P > 1:
        stride = max(2, P // 3) | 1  # odd -> coprime walk when P is 2^k
        while math.gcd(stride, P) != 1:
            stride += 2
        perm = np.arange(P) * stride % P  # perm[logical] = physical
    else:
        perm = np.arange(P)
    inv = np.empty(P, np.int64)
    inv[perm] = np.arange(P)
    k_pages = k_lin[inv]  # physical slot p holds logical page perm^-1...
    v_pages = v_lin[inv]
    table = perm.reshape(B, NP)
    # Entries past each sequence's last valid page -> page 0.
    lens = np.asarray(lengths)
    used = np.ceil(np.maximum(lens, 1) / page_size).astype(np.int64)
    col = np.arange(NP)[None, :]
    table = np.where(col < used[:, None], table, 0)
    return k_pages, v_pages, jnp.asarray(table, jnp.int32)
