"""Pallas TPU quantized matmul: bf16 activations × int8/int4 weights with
per-(K-group, N-column) symmetric scales, dequantized on the fly in VMEM.

This is the compute core of the paper's model-zoo idea on TPU: the low
precision variants are *served through this kernel*, so the ~2–4× weight
footprint saving (which is what the Edge-MultiAI manager trades on) comes
with HBM-bandwidth savings rather than a dequantize-to-HBM round trip.

The same int8-payload-plus-per-group-scales layout is the serving
stack's *wire format* too: ``LoaderSpec(compress="int8")`` stages loads
in it (``repro.distributed.compression.wire_compression_ratio`` prices
the transfer), and a ``Downgrade(in_place=True)`` in the residency IR
requantizes resident leaves into it on-chip — a variant switch that
moves zero bytes over the host link, because the weights this kernel
serves are exactly what :func:`quantize_params` derives from the wider
resident copy.

TPU mapping
-----------
* Grid ``(nM, nN, nK)``, K innermost; an f32 accumulator tile persists in
  VMEM scratch across the K loop and is flushed once per (M, N) tile.
* The weight tile is loaded as int8 (half/quarter the HBM bytes of bf16 —
  the whole point), upcast in-register, scaled by the per-group scale row,
  and fed to the MXU via ``dot_general`` with f32 accumulation.
* Block sizes default to (256, 256, 512); K blocks are chosen to divide
  the quantization group so each K tile sees exactly one scale row
  (``block_k = lcm(group, 128)`` handled by the wrapper).
* VMEM at defaults: x 256×512×2B + w 512×256×1B + acc 256×256×4B ≈ 0.6 MB.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _qmm_kernel(x_ref, w_ref, s_ref, o_ref, acc_scr, *, nk):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[...].astype(jnp.float32)  # (bm, bk)
    w = w_ref[...].astype(jnp.float32)  # (bk, bn) — dequant below
    s = s_ref[...].astype(jnp.float32)  # (gk, bn) scale rows for this K tile
    gk = s.shape[0]
    bk = w.shape[0]
    group = bk // gk
    w = w.reshape(gk, group, -1) * s[:, None, :]
    w = w.reshape(bk, -1)
    acc_scr[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _finish():
        o_ref[...] = acc_scr[...].astype(o_ref.dtype)


def quant_matmul(
    x: jnp.ndarray,  # (..., K) bf16/f32
    w_q: jnp.ndarray,  # (K, N) int8 (int4 values in int8 storage)
    scales: jnp.ndarray,  # (K // group, N) f32
    *,
    out_dtype=None,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    out_dtype = out_dtype or x.dtype
    K, N = w_q.shape
    G = scales.shape[0]
    group = K // G
    lead = x.shape[:-1]
    M = int(jnp.prod(jnp.array(lead))) if lead else 1
    x2 = x.reshape(M, K)

    bm = min(block_m, max(8, M))
    bn = min(block_n, N)
    # K blocks must hold an integer number of scale groups.
    bk = min(block_k, K)
    bk = max(group, (bk // group) * group)
    Mp = math.ceil(M / bm) * bm
    Np = math.ceil(N / bn) * bn
    Kp = math.ceil(K / bk) * bk
    if Mp != M:
        x2 = jnp.pad(x2, ((0, Mp - M), (0, 0)))
    if Kp != K or Np != N:
        x2 = jnp.pad(x2, ((0, 0), (0, Kp - K)))
        w_q = jnp.pad(w_q, ((0, Kp - K), (0, Np - N)))
        scales = jnp.pad(scales, ((0, (Kp - K) // group), (0, Np - N)))
    nm, nn, nk = Mp // bm, Np // bn, Kp // bk
    gk = bk // group  # scale rows per K tile

    kernel = functools.partial(_qmm_kernel, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((gk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x2, w_q, scales)
    return out[:M, :N].reshape(*lead, N)
