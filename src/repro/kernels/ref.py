"""Pure-jnp reference oracles for every Pallas kernel.

These are the mathematically authoritative implementations: the Pallas
kernels are validated against them (tests/test_kernels.py sweeps shapes and
dtypes), and the dry-run/roofline path lowers THESE, since Pallas TPU
kernels cannot be lowered on the CPU backend.  Everything here is plain
``jnp`` + ``lax`` and jit/grad-compatible.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -2.3819763e38  # close to bf16 min; avoids NaN from (-inf) - (-inf)


def _softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    return jnp.tanh(x / cap) * cap


# ---------------------------------------------------------------------------
# flash_attention: batched multi-head attention with GQA, causal masking,
# optional sliding window and logit soft-capping (gemma2 / hymba semantics).
# ---------------------------------------------------------------------------
def flash_attention(
    q: jnp.ndarray,  # (B, S, H, D)
    k: jnp.ndarray,  # (B, T, KV, D)
    v: jnp.ndarray,  # (B, T, KV, D)
    *,
    causal: bool = True,
    window: int = 0,  # 0 = unbounded
    softcap: float = 0.0,
    scale: float = 0.0,
    q_offset: int = 0,  # absolute position of q[0] (prefill continuation)
    prefix: int = 0,  # positions < prefix always visible (meta tokens)
) -> jnp.ndarray:
    B, S, H, D = q.shape
    _, T, KV, _ = k.shape
    G = H // KV
    if scale == 0.0:
        scale = D ** -0.5
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qg = qf.reshape(B, S, KV, G, D)
    # scores: (B, KV, G, S, T)
    s = jnp.einsum("bskgd,btkd->bkgst", qg, kf)
    if softcap:
        s = _softcap(s, softcap)
    q_pos = q_offset + jnp.arange(S)[:, None]  # (S, 1)
    kv_pos = jnp.arange(T)[None, :]  # (1, T)
    mask = jnp.ones((S, T), dtype=bool)
    if causal:
        mask &= kv_pos <= q_pos
    if window:
        mask &= (kv_pos > q_pos - window) | (kv_pos < prefix)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,btkd->bskgd", p, vf)
    return o.reshape(B, S, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# decode_attention: single-query-token attention against a long KV cache.
# ---------------------------------------------------------------------------
def decode_attention(
    q: jnp.ndarray,  # (B, H, D) — one new token per sequence
    k_cache: jnp.ndarray,  # (B, T, KV, D)
    v_cache: jnp.ndarray,  # (B, T, KV, D)
    lengths: jnp.ndarray,  # (B,) int32 — valid prefix length per sequence
    *,
    window: int = 0,
    softcap: float = 0.0,
    scale: float = 0.0,
    prefix: int = 0,
) -> jnp.ndarray:
    B, H, D = q.shape
    _, T, KV, _ = k_cache.shape
    G = H // KV
    if scale == 0.0:
        scale = D ** -0.5
    qf = q.astype(jnp.float32).reshape(B, KV, G, D) * scale
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)
    s = jnp.einsum("bkgd,btkd->bkgt", qf, kf)  # (B, KV, G, T)
    if softcap:
        s = _softcap(s, softcap)
    kv_pos = jnp.arange(T)[None, :]  # (1, T)
    valid = kv_pos < lengths[:, None]
    if window:
        valid &= (kv_pos >= (lengths[:, None] - window)) | (kv_pos < prefix)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgt,btkd->bkgd", p, vf)
    return o.reshape(B, H, D).astype(q.dtype)


def paged_decode_attention(
    q: jnp.ndarray,  # (B, H, D)
    k_pages: jnp.ndarray,  # (P, KV, page_size, D)
    v_pages: jnp.ndarray,  # (P, KV, page_size, D)
    page_table: jnp.ndarray,  # (B, NP) int32
    lengths: jnp.ndarray,  # (B,) int32
    *,
    window: int = 0,
    softcap: float = 0.0,
    scale: float = 0.0,
    prefix: int = 0,
) -> jnp.ndarray:
    """Oracle for the paged kernel: gather each sequence's pages back
    into a dense cache, then run the dense oracle.  Table entries past
    ``lengths`` may point anywhere (they are masked)."""
    B, NP = page_table.shape
    _, KV, ps, D = k_pages.shape
    # (B, NP, KV, ps, D) -> (B, NP, ps, KV, D) -> (B, NP*ps, KV, D)
    k = jnp.swapaxes(k_pages[page_table], 2, 3).reshape(B, NP * ps, KV, D)
    v = jnp.swapaxes(v_pages[page_table], 2, 3).reshape(B, NP * ps, KV, D)
    return decode_attention(q, k, v, lengths, window=window,
                            softcap=softcap, scale=scale, prefix=prefix)


# ---------------------------------------------------------------------------
# quant_matmul: activation @ dequantize(w_q, scales).
# Weights are stored int8 (int4 values occupy int8 storage in [-8, 7];
# bit-packing is a TPU-memory-layout concern handled inside the Pallas
# kernel, not in the oracle).  Scales are per (K-group, N-column).
# ---------------------------------------------------------------------------
def quant_matmul(
    x: jnp.ndarray,  # (..., K)
    w_q: jnp.ndarray,  # (K, N) int8
    scales: jnp.ndarray,  # (K // group, N) float
    *,
    out_dtype=None,
) -> jnp.ndarray:
    K, N = w_q.shape
    G = scales.shape[0]
    group = K // G
    out_dtype = out_dtype or x.dtype
    w = w_q.astype(jnp.float32).reshape(G, group, N) * scales.astype(
        jnp.float32
    )[:, None, :]
    w = w.reshape(K, N)
    y = jnp.einsum("...k,kn->...n", x.astype(jnp.float32), w)
    return y.astype(out_dtype)


def quantize_weights(
    w: jnp.ndarray, *, bits: int = 8, group: int = 128
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-(group, column) absmax quantization. w: (K, N)."""
    K, N = w.shape
    if K % group:
        group = K  # degenerate single group
    G = K // group
    wg = w.astype(jnp.float32).reshape(G, group, N)
    qmax = float(2 ** (bits - 1) - 1)
    absmax = jnp.max(jnp.abs(wg), axis=1)  # (G, N)
    scales = jnp.maximum(absmax / qmax, 1e-8)
    q = jnp.clip(jnp.round(wg / scales[:, None, :]), -qmax - 1, qmax)
    return q.reshape(K, N).astype(jnp.int8), scales.astype(jnp.float32)


# ---------------------------------------------------------------------------
# ssd_scan: Mamba-2 state-space-duality scan (sequential oracle).
#   h_t = exp(dt_t * A) * h_{t-1} + dt_t * (B_t ⊗ x_t)
#   y_t = C_t · h_t + D ⊙ x_t
# ---------------------------------------------------------------------------
def ssd_scan(
    x: jnp.ndarray,  # (B, S, H, P)
    dt: jnp.ndarray,  # (B, S, H) — post-softplus, positive
    A: jnp.ndarray,  # (H,) — negative decay rates
    Bm: jnp.ndarray,  # (B, S, G, N)
    Cm: jnp.ndarray,  # (B, S, G, N)
    D: jnp.ndarray,  # (H,)
    *,
    init_state: Optional[jnp.ndarray] = None,  # (B, H, P, N)
    return_state: bool = False,
):
    Bb, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = jnp.repeat(Bm.astype(jnp.float32), rep, axis=2)  # (B, S, H, N)
    Cf = jnp.repeat(Cm.astype(jnp.float32), rep, axis=2)
    Af = A.astype(jnp.float32)
    h0 = (
        jnp.zeros((Bb, H, P, N), jnp.float32)
        if init_state is None
        else init_state.astype(jnp.float32)
    )

    def step(h, inp):
        xt, dtt, bt, ct = inp  # (B,H,P), (B,H), (B,H,N), (B,H,N)
        decay = jnp.exp(dtt * Af[None, :])  # (B, H)
        dBx = jnp.einsum("bh,bhn,bhp->bhpn", dtt, bt, xt)
        h = decay[:, :, None, None] * h + dBx
        y = jnp.einsum("bhn,bhpn->bhp", ct, h)
        return h, y

    inputs = (
        jnp.moveaxis(xf, 1, 0),
        jnp.moveaxis(dtf, 1, 0),
        jnp.moveaxis(Bf, 1, 0),
        jnp.moveaxis(Cf, 1, 0),
    )
    hT, ys = lax.scan(step, h0, inputs)
    y = jnp.moveaxis(ys, 0, 1) + xf * D.astype(jnp.float32)[None, None, :, None]
    y = y.astype(x.dtype)
    if return_state:
        return y, hT.astype(jnp.float32)
    return y


def ssd_scan_chunked(
    x: jnp.ndarray,  # (B, S, H, P)
    dt: jnp.ndarray,  # (B, S, H)
    A: jnp.ndarray,  # (H,)
    Bm: jnp.ndarray,  # (B, S, G, N)
    Cm: jnp.ndarray,  # (B, S, G, N)
    D: jnp.ndarray,  # (H,)
    *,
    chunk: int = 256,
    init_state: Optional[jnp.ndarray] = None,
    return_state: bool = False,
):
    """Chunked SSD (the actual Mamba-2 algorithm): quadratic intra-chunk
    attention-like form + linear inter-chunk state recurrence.  This is the
    formulation the Pallas kernel tiles; it is mathematically identical to
    :func:`ssd_scan` (validated in tests) but maps onto the MXU.
    """
    Bb, S0, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Q = min(chunk, S0)
    if S0 % Q:
        # Pad the tail with dt=0 steps: decay=exp(0)=1 and the dt factor
        # zeroes the padded contributions, so the result is exact.
        pad = Q - S0 % Q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    S = x.shape[1]
    nc = S // Q
    xf = x.astype(jnp.float32).reshape(Bb, nc, Q, H, P)
    dtf = dt.astype(jnp.float32).reshape(Bb, nc, Q, H)
    Bf = jnp.repeat(Bm.astype(jnp.float32), rep, axis=2).reshape(
        Bb, nc, Q, H, N)
    Cf = jnp.repeat(Cm.astype(jnp.float32), rep, axis=2).reshape(
        Bb, nc, Q, H, N)
    Af = A.astype(jnp.float32)

    a = dtf * Af[None, None, None, :]  # (B, nc, Q, H) — log decay per step
    a_cum = jnp.cumsum(a, axis=2)  # inclusive within-chunk cumulative decay
    # Intra-chunk ("diagonal block") term.
    seg = a_cum[:, :, :, None, :] - a_cum[:, :, None, :, :]  # (B,nc,Qi,Qj,H)
    iq = jnp.arange(Q)
    tri = iq[:, None] >= iq[None, :]
    Ldec = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)
    cb = jnp.einsum("bcqhn,bckhn->bcqkh", Cf, Bf)
    M = cb * Ldec * dtf[:, :, None, :, :]  # weight by dt at the key position
    y_diag = jnp.einsum("bcqkh,bckhp->bcqhp", M, xf)
    # Chunk-final states.
    decay_to_end = jnp.exp(a_cum[:, :, -1:, :] - a_cum)  # (B, nc, Q, H)
    S_c = jnp.einsum("bcqh,bcqhn,bcqhp->bchpn", decay_to_end * dtf, Bf, xf)
    chunk_decay = jnp.exp(a_cum[:, :, -1, :])  # (B, nc, H)
    h0 = (
        jnp.zeros((Bb, H, P, N), jnp.float32)
        if init_state is None
        else init_state.astype(jnp.float32)
    )

    def step(h, inp):
        s_c, dec = inp
        h_new = dec[:, :, None, None] * h + s_c
        return h_new, h  # emit the state at chunk START

    hT, h_prev = lax.scan(
        step, h0,
        (jnp.moveaxis(S_c, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)  # (B, nc, H, P, N)
    y_off = jnp.einsum(
        "bcqhn,bchpn,bcqh->bcqhp", Cf, h_prev, jnp.exp(a_cum))
    y = (y_diag + y_off).reshape(Bb, S, H, P)[:, :S0]
    y = y + x.astype(jnp.float32)[:, :S0] * (
        D.astype(jnp.float32)[None, None, :, None])
    y = y.astype(x.dtype)
    if return_state:
        return y, hT
    return y


def ssd_step(
    x: jnp.ndarray,  # (B, H, P) — one token
    dt: jnp.ndarray,  # (B, H)
    A: jnp.ndarray,  # (H,)
    Bm: jnp.ndarray,  # (B, G, N)
    Cm: jnp.ndarray,  # (B, G, N)
    D: jnp.ndarray,  # (H,)
    state: jnp.ndarray,  # (B, H, P, N)
):
    """Single decode step of the SSD recurrence. Returns (y, new_state)."""
    H = x.shape[1]
    G = Bm.shape[1]
    rep = H // G
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = jnp.repeat(Bm.astype(jnp.float32), rep, axis=1)
    Cf = jnp.repeat(Cm.astype(jnp.float32), rep, axis=1)
    decay = jnp.exp(dtf * A.astype(jnp.float32)[None, :])
    dBx = jnp.einsum("bh,bhn,bhp->bhpn", dtf, Bf, xf)
    new_state = decay[:, :, None, None] * state.astype(jnp.float32) + dBx
    y = jnp.einsum("bhn,bhpn->bhp", Cf, new_state)
    y = y + xf * D.astype(jnp.float32)[None, :, None]
    return y.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# Depthwise causal conv (Mamba-2 front conv) — oracle + single-step update.
# ---------------------------------------------------------------------------
def causal_conv1d(
    x: jnp.ndarray,  # (B, S, C)
    w: jnp.ndarray,  # (W, C) depthwise taps
    b: jnp.ndarray,  # (C,)
    *,
    init: Optional[jnp.ndarray] = None,  # (B, W-1, C) left context
) -> jnp.ndarray:
    B, S, C = x.shape
    W = w.shape[0]
    if init is None:
        init = jnp.zeros((B, W - 1, C), x.dtype)
    xp = jnp.concatenate([init, x], axis=1).astype(jnp.float32)
    out = jnp.zeros((B, S, C), jnp.float32)
    for i in range(W):
        out = out + xp[:, i : i + S, :] * w[i].astype(jnp.float32)[None, None, :]
    out = out + b.astype(jnp.float32)[None, None, :]
    return jax.nn.silu(out).astype(x.dtype)


def causal_conv1d_step(
    x: jnp.ndarray,  # (B, C) — one token
    w: jnp.ndarray,  # (W, C)
    b: jnp.ndarray,  # (C,)
    buf: jnp.ndarray,  # (B, W-1, C) rolling context
):
    """Returns (y, new_buf)."""
    full = jnp.concatenate([buf, x[:, None, :]], axis=1)  # (B, W, C)
    y = jnp.einsum("bwc,wc->bc", full.astype(jnp.float32), w.astype(jnp.float32))
    y = jax.nn.silu(y + b.astype(jnp.float32)[None, :]).astype(x.dtype)
    return y, full[:, 1:, :]
