"""Pallas TPU flash attention (prefill/train path).

Blockwise online-softmax attention with GQA, causal masking, sliding
window, always-visible prefix (hymba meta tokens) and logit soft-capping
(gemma2) — the exact semantics of :func:`repro.kernels.ref.flash_attention`.

TPU mapping
-----------
* Layouts are transposed to head-major ``(B, H, S, D)`` so every BlockSpec
  tiles the trailing ``(S, D)`` plane; ``D`` (64–256) and the block sizes
  (128) are MXU/VREG aligned (multiples of 128 on the lane dim).
* Grid ``(B, H, nQ, nK)`` — the KV dim iterates innermost; the running
  max / denominator / accumulator live in VMEM scratch that persists
  across the ``nK`` loop (TPU grids execute sequentially), giving the
  classic one-pass flash recurrence with VMEM footprint
  ``bq·D + bk·D·2 + bq·bk + bq·D`` ≈ 0.4 MB at (bq, bk, D) = (128, 128, 128),
  far under the ~16 MB v5e VMEM budget; larger D simply scales the tiles.
* The causal/window/prefix mask is computed from block-relative iotas —
  no mask tensor is ever materialized in HBM.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.3819763e38


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 bq, bk, nk, scale, causal, window, softcap, prefix,
                 q_offset, seq_q, seq_k):
    iq, ik = pl.program_id(2), pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale  # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)  # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)  # (bk, D)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)  # (bq, bk)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap

    q_pos = q_offset + iq * bq + jax.lax.broadcasted_iota(
        jnp.int32, (bq, bk), 0)
    kv_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = kv_pos < seq_k
    if causal:
        mask &= kv_pos <= q_pos
    if window:
        win_ok = kv_pos > q_pos - window
        if prefix:
            win_ok |= kv_pos < prefix
        mask &= win_ok
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]  # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)  # (bq, bk)
    alpha = jnp.exp(m_prev - m_new)  # (bq, 1)
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(ik == nk - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention(
    q: jnp.ndarray,  # (B, S, H, D)
    k: jnp.ndarray,  # (B, T, KV, D)
    v: jnp.ndarray,  # (B, T, KV, D)
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    scale: float = 0.0,
    q_offset: int = 0,
    prefix: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    B, S, H, D = q.shape
    _, T, KV, _ = k.shape
    G = H // KV
    if scale == 0.0:
        scale = D ** -0.5
    bq, bk = min(block_q, S), min(block_k, T)
    # Pad sequence dims up to block multiples (masked out in-kernel).
    Sp = math.ceil(S / bq) * bq
    Tp = math.ceil(T / bk) * bk
    qt = jnp.moveaxis(q, (0, 2, 1, 3), (0, 1, 2, 3))  # (B, H, S, D)
    kt = jnp.moveaxis(k, (0, 2, 1, 3), (0, 1, 2, 3))  # (B, KV, T, D)
    vt = jnp.moveaxis(v, (0, 2, 1, 3), (0, 1, 2, 3))
    if Sp != S:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))
    if Tp != T:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, Tp - T), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, Tp - T), (0, 0)))
    nq, nk = Sp // bq, Tp // bk

    kernel = functools.partial(
        _attn_kernel, bq=bq, bk=bk, nk=nk, scale=scale, causal=causal,
        window=window, softcap=softcap, prefix=prefix, q_offset=q_offset,
        seq_q=S, seq_k=T)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sp, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    out = out[:, :, :S, :]
    return jnp.moveaxis(out, (0, 1, 2, 3), (0, 2, 1, 3))  # (B, S, H, D)
