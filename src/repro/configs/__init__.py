"""Architecture registry: one module per assigned architecture.

``get_config(name)`` returns the full published configuration;
``get_config(name, reduced=True)`` returns the same family scaled down for
CPU smoke tests (few layers, narrow widths, tiny vocab).
"""
from __future__ import annotations

from typing import Dict, List

from repro.models.config import ModelConfig

from . import (
    gemma2_2b,
    granite_3_2b,
    hymba_1_5b,
    internvl2_1b,
    llama4_scout_17b_16e,
    mamba2_780m,
    musicgen_large,
    olmoe_1b_7b,
    paper_edge,
    tinyllama_1_1b,
    yi_6b,
)

_MODULES = {
    "mamba2-780m": mamba2_780m,
    "hymba-1.5b": hymba_1_5b,
    "gemma2-2b": gemma2_2b,
    "tinyllama-1.1b": tinyllama_1_1b,
    "yi-6b": yi_6b,
    "granite-3-2b": granite_3_2b,
    "musicgen-large": musicgen_large,
    "llama4-scout-17b-a16e": llama4_scout_17b_16e,
    "olmoe-1b-7b": olmoe_1b_7b,
    "internvl2-1b": internvl2_1b,
}

ARCH_NAMES: List[str] = list(_MODULES)


def get_config(name: str, *, reduced: bool = False) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    mod = _MODULES[name]
    return mod.reduced_config() if reduced else mod.config()


def all_configs(*, reduced: bool = False) -> Dict[str, ModelConfig]:
    return {n: get_config(n, reduced=reduced) for n in ARCH_NAMES}
