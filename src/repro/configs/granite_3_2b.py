"""granite-3-2b — GQA dense.  [hf:ibm-granite/granite-3.0-2b-base; hf]
40L d_model=2048 32H (kv=8) d_ff=8192 vocab=49155 (padded to 49408 for TP).
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-3-2b",
        family="dense",
        num_layers=40,
        d_model=2048,
        num_heads=32,
        num_kv_heads=8,
        head_dim=64,
        d_ff=8192,
        vocab_size=49155,
        rope_theta=10000.0,
        tie_embeddings=True,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="granite-3-2b-reduced",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=131,  # deliberately non-multiple: exercises vocab padding
        tie_embeddings=True,
        vocab_pad_multiple=16,
    )
