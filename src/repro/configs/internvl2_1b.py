"""internvl2-1b — InternViT-300M frontend + Qwen2-0.5B LM backbone.
[arXiv:2404.16821; hf]  Backbone: 24L d_model=896 14H (kv=2) d_ff=4864
vocab=151655.

STUB per assignment: the InternViT vision tower is not implemented —
``input_specs()`` supplies precomputed patch embeddings (B, 256, d_model)
which the backbone consumes via early concatenation with text embeddings.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b",
        family="vlm",
        num_layers=24,
        d_model=896,
        num_heads=14,
        num_kv_heads=2,
        head_dim=64,
        d_ff=4864,
        vocab_size=151655,
        rope_theta=1_000_000.0,
        frontend="vision_stub",
        num_vision_tokens=256,
        tie_embeddings=True,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b-reduced",
        family="vlm",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=128,
        frontend="vision_stub",
        num_vision_tokens=8,
        tie_embeddings=True,
        vocab_pad_multiple=16,
    )
