"""musicgen-large — decoder-only transformer over EnCodec tokens.
[arXiv:2306.05284; hf]  48L d_model=2048 32H (kv=32, MHA) d_ff=8192
vocab=2048 per codebook, 4 codebooks (delay pattern).

STUB per assignment: the EnCodec audio frontend is not implemented —
``input_specs()`` supplies the 4-codebook token grid directly.  Adaptations
recorded in DESIGN.md: RoPE replaces learned positional embeddings; the
text-conditioning cross-attention stack is omitted (unconditional decoding).
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large",
        family="audio",
        num_layers=48,
        d_model=2048,
        num_heads=32,
        num_kv_heads=32,
        head_dim=64,
        d_ff=8192,
        vocab_size=2048,
        num_codebooks=4,
        frontend="audio_stub",
        vocab_pad_multiple=128,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large-reduced",
        family="audio",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=64,
        num_codebooks=4,
        frontend="audio_stub",
        vocab_pad_multiple=16,
    )
