"""tinyllama-1.1b — llama2-architecture small model with GQA.
[arXiv:2401.02385; hf]  22L d_model=2048 32H (kv=4) d_ff=5632 vocab=32000.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="tinyllama-1.1b",
        family="dense",
        num_layers=22,
        d_model=2048,
        num_heads=32,
        num_kv_heads=4,
        head_dim=64,
        d_ff=5632,
        vocab_size=32000,
        rope_theta=10000.0,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="tinyllama-1.1b-reduced",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=128,
        vocab_pad_multiple=16,
    )
