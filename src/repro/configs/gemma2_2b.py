"""gemma2-2b — local/global alternating attention, logit softcaps, GeGLU,
pre+post norms, tied embeddings.  [arXiv:2408.00118; hf]
26L d_model=2304 8H (kv=4, head_dim=256) d_ff=9216 vocab=256000.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-2b",
        family="dense",
        num_layers=26,
        d_model=2304,
        num_heads=8,
        num_kv_heads=4,
        head_dim=256,
        d_ff=9216,
        vocab_size=256000,
        sliding_window=4096,
        layer_pattern=("local", "global"),
        attn_logit_softcap=50.0,
        final_logit_softcap=30.0,
        act="gelu",
        emb_scale=True,
        post_norm=True,
        tie_embeddings=True,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-2b-reduced",
        family="dense",
        num_layers=4,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=128,
        sliding_window=8,
        layer_pattern=("local", "global"),
        attn_logit_softcap=50.0,
        final_logit_softcap=30.0,
        act="gelu",
        emb_scale=True,
        post_norm=True,
        tie_embeddings=True,
        vocab_pad_multiple=16,
    )
