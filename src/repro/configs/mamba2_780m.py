"""mamba2-780m — SSD (state-space duality), attention-free.
[arXiv:2405.21060; unverified]  48L d_model=1536 d_ff=0 vocab=50280 state=128.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m",
        family="ssm",
        num_layers=48,
        d_model=1536,
        num_heads=0,
        num_kv_heads=0,
        d_ff=0,
        vocab_size=50280,
        ssm_state=128,
        ssm_head_dim=64,
        ssm_expand=2,
        ssm_chunk=256,
        ssm_conv_width=4,
        ssm_ngroups=1,
        tie_embeddings=True,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m-reduced",
        family="ssm",
        num_layers=2,
        d_model=64,
        num_heads=0,
        num_kv_heads=0,
        d_ff=0,
        vocab_size=128,
        ssm_state=16,
        ssm_head_dim=16,
        ssm_expand=2,
        ssm_chunk=16,
        ssm_conv_width=4,
        ssm_ngroups=1,
        tie_embeddings=True,
        vocab_pad_multiple=16,
    )
