"""llama4-scout-17b-a16e — MoE 16 routed experts top-1 + 1 shared expert,
QK-norm, early fusion (text path only here).
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
48L d_model=5120 40H (kv=8) d_ff=8192 vocab=202048.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e",
        family="moe",
        num_layers=48,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        head_dim=128,
        d_ff=8192,  # shared-expert width
        vocab_size=202048,
        rope_theta=500_000.0,
        num_experts=16,
        num_experts_per_tok=1,
        moe_d_ff=8192,
        num_shared_experts=1,
        qk_norm=True,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e-reduced",
        family="moe",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=128,
        num_experts=4,
        num_experts_per_tok=1,
        moe_d_ff=128,
        num_shared_experts=1,
        qk_norm=True,
        vocab_pad_multiple=16,
    )
