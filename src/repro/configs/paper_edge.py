"""The paper's own five edge applications (Table II) with their published
model-zoo variants — used for the paper-faithful simulation benchmarks
(Figs 4–10).  Sizes are MB, accuracies are %, exactly as printed.

These are *simulation* entities (the paper itself evaluates via its E2C
simulator); the 10 assigned LM architectures are the *system* tenants and
get their zoo sizes from real config math instead.
"""
from __future__ import annotations

from repro.core.model_zoo import ModelVariant, ModelZoo

# Table I — load/inference times on a Galaxy S20+ (ms); used to calibrate
# the simulator's load-time model and reproduced by benchmarks/table1.
TABLE1 = {
    # name: bits -> (size MB, load ms, infer ms, accuracy %)
    "InceptionV3": {32: (105, 650, 100, 78.50), 8: (24, 380, 80, 77.20)},
    "VGG16": {32: (528, 820, 52, 71.30), 8: (132, 185, 40, 70.18)},
    "MobileNetV1": {32: (89, 600, 15, 70.56), 8: (23, 192, 8, 65.70)},
    "MobileNetV2": {32: (26, 110, 10, 72.08), 8: (9, 65, 7.5, 63.70)},
    "MobileNetV3": {32: (14, 80.3, 7.80, 74.04), 8: (8, 47.45, 6.21, 71.32)},
    "MobileBERT": {32: (96, 1100, 62, 81.23), 8: (26, 890, 40, 77.08)},
}

# Table II — the five benchmarked applications and their zoos.
_TABLE2 = [
    # (app, model, [(bits, size MB, accuracy %)])
    ("face_recognition", "VGG-Face",
     [(32, 535.1, 90.2), (16, 378.8, 82.5), (8, 144.2, 71.8)]),
    ("image_classification", "VIT-base-patch16",
     [(32, 346.4, 94.5), (16, 242.2, 81.3), (8, 106.7, 72.2)]),
    ("speech_recognition", "S2T-librispeech",
     [(32, 285.2, 89.7), (16, 228.0, 77.2), (8, 78.4, 68.0)]),
    ("sentence_prediction", "Paraphrase-MiniLM-L12-v2",
     [(32, 471.3, 88.2), (16, 377.6, 81.7), (8, 98.9, 76.2)]),
    ("text_classification", "Roberta-base",
     [(32, 499.0, 91.1), (16, 392.2, 82.4), (8, 132.3, 76.6)]),
]

# The paper's edge server memory budget for NN models (MB).  A Jetson-Nano
# class device has 4 GB total; the paper contends ~5 FP32 models (~2.1 GB)
# against a smaller usable pool.  1.2 GB reproduces the paper's contention
# regime (all-FP32 residency impossible, all-INT8 residency possible).
DEFAULT_MEMORY_MB = 1200.0

# Load-time model calibrated on Table I's *large* models (VGG16 528 MB /
# 820 ms ≈ 1.6, InceptionV3 105/650 ≈ 6.2, MobileBERT 96/1100 ≈ 11.5 —
# size-weighted ≈ 2 ms/MB; small models amortize worse but matter less).
LOAD_MS_PER_MB = 2.0


def paper_zoos() -> dict[str, ModelZoo]:
    zoos = {}
    for app, model, variants in _TABLE2:
        zoos[app] = ModelZoo(
            app_name=app,
            variants=tuple(
                ModelVariant(
                    name=f"{model}-int{bits}" if bits < 32 else f"{model}-fp32",
                    bits=bits,
                    size_mb=size,
                    accuracy=acc,
                    load_ms=size * LOAD_MS_PER_MB,
                )
                for bits, size, acc in variants
            ),
        )
    return zoos


APP_NAMES = [row[0] for row in _TABLE2]
