"""olmoe-1b-7b — 64 experts, top-8 routing, QK-norm.
[arXiv:2409.02060; hf]  16L d_model=2048 16H (kv=16) d_ff=1024/expert
vocab=50304.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b",
        family="moe",
        num_layers=16,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        d_ff=0,  # no dense/shared FFN — all-MoE
        vocab_size=50304,
        num_experts=64,
        num_experts_per_tok=8,
        moe_d_ff=1024,
        qk_norm=True,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b-reduced",
        family="moe",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=0,
        vocab_size=128,
        num_experts=8,
        num_experts_per_tok=2,
        moe_d_ff=64,
        qk_norm=True,
        vocab_pad_multiple=16,
    )
