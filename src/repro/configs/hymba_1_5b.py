"""hymba-1.5b — parallel attention + mamba heads per layer, meta tokens,
sliding-window attention with 3 full-attention layers (first/middle/last).
[arXiv:2411.13676; hf]  32L d_model=1600 25H (kv=5) d_ff=5504 vocab=32001.
"""
from repro.models.config import ModelConfig

_PATTERN = tuple(
    "hybrid_full" if i in (0, 15, 31) else "hybrid" for i in range(32)
)


def config() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b",
        family="hybrid",
        num_layers=32,
        d_model=1600,
        num_heads=25,
        num_kv_heads=5,
        head_dim=64,
        d_ff=5504,
        vocab_size=32001,
        sliding_window=1024,
        layer_pattern=_PATTERN,
        ssm_state=16,
        ssm_head_dim=64,
        ssm_expand=1,  # hybrid branch works at d_model width
        ssm_chunk=256,
        ssm_conv_width=4,
        ssm_ngroups=1,
        num_meta_tokens=128,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b-reduced",
        family="hybrid",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=97,
        sliding_window=8,
        layer_pattern=("hybrid_full", "hybrid", "hybrid"),
        ssm_state=8,
        ssm_head_dim=16,
        ssm_expand=1,
        ssm_chunk=8,
        ssm_conv_width=4,
        ssm_ngroups=1,
        num_meta_tokens=4,
        vocab_pad_multiple=16,
    )
