"""yi-6b — llama-architecture GQA.  [arXiv:2403.04652; hf]
32L d_model=4096 32H (kv=4) d_ff=11008 vocab=64000.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="yi-6b",
        family="dense",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=4,
        head_dim=128,
        d_ff=11008,
        vocab_size=64000,
        rope_theta=5_000_000.0,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="yi-6b-reduced",
        family="dense",
        num_layers=2,
        d_model=96,
        num_heads=6,
        num_kv_heads=2,
        head_dim=16,
        d_ff=192,
        vocab_size=160,
        rope_theta=5_000_000.0,
        vocab_pad_multiple=16,
    )
