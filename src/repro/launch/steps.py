"""Lowerable step functions (train / prefill / decode) with shardings.

These are the exact callables the dry-run lowers and a real launch would
execute — one definition, two uses.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed import sharding as SH
from repro.launch.mesh import data_axes
from repro.launch.specs import SHAPE_SPECS, input_specs
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.training.optim import AdamW
from repro.training.train_step import (abstract_state,
                                       make_train_step)


# Tenants below this parameter count train in pure-DP mode: the model fits
# per-chip, so tensor parallelism would only add per-layer all-reduces.
# Both mesh axes become data axes and all state is fully ZeRO-sharded —
# the per-step wire drops to one gradient reduce-scatter pass (§Perf B2).
DP_ONLY_MAX_PARAMS = 4e9


def build_cell(cfg: ModelConfig, shape_name: str, mesh: Mesh, *,
               moe_impl: str = "dense", param_dtype=jnp.bfloat16,
               grad_accum: int = 1, dp_only=None, qcache: bool = False):
    """Returns (fn, example_args, in_shardings, out_shardings, donate) for
    one (arch × shape) cell on the given mesh."""
    from repro.distributed.ctx import ShardCtx, set_ctx

    kind, specs = input_specs(cfg, shape_name, quantized_cache=qcache)
    gbatch = SHAPE_SPECS[shape_name][1]
    dp = data_axes(mesh)
    if dp_only is None:
        dp_only = (kind == "train"
                   and cfg.param_count() < DP_ONLY_MAX_PARAMS
                   and gbatch % mesh.size == 0)
    if dp_only:
        dp = tuple(mesh.axis_names)  # every mesh axis is a data axis
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    set_ctx(ShardCtx(
        dp_axes=dp, model_axis="model",
        model_size=1 if dp_only else mesh.shape["model"],
        dp_size=dp_size, enabled=True))
    # Collective-saving remat (§Perf B1) unless the tenant is so large
    # that the extra saved activations would break the HBM fit.
    T.set_remat_save_tp(cfg.param_count() < 5e10)

    if kind == "train":
        opt = AdamW(lr=1e-4)
        state_abs = abstract_state(cfg, opt, dtype=jnp.float32)
        if dp_only:
            # no TP: compute weights replicated; state fully ZeRO-sharded
            pspecs = jax.tree.map(
                lambda leaf: P(*([None] * leaf.ndim)), state_abs.params)
        else:
            pspecs = SH.param_specs(cfg, state_abs.params, mesh)
        sspecs = SH.state_specs(cfg, state_abs, mesh, pspecs, zero1=True,
                                dp_axes=dp)
        bspecs = SH.batch_specs(cfg, specs["batch"], mesh, dp_axes=dp)
        step = make_train_step(cfg, opt, moe_impl=moe_impl, remat=True,
                               grad_accum=grad_accum,
                               zero_specs=sspecs.params)

        def fn(state, batch):
            new_state, metrics = step(state, batch)
            return new_state, metrics["loss"]

        args = (state_abs, specs["batch"])
        in_sh = (sspecs, bspecs)
        out_sh = (sspecs, P())
        return fn, args, in_sh, out_sh, (0,)  # donate the train state

    params_abs = T.abstract_params(cfg, param_dtype)
    # FSDP-2D weights stay ON for serving the huge MoE tenant (its bf16
    # weights don't fit 1-D); small tenants are unaffected (threshold).
    pspecs = SH.param_specs(cfg, params_abs, mesh, fsdp=True)

    if kind == "prefill":
        seq = SHAPE_SPECS[shape_name][0]
        bspecs = SH.batch_specs(cfg, specs["batch"], mesh, dp_axes=dp)
        cache_abs = jax.eval_shape(
            lambda p, b: T.prefill(cfg, p, b, max_len=seq)[1],
            params_abs, specs["batch"])
        cspecs = SH.cache_specs(cfg, cache_abs, mesh, dp_axes=dp)

        def fn(params, batch):
            logits, cache = T.prefill(cfg, params, batch, max_len=seq)
            return T.greedy_token(cfg, logits), cache

        args = (params_abs, specs["batch"])
        in_sh = (pspecs, bspecs)
        tok_spec = P(dp if len(dp) > 1 else dp[0])
        out_sh = (tok_spec, cspecs)
        return fn, args, in_sh, out_sh, ()

    # decode
    tok_abs, cache_abs = specs["tokens"], specs["cache"]
    cspecs = SH.cache_specs(cfg, cache_abs, mesh, dp_axes=dp)
    gbatch = tok_abs.shape[0]
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    tok_sh = P(dp if len(dp) > 1 else dp[0]) if gbatch % dp_size == 0 else P()

    def fn(params, cache, tokens):
        logits, new_cache = T.decode_step(cfg, params, cache, tokens,
                                          moe_impl=moe_impl,
                                          uniform_pos=True)
        return T.greedy_token(cfg, logits), new_cache

    args = (params_abs, cache_abs, tok_abs)
    in_sh = (pspecs, cspecs, tok_sh)
    out_sh = (tok_sh, cspecs)
    return fn, args, in_sh, out_sh, (1,)  # donate the KV cache
