import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent on the
production mesh without hardware.

For every (architecture × input shape) cell this lowers + compiles the real
step function (train_step for train shapes, prefill/serve_step for serving
shapes) against ShapeDtypeStruct inputs on:

  * the single-pod 16×16 (data, model) mesh  — also the roofline source;
  * the 2×16×16 (pod, data, model) multi-pod mesh — proves the pod axis
    shards.

It records ``memory_analysis()`` (fits-in-HBM proof), ``cost_analysis()``
FLOPs/bytes, the collective schedule (parsed from the partitioned HLO) and
— single-pod only — the L2/L4 fully-unrolled marginal probe that recovers
exact per-layer costs (see launch/roofline.py).  Results go to a JSON cache
consumed by benchmarks/ and EXPERIMENTS.md.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Dict

import jax

from repro.configs import ARCH_NAMES, get_config
from repro.launch import roofline as RL
from repro.launch.mesh import HBM_BYTES, make_production_mesh
from repro.launch.steps import build_cell
from repro.models import transformer as T
from repro.models.config import SHAPE_SPECS, cell_is_runnable

RESULTS_PATH = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "results", "dryrun")


def pick_grad_accum(cfg, shape_name, mesh) -> int:
    """Choose microbatching so the remat residual stack (~6 bytes/act
    element × L layers) stays under ~5 GB/device.  Powers of two, ≤ 16."""
    from repro.launch.steps import DP_ONLY_MAX_PARAMS

    seq, gbatch, kind = SHAPE_SPECS[shape_name]
    if kind != "train":
        return 1
    if (cfg.param_count() < DP_ONLY_MAX_PARAMS
            and gbatch % mesh.size == 0):
        return 1  # pure-DP cells: one row per device already
    dp = 1
    for a in mesh.axis_names:
        if a in ("pod", "data"):
            dp *= mesh.shape[a]
    b_loc = max(gbatch // dp, 1)
    per_b = seq * cfg.d_model * 6 * cfg.num_layers  # bytes per batch row
    accum = 1
    while accum < min(b_loc, 16) and b_loc // accum * per_b > 5e9:
        accum *= 2
    return accum


def _lower_compile(cfg, shape_name, mesh, *, moe_impl="dense",
                   grad_accum=None, qcache=False, dp_only=None):
    ga = (pick_grad_accum(cfg, shape_name, mesh)
          if grad_accum is None else grad_accum)
    fn, args, in_sh, out_sh, donate = build_cell(
        cfg, shape_name, mesh, moe_impl=moe_impl, grad_accum=ga,
        qcache=qcache, dp_only=dp_only)
    with jax.set_mesh(mesh):
        lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                          donate_argnums=donate).lower(*args)
        compiled = lowered.compile()
    return compiled


def _mem_stats(compiled) -> Dict[str, float]:
    try:
        ma = compiled.memory_analysis()
        return {
            "argument_bytes": float(ma.argument_size_in_bytes),
            "output_bytes": float(ma.output_size_in_bytes),
            "temp_bytes": float(ma.temp_size_in_bytes),
            "alias_bytes": float(ma.alias_size_in_bytes),
            "peak_bytes": float(ma.argument_size_in_bytes
                                + ma.temp_size_in_bytes
                                + ma.output_size_in_bytes
                                - ma.alias_size_in_bytes),
            "hbm_fraction": float(
                (ma.argument_size_in_bytes + ma.temp_size_in_bytes
                 + ma.output_size_in_bytes - ma.alias_size_in_bytes)
                / HBM_BYTES),
        }
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             probe: bool = True, moe_impl: str = "dense",
             qcache: bool = False, verbose: bool = True) -> dict:
    cfg = get_config(arch)
    result: dict = {"arch": arch, "shape": shape_name,
                    "multi_pod": multi_pod, "moe_impl": moe_impl,
                    "qcache": qcache}
    if not cell_is_runnable(arch, shape_name):
        result["status"] = "SKIP(full-attn)"
        return result
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    # dp_only is decided ONCE from the full config so the shallow L2/L4
    # probes lower with the same parallelism mapping.
    from repro.launch.steps import DP_ONLY_MAX_PARAMS
    kind = SHAPE_SPECS[shape_name][2]
    gbatch = SHAPE_SPECS[shape_name][1]
    dp_only = (kind == "train"
               and cfg.param_count() < DP_ONLY_MAX_PARAMS
               and gbatch % mesh.size == 0)
    result["dp_only"] = dp_only
    t0 = time.time()
    try:
        compiled = _lower_compile(cfg, shape_name, mesh,
                                  moe_impl=moe_impl, qcache=qcache,
                                  dp_only=dp_only)
    except Exception as e:
        result["status"] = "FAIL"
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            _print_cell(result)
        return result
    result["compile_s"] = time.time() - t0
    result["status"] = "OK"
    result["memory"] = _mem_stats(compiled)
    base_cost = RL.cost_from_compiled(compiled)
    result["scan_body_cost"] = {
        "flops": base_cost.flops, "bytes": base_cost.bytes_accessed,
        "coll_bytes": base_cost.coll_bytes}
    del compiled

    if probe and not multi_pod:
        try:
            T.set_scan_unroll(64)  # full unroll at probe depths
            costs = {}
            for Lp in (2, 4):
                cfg_p = dataclasses.replace(cfg, num_layers=Lp)
                # grad_accum=1 for probes: a microbatch scan body would be
                # counted once; totals are accum-invariant anyway.
                cp = _lower_compile(cfg_p, shape_name, mesh,
                                    moe_impl=moe_impl, grad_accum=1,
                                    qcache=qcache, dp_only=dp_only)
                costs[Lp] = RL.cost_from_compiled(cp)
                del cp
        finally:
            T.set_scan_unroll(1)
        total = RL.extrapolate(costs[2], costs[4], cfg.num_layers)
        result["cost"] = {
            "flops_per_device": total.flops,
            "bytes_per_device": total.bytes_accessed,
            "coll_bytes_per_device": total.coll_bytes,
            "per_layer_flops": (costs[4] - costs[2]).scaled(0.5).flops,
        }
        terms = RL.roofline_terms(total, chips)
        mf = RL.model_flops(cfg, shape_name)
        terms["model_flops"] = mf
        terms["useful_ratio"] = (mf / terms["hlo_flops_global"]
                                 if terms["hlo_flops_global"] else 0.0)
        result["roofline"] = terms
    if verbose:
        _print_cell(result)
    return result


def _print_cell(r: dict) -> None:
    tag = f"{r['arch']} × {r['shape']}" + (" [multi-pod]" if r["multi_pod"]
                                           else "")
    if r["status"] != "OK":
        print(f"{tag}: {r['status']} {r.get('error', '')}")
        return
    mem = r.get("memory", {})
    line = (f"{tag}: OK compile={r['compile_s']:.1f}s "
            f"hbm={mem.get('hbm_fraction', float('nan')) * 100:.1f}%")
    if "roofline" in r:
        t = r["roofline"]
        line += (f" | compute={t['compute_s'] * 1e3:.2f}ms "
                 f"memory={t['memory_s'] * 1e3:.2f}ms "
                 f"coll={t['collective_s'] * 1e3:.2f}ms "
                 f"dominant={t['dominant']} useful={t['useful_ratio']:.2f}")
    print(line, flush=True)


def all_cells():
    for arch in ARCH_NAMES:
        for shape_name in SHAPE_SPECS:
            yield arch, shape_name


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-probe", action="store_true")
    ap.add_argument("--moe-impl", default="dense",
                    choices=["dense", "ragged", "local"])
    ap.add_argument("--qcache", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells = (list(all_cells()) if args.all
             else [(args.arch, args.shape)])
    results = []
    for arch, shape_name in cells:
        meshes = ([False, True] if args.both_meshes
                  else [args.multi_pod])
        for mp in meshes:
            results.append(run_cell(
                arch, shape_name, multi_pod=mp, probe=not args.no_probe,
                moe_impl=args.moe_impl, qcache=args.qcache))
    if args.out:
        os.makedirs(os.path.dirname(os.path.abspath(args.out)),
                    exist_ok=True)
        existing = []
        if os.path.exists(args.out):
            with open(args.out) as f:
                existing = json.load(f)
        keyed = {(r["arch"], r["shape"], r["multi_pod"]): r
                 for r in existing}
        for r in results:
            keyed[(r["arch"], r["shape"], r["multi_pod"])] = r
        with open(args.out, "w") as f:
            json.dump(list(keyed.values()), f, indent=1)
    ok = sum(r["status"] == "OK" for r in results)
    skip = sum(r["status"].startswith("SKIP") for r in results)
    fail = sum(r["status"] == "FAIL" for r in results)
    print(f"\n=== dry-run: {ok} OK, {skip} skipped, {fail} failed "
          f"of {len(results)} cells")
    if fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
