"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first init.
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants (roofline denominators).
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link
HBM_BYTES = 16 * 1024 ** 3  # per chip


def make_mesh_compat(shape, axes):
    """``jax.make_mesh`` across jax versions: ``axis_types`` (and
    ``jax.sharding.AxisType``) only exist on newer releases; older ones
    default to auto axes anyway."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def data_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
