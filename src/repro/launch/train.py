"""Training launcher.

Two modes:
  * ``--reduced`` — really trains the reduced config on local devices
    (the CPU-runnable end-to-end path used by examples/ and tests).
  * default — builds the full config against the production mesh and
    lower+compiles the train step (the launch path a TPU fleet would run;
    on CPU this is the dry-run entry).

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --reduced --steps 50
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.distributed.fault_tolerance import FailureInjector, run_supervised
from repro.training.data import DataConfig, SyntheticStream
from repro.training.optim import AdamW, warmup_cosine
from repro.training.train_step import init_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--compression", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[])
    ap.add_argument("--moe-impl", default="dense")
    args = ap.parse_args()

    if not args.reduced:
        # Full-config path: delegate to the dry-run cell (lower+compile).
        from repro.launch.dryrun import run_cell

        run_cell(args.arch, "train_4k", probe=False)
        return

    cfg = get_config(args.arch, reduced=True)
    opt = AdamW(lr=warmup_cosine(args.lr, 10, args.steps),
                weight_decay=0.01)
    step_fn = jax.jit(make_train_step(
        cfg, opt, moe_impl=args.moe_impl, remat=True,
        grad_accum=args.grad_accum, compression=args.compression))
    state = init_state(cfg, jax.random.key(0), opt,
                       compression=args.compression)
    ds = SyntheticStream(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch))

    def batch_fn(step):
        return {k: jnp.asarray(v) for k, v in ds.batch_at(step).items()}

    t0 = time.time()
    report = run_supervised(
        init_state=state, step_fn=step_fn, batch_fn=batch_fn,
        total_steps=args.steps, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
        injector=(FailureInjector(fail_at_steps=tuple(args.fail_at))
                  if args.fail_at else None))
    dt = time.time() - t0
    print(f"arch={cfg.name} steps={report.steps_completed} "
          f"restarts={report.restarts} "
          f"loss {report.losses[0]:.4f} -> {report.losses[-1]:.4f} "
          f"({dt:.1f}s, {report.steps_completed / dt:.2f} steps/s)")


if __name__ == "__main__":
    main()
