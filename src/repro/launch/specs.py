"""ShapeDtypeStruct stand-ins for every model input — the dry-run currency.

``input_specs(cfg, shape_name)`` returns (step_kind, kwargs-of-specs) for
the train / prefill / decode step of the given assigned shape.  Weak-type
correct, shardable, no device allocation.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.config import SHAPE_SPECS, ModelConfig

SDS = jax.ShapeDtypeStruct


def token_spec(cfg: ModelConfig, batch: int, seq: int) -> SDS:
    if cfg.num_codebooks == 1:
        return SDS((batch, seq), jnp.int32)
    return SDS((batch, seq, cfg.num_codebooks), jnp.int32)


def batch_specs_for(cfg: ModelConfig, shape_name: str,
                    *, with_labels: bool) -> Dict[str, SDS]:
    seq, gbatch, _ = SHAPE_SPECS[shape_name]
    text_seq = seq
    out: Dict[str, SDS] = {}
    if cfg.frontend == "vision_stub":
        # vision tokens count toward the total sequence budget.
        text_seq = seq - cfg.num_vision_tokens
        out["patch_embeds"] = SDS(
            (gbatch, cfg.num_vision_tokens, cfg.d_model), jnp.bfloat16)
    out["tokens"] = token_spec(cfg, gbatch, text_seq)
    if with_labels:
        out["labels"] = token_spec(cfg, gbatch, text_seq)
    return out


def decode_specs_for(cfg: ModelConfig, shape_name: str,
                     cache_dtype=jnp.bfloat16,
                     quantized_cache: bool = False) -> Tuple[SDS, Any]:
    """(token spec, abstract cache at full context length)."""
    seq, gbatch, _ = SHAPE_SPECS[shape_name]
    tok = (SDS((gbatch,), jnp.int32) if cfg.num_codebooks == 1
           else SDS((gbatch, cfg.num_codebooks), jnp.int32))
    cache = T.abstract_cache(cfg, gbatch, seq, cache_dtype,
                             quantized_cache)
    return tok, cache


def input_specs(cfg: ModelConfig, shape_name: str,
                quantized_cache: bool = False):
    """Returns (kind, specs dict) for the lowered step of this cell."""
    kind = SHAPE_SPECS[shape_name][2]
    if kind == "train":
        return kind, {"batch": batch_specs_for(cfg, shape_name,
                                               with_labels=True)}
    if kind == "prefill":
        return kind, {"batch": batch_specs_for(cfg, shape_name,
                                               with_labels=False)}
    tok, cache = decode_specs_for(cfg, shape_name,
                                  quantized_cache=quantized_cache)
    return kind, {"tokens": tok, "cache": cache}
