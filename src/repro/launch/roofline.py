"""Roofline extraction from compiled dry-run artifacts.

Three terms per (arch × shape) on the single-pod mesh (DESIGN.md §7):

    compute    = HLO_FLOPs / (chips × 197e12)
    memory     = HLO_bytes / (chips × 819e9)
    collective = Σ per-device wire bytes / 50e9

HLO_FLOPs/bytes come from ``compiled.cost_analysis()`` — but XLA counts a
``while`` (scan) body ONCE regardless of trip count (verified empirically).
The extractor therefore recovers exact totals with a **marginal probe**:
lower the same cell at L=2 and L=4 fully unrolled; then

    per_layer = (cost(L4) − cost(L2)) / 2
    total     = cost(L2) − 2·per_layer + num_layers·per_layer

which also yields exact per-layer *collective* bytes from the partitioned
HLO text.  Collective wire bytes use ring-algorithm factors on the local
(post-SPMD) shapes: all-reduce 2·(n−1)/n·b, all-gather/reduce-scatter
(n−1)/n·b_full, all-to-all (n−1)/n·b, collective-permute b.

MODEL_FLOPS (the "useful" numerator) is the standard accounting:
6·N_active·tokens for training (2· for inference) plus the attention /
SSD terms — formulas inline below.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict, Optional

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.models.config import SHAPE_SPECS, ModelConfig

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLL_RE = re.compile(
    r"(\w+[\d.]*)\s*=\s*(?:\()?([a-z0-9]+)\[([\d,]*)\][^=]*?"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"[^(]*\(", )
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_wire_bytes(hlo_text: str, top: Optional[list] = None
                          ) -> Dict[str, float]:
    """Per-device wire bytes by collective kind, ring-algorithm model.
    If ``top`` is a list, (wire_bytes, kind, shape) tuples are appended
    for every collective — the §Perf diagnosis feed."""
    out: Dict[str, float] = {
        "all-reduce": 0.0, "all-gather": 0.0, "reduce-scatter": 0.0,
        "all-to-all": 0.0, "collective-permute": 0.0}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        _, dtype, dims, kind = m.groups()
        b = _shape_bytes(dtype, dims)  # local (per-device) output bytes
        gm = _GROUPS_RE.search(line)
        if gm:
            n = len(gm.group(1).split(","))
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            n = int(gi.group(2)) if gi else 2
        n = max(n, 2)
        if kind == "all-reduce":
            wire = 2.0 * (n - 1) / n * b
        elif kind == "all-gather":
            wire = (n - 1) / n * b  # b is the gathered (full) output
        elif kind == "reduce-scatter":
            wire = (n - 1) * b  # b is the scattered (shard) output
        elif kind == "all-to-all":
            wire = (n - 1) / n * b
        else:  # collective-permute
            wire = float(b)
        out[kind] += wire
        if top is not None:
            top.append((wire, kind, f"{dtype}[{dims}]", n))
    return out


@dataclass
class CellCost:
    flops: float
    bytes_accessed: float
    coll_bytes: Dict[str, float]

    @property
    def coll_total(self) -> float:
        return sum(self.coll_bytes.values())

    def __sub__(self, o: "CellCost") -> "CellCost":
        return CellCost(
            self.flops - o.flops, self.bytes_accessed - o.bytes_accessed,
            {k: self.coll_bytes[k] - o.coll_bytes.get(k, 0.0)
             for k in self.coll_bytes})

    def scaled(self, f: float) -> "CellCost":
        return CellCost(self.flops * f, self.bytes_accessed * f,
                        {k: v * f for k, v in self.coll_bytes.items()})

    def __add__(self, o: "CellCost") -> "CellCost":
        keys = set(self.coll_bytes) | set(o.coll_bytes)
        return CellCost(
            self.flops + o.flops, self.bytes_accessed + o.bytes_accessed,
            {k: self.coll_bytes.get(k, 0.0) + o.coll_bytes.get(k, 0.0)
             for k in keys})


def cost_from_compiled(compiled) -> CellCost:
    ca = compiled.cost_analysis() or {}
    return CellCost(
        flops=float(ca.get("flops", 0.0)),
        bytes_accessed=float(ca.get("bytes accessed", 0.0)),
        coll_bytes=collective_wire_bytes(compiled.as_text()))


def extrapolate(cost_l2: CellCost, cost_l4: CellCost,
                num_layers: int) -> CellCost:
    per_layer = (cost_l4 - cost_l2).scaled(0.5)
    base = cost_l2 - per_layer.scaled(2.0)
    return base + per_layer.scaled(float(num_layers))


# ---------------------------------------------------------------------------
# Analytic MODEL_FLOPS
# ---------------------------------------------------------------------------
def model_flops(cfg: ModelConfig, shape_name: str) -> float:
    seq, gbatch, kind = SHAPE_SPECS[shape_name]
    n_active = cfg.active_param_count()
    Lc, H, hd = cfg.num_layers, cfg.num_heads, cfg.resolved_head_dim
    mult = 6.0 if kind == "train" else 2.0

    if kind == "decode":
        toks = float(gbatch)
        flops = mult * n_active * toks
        if cfg.uses_attention:
            for k in cfg.layer_kinds():
                w = cfg.window_for_kind(k)
                ctx = min(seq, w) if w else seq
                flops += 4.0 * H * hd * ctx * toks
        if cfg.uses_ssm:
            di = cfg.d_model if cfg.family == "hybrid" else cfg.ssm_d_inner
            nh = di // cfg.ssm_head_dim
            flops += Lc * toks * 6.0 * nh * cfg.ssm_head_dim * cfg.ssm_state
        return flops

    toks = float(gbatch) * seq
    flops = mult * n_active * toks
    if cfg.uses_attention:
        attn_mult = 12.0 if kind == "train" else 4.0  # fwd(+bwd), qk+pv
        for k in cfg.layer_kinds():
            w = cfg.window_for_kind(k)
            eff = min(seq, w) if w else seq
            # causal: average context length ≈ eff/2 (full) or w (local)
            avg_ctx = (eff / 2.0) if not w else min(w, seq / 2.0)
            flops += attn_mult * H * hd * avg_ctx * toks / 2.0 * 2.0
    if cfg.uses_ssm:
        di = cfg.d_model if cfg.family == "hybrid" else cfg.ssm_d_inner
        nh = di // cfg.ssm_head_dim
        Q = min(cfg.ssm_chunk, seq)
        N, P = cfg.ssm_state, cfg.ssm_head_dim
        per_tok = nh * (2 * Q * N + 2 * Q * P + 6 * N * P)
        fb = 3.0 if kind == "train" else 1.0  # bwd ≈ 2× fwd
        flops += fb * Lc * toks * per_tok
    return flops


def roofline_terms(cost: CellCost, chips: int) -> Dict[str, float]:
    """``cost`` carries PER-DEVICE numbers (cost_analysis on the SPMD
    module reports local shapes — verified empirically), so each term
    divides by single-chip peaks; ``chips`` only converts back to global
    FLOPs for the useful-compute ratio."""
    compute = cost.flops / PEAK_FLOPS_BF16
    memory = cost.bytes_accessed / HBM_BW
    collective = cost.coll_total / ICI_BW
    dominant = max(
        (("compute", compute), ("memory", memory),
         ("collective", collective)), key=lambda kv: kv[1])[0]
    total = max(compute, memory, collective)
    return {
        "compute_s": compute,
        "memory_s": memory,
        "collective_s": collective,
        "dominant": dominant,
        "bound_s": total,
        "hlo_flops_global": cost.flops * chips,
    }
