"""Multi-tenant serving launcher: Edge-MultiAI managing real (reduced)
models under a device memory budget, driven by a synthetic request trace.
The stack comes up through the declarative API — every CLI flag maps
onto a :class:`~repro.serving.api.ServingConfig` field and
``EdgeServer.build`` does the wiring.

    PYTHONPATH=src python -m repro.launch.serve --tenants tinyllama-1.1b \
        gemma2-2b mamba2-780m --requests 30 --budget-mb 6
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core.policies import available_policies
from repro.serving import Batcher, Request
from repro.serving.api import (BatchingSpec, EdgeServer, LoaderSpec,
                               ServingConfig, TenantSpec)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tenants", nargs="+",
                    default=["tinyllama-1.1b", "gemma2-2b", "mamba2-780m"])
    ap.add_argument("--requests", type=int, default=30)
    ap.add_argument("--budget-mb", type=float, default=6.0)
    ap.add_argument("--policy", default="iws-bfe",
                    choices=["none", *available_policies()])
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--sim", action="store_true",
                    help="sim-time executors (no XLA, deterministic)")
    ap.add_argument("--sharded-mesh", type=int, nargs="+", default=None,
                    metavar="N", help="serve from a device mesh, e.g. "
                    "'--sharded-mesh 8' (8-way tensor parallel): weights "
                    "shard per chip, loads stage per shard under "
                    "per-device budgets")
    args = ap.parse_args()

    rng = np.random.default_rng(args.seed)
    server = EdgeServer.build(ServingConfig(
        tenants=tuple(TenantSpec(n) for n in args.tenants),
        budget_mb=args.budget_mb,
        policy=args.policy,
        delta_ms=2000.0,
        batching=BatchingSpec(max_batch=4),
        loader=(LoaderSpec(sharded=True,
                           mesh_shape=tuple(args.sharded_mesh))
                if args.sharded_mesh else LoaderSpec()),
        executor="sim" if args.sim else "real"))
    if server.manager.state.devices is not None:
        led = server.manager.state.devices
        print(f"mesh: {led.n_devices} chips x "
              f"{led.budgets_mb[0]:.2f}MB device budget")
    cfgs = {}
    for name in args.tenants:
        cfgs[name] = server.tenants[name].cfg
        zoo = server.tenants[name].zoo
        print(f"tenant {name}: zoo " + ", ".join(
            f"{v.bits}b={v.size_mb:.2f}MB" for v in zoo.variants))

    batcher = Batcher(max_batch=4)
    now = 0.0
    for i in range(args.requests):
        name = args.tenants[i % len(args.tenants)]
        cfg = cfgs[name]
        plen = int(rng.integers(4, 12))
        prompt = rng.integers(0, cfg.vocab_size, size=plen).astype(np.int32)
        batcher.submit(Request(app=name, prompt=prompt,
                               max_new=args.max_new, arrival_ms=now))
        now += float(rng.exponential(500.0))
        if batcher.pending() >= 3 or i == args.requests - 1:
            while (b := batcher.next_batch()) is not None:
                server.predict_and_preload(now)
                extra = None
                # Gate on the *batch's* tenant, not the most recently
                # submitted request's.
                if cfgs[b.app].frontend == "vision_stub" and not args.sim:
                    extra = {"patch_embeds": np.zeros(
                        (len(b.requests), cfgs[b.app].num_vision_tokens,
                         cfgs[b.app].d_model), np.float32)}
                r = server.serve(b.app, b.prompts, b.max_new, now_ms=now,
                                 extra=extra)
                print(f"[{now:8.0f}ms] {b.app:16s} batch={len(b.requests)} "
                      f"{'warm' if r.warm else 'COLD'}"
                      f"{' FAIL' if r.failed else ''} bits={r.bits} "
                      f"lat={r.latency_s * 1e3:.0f}ms")
    print("\nstats:", server.stats().to_dict())
    server.close()


if __name__ == "__main__":
    main()
