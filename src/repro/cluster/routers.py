"""Pluggable cluster routing: the ``Router`` protocol and its registry.

A router answers one question per arriving request: *which server?*
It answers from :class:`ServerView` snapshots — the typed, external
gossip surface a real fleet's router would receive from each server's
stats endpoint (queue depths, served/warm counts, which tenants are
resident or staging at what variant accuracy).  Routers never touch a
server's ``MemoryState``, ledger, or loader directly: if the real
network couldn't see it, the router can't either.

Same registry idiom as ``repro.core.policies``: decorate with
``@register_router(name)``, resolve declaratively from a
:class:`~repro.cluster.config.RouterSpec`, enumerate with
:func:`available_routers`.  All built-ins are deterministic — ties
break toward the lowest server index, so two identical runs route
identically.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import (Callable, ClassVar, Dict, Mapping, Optional,
                    Protocol, Sequence, Tuple, runtime_checkable)

from repro.cluster.config import RouterSpec

__all__ = ["Router", "ServerView", "available_routers",
           "register_router", "resolve_router"]


@dataclass(frozen=True)
class ServerView:
    """One server's externally visible state at a routing instant.

    Everything here is derivable from the server's typed stats/ledger
    surface: ``resident``/``staging`` map tenant name → the accuracy of
    the variant it holds (or is transferring) — the same per-variant
    accuracy the zoos publish; ``queued`` is per-tenant queue depth;
    ``served``/``warm`` are cumulative admission counts.
    """

    index: int
    pending: int                      # total queued requests
    served: int                       # results so far
    warm: int                         # warm admissions so far
    queued: Mapping[str, int] = field(default_factory=dict)
    resident: Mapping[str, float] = field(default_factory=dict)
    staging: Mapping[str, float] = field(default_factory=dict)

    @property
    def warm_ratio(self) -> float:
        return self.warm / self.served if self.served else 0.0


@runtime_checkable
class Router(Protocol):
    """Route ``app``'s request to one of ``views`` (non-empty, ordered
    by server index).  Must return a valid ``views[i].index``."""

    name: ClassVar[str]

    def route(self, app: str, views: Sequence[ServerView],
              now_ms: float) -> int: ...


_ROUTERS: Dict[str, Callable[[Optional[RouterSpec]], "Router"]] = {}


def register_router(name: str) -> Callable:
    """Register a router factory (usually the class itself; called with
    the :class:`RouterSpec` or ``None``) under ``name``."""
    def deco(factory):
        if isinstance(factory, type):
            factory.name = name
        _ROUTERS[name] = factory
        return factory
    return deco


def available_routers() -> Tuple[str, ...]:
    """The registered router names, sorted — what a
    :class:`~repro.cluster.config.RouterSpec` may name.

    >>> available_routers()
    ('least-loaded', 'round-robin', 'warm-aware')
    """
    return tuple(sorted(_ROUTERS))


def resolve_router(spec: "RouterSpec | str") -> Router:
    """Resolve a :class:`RouterSpec` (or bare name) to a live router
    instance through the registry; unknown names raise ``KeyError``
    listing the registered set.

    >>> resolve_router("round-robin").name
    'round-robin'
    """
    if isinstance(spec, str):
        spec = RouterSpec(name=spec)
    if spec.name not in _ROUTERS:
        raise KeyError(
            f"unknown router {spec.name!r}; registered routers: "
            f"{', '.join(available_routers())}")
    return _ROUTERS[spec.name](spec)


# ---------------------------------------------------------------------------
# Built-ins
# ---------------------------------------------------------------------------
@register_router("round-robin")
class RoundRobin:
    """State-blind rotation — the baseline every placement-aware router
    must beat.  Spreads load perfectly and residency terribly: each
    tenant's requests land on every server in turn, so every server
    ends up churning every zoo."""

    def __init__(self, spec: Optional[RouterSpec] = None):
        self._next = 0

    def route(self, app: str, views: Sequence[ServerView],
              now_ms: float) -> int:
        i = views[self._next % len(views)].index
        self._next += 1
        return i


@register_router("least-loaded")
class LeastLoaded:
    """Shortest total queue wins (ties to the lowest index): the classic
    load balancer — placement-blind, so it trades residency for queue
    evenness exactly like round-robin under symmetric load."""

    def __init__(self, spec: Optional[RouterSpec] = None):
        pass

    def route(self, app: str, views: Sequence[ServerView],
              now_ms: float) -> int:
        return min(views, key=lambda v: (v.pending, v.index)).index


@register_router("warm-aware")
class WarmAware:
    """Route to the server already holding the tenant's weights — the
    cluster-scale analogue of the paper's warm-start objective.

    Score per server: the accuracy of the tenant's resident variant
    (staging counts half — the transfer may not commit before the
    request admits), minus ``spill_penalty`` per queued request.  The
    penalty is what makes a flash crowd *spill*: once the home server's
    queue is deep enough, a cold-but-idle neighbor outscores it, and
    the overflow moves instead of stacking up behind one box.

    Score ties (typically: the tenant is cold everywhere) break toward
    the server hosting the fewest tenants, then the lowest index — so
    cold tenants spread out and the fleet partitions residency instead
    of piling every zoo onto server 0.
    """

    def __init__(self, spec: Optional[RouterSpec] = None):
        self.spill_penalty = (spec.spill_penalty if spec is not None
                              else RouterSpec().spill_penalty)

    def score(self, app: str, v: ServerView) -> float:
        warmth = v.resident.get(app, 0.0)
        if warmth <= 0.0:
            warmth = 0.5 * v.staging.get(app, 0.0)
        return warmth - self.spill_penalty * v.pending

    def route(self, app: str, views: Sequence[ServerView],
              now_ms: float) -> int:
        def crowding(v: ServerView) -> int:
            return len(v.resident) + len(v.staging)
        return max(views, key=lambda v: (self.score(app, v),
                                         -crowding(v), -v.index)).index
