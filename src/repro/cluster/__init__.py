"""Cluster tier: warm-aware routing across a fleet of edge servers.

Layered strictly above ``repro.serving`` — a :class:`EdgeCluster`
composes N built :class:`~repro.serving.api.EdgeServer` instances under
one global virtual clock, routes each arrival through a pluggable
:class:`~repro.cluster.routers.Router`, and moves tenants between
servers with transactional hand-offs when a flash crowd overloads one
box.  See ``cluster.py`` for the event loop, ``routers.py`` for the
routing registry, ``config.py`` for the declarative config tree.
"""
from repro.cluster.cluster import EdgeCluster
from repro.cluster.config import ClusterConfig, RouterSpec
from repro.cluster.routers import (Router, ServerView, available_routers,
                                   register_router, resolve_router)

__all__ = ["ClusterConfig", "EdgeCluster", "Router", "RouterSpec",
           "ServerView", "available_routers", "register_router",
           "resolve_router"]
