"""EdgeCluster: a fleet of EdgeServers under one global clock + router.

The cluster tier is layered strictly above :class:`EdgeServer` — it
composes built servers, it never reaches into engine internals.  Three
pieces:

* **The global event loop** (:meth:`EdgeCluster.run_trace`): arrivals
  are routed one at a time at their trace timestamps; before each
  routing decision every server's loop is advanced up to (exclusive of)
  that instant through the engine's ``cluster_advance`` protocol, so
  the router always sees the fleet as it stands *at* the arrival — and
  two identical runs see identical fleets, making the whole cluster
  run bit-deterministic (identical per-server audit trails).

* **Routing** over :class:`~repro.cluster.routers.ServerView` snapshots
  — the typed external surface; see ``routers.py``.

* **Cross-server tenant hand-off** (:meth:`_handoff`): the scale-out of
  ``MigrateShard``.  When a flash crowd piles one tenant's queue up on
  its routed server while a strictly lighter server exists, the tenant
  moves home as a transactional pair of residency plans — a staged
  ``Load`` on the receiver (simulate-validated *before* anything
  mutates, staged through the receiver's loader exactly like a demand
  load), then an ``Unload`` drain on the donor and the queued requests
  re-queued to the new home.  Both sides ride the PR-5 residency-plan
  IR through the existing manager/loader mutation paths — no second
  mutation path.
"""
from __future__ import annotations

import math
from typing import Sequence, Tuple

from repro.core import actions as A
from repro.cluster.config import ClusterConfig
from repro.cluster.routers import Router, ServerView, resolve_router
from repro.serving.api import EdgeServer
from repro.serving.batcher import Request
from repro.serving.stats import AuditEvent, ServingStats

__all__ = ["EdgeCluster"]


class EdgeCluster:
    """N built servers + a router, driven by one global virtual clock."""

    def __init__(self, config: ClusterConfig,
                 servers: Sequence[EdgeServer], router: Router):
        self.config = config
        self.servers = tuple(servers)
        self.router = router
        self.routed = 0
        self.spilled = 0     # routed cold while another server was warm
        self.handoffs = 0

    @classmethod
    def build(cls, config: ClusterConfig) -> "EdgeCluster":
        servers = tuple(EdgeServer.build(sc) for sc in config.servers)
        return cls(config, servers, resolve_router(config.router))

    @property
    def n_servers(self) -> int:
        return len(self.servers)

    def close(self) -> None:
        for srv in self.servers:
            srv.close()

    # -- the external gossip surface ------------------------------------
    def view(self, i: int) -> ServerView:
        """Server ``i``'s :class:`ServerView` snapshot — only state a
        real fleet's stats endpoint would publish."""
        srv = self.servers[i]
        eng = srv.engine
        st = srv.manager.state
        resident = {a: t.loaded.accuracy for a, t in st.tenants.items()
                    if t.loaded is not None}
        staging = {a: ld.variant.accuracy
                   for a, ld in srv.loader.inflight.items()}
        queued = {a: eng.batcher.queued(a)
                  for a in eng.batcher.queued_apps()}
        return ServerView(
            index=i, pending=eng.batcher.pending(),
            served=len(eng.results),
            warm=eng.warm_served,
            queued=queued, resident=resident, staging=staging)

    def views(self) -> Tuple[ServerView, ...]:
        return tuple(self.view(i) for i in range(self.n_servers))

    # -- the global event loop ------------------------------------------
    def run_trace(self, requests: Sequence[Request]) -> ServingStats:
        """Route-and-serve the trace across the fleet; returns the
        aggregated :class:`ServingStats` (``cluster`` block included)."""
        pending = sorted(requests, key=lambda r: r.arrival_ms)
        # Cluster-global request ids: Batcher.assign is idempotent for
        # explicit rids, so a handed-off request keeps its id on the
        # receiving server and per-request results stay unique fleetwide.
        for i, r in enumerate(pending):
            if r.rid is None:
                r.rid = i
        engines = [srv.engine for srv in self.servers]
        # Next-internal-event cache: only servers whose next event
        # precedes the routing horizon advance.  ``cluster_advance`` is
        # a strict no-op when nothing precedes the horizon (its loop
        # breaks before any state moves), so the skip is bit-exact;
        # anything that mutates a server through routing — a submit, a
        # hand-off's donor/receiver — invalidates that entry.
        nxt = [-math.inf] * len(engines)
        for r in pending:
            t = r.arrival_ms
            for i, eng in enumerate(engines):
                if nxt[i] < t:
                    nxt[i] = eng.cluster_advance(t)
            views = self.views()
            routed = self.router.route(r.app, views, t)
            target = self._maybe_handoff(r.app, routed, views, t)
            if target != routed:  # hand-off moved state on both ends
                nxt[routed] = nxt[target] = -math.inf
            self.routed += 1
            v = self.view(target)  # fresh: a hand-off just moved state
            if (r.app not in v.resident and r.app not in v.staging
                    and any(r.app in w.resident
                            for w in views if w.index != target)):
                self.spilled += 1
            engines[target].cluster_submit(r)
            nxt[target] = -math.inf
        # Drain: keep advancing on the shared clock until every server
        # reports no further internal events.
        while True:
            nxt = [eng.cluster_advance(math.inf) for eng in engines]
            if all(x == math.inf for x in nxt):
                break
        for eng in engines:
            eng.cluster_finish()
        return self.stats()

    # -- cross-server tenant hand-off -----------------------------------
    def _maybe_handoff(self, app: str, target: int,
                       views: Sequence[ServerView], now: float) -> int:
        """Flash-crowd overload check at routing time: if ``app``'s
        queue on ``target`` has reached the configured depth *because
        the server is busy with other tenants' work*, and a server at
        most half that busy exists, hand the tenant off and route this
        request to its new home.  A tenant whose own crowd is the whole
        overload stays put — its queue would move with it, so handing
        it off is churn, not relief (the router's spill penalty is what
        sheds that overflow)."""
        hq = self.config.router.handoff_queue
        if not hq:
            return target
        v = views[target]
        if v.queued.get(app, 0) < hq or app not in v.resident:
            return target
        other_work = v.pending - v.queued.get(app, 0)
        if other_work <= 0:
            return target
        others = sorted((w for w in views if w.index != target),
                        key=lambda w: (w.pending, w.index))
        if not others or others[0].pending * 2 > other_work:
            return target  # nobody is meaningfully lighter
        recv = others[0].index
        if self._handoff(app, target, recv, now):
            return recv
        return target

    def _handoff(self, app: str, src: int, dst: int,
                 now: float) -> bool:
        """Move tenant ``app`` from server ``src`` to ``dst`` as one
        transactional pair of residency plans.  Validates the receiver
        side with ``simulate`` before anything mutates; returns False
        (fleet untouched) when the receiver cannot host the tenant."""
        donor, recv = self.servers[src], self.servers[dst]
        dstate = donor.manager.state
        variant = dstate.tenants[app].loaded
        if variant is None or app in recv.loader.inflight:
            return False
        rstate = recv.manager.state
        rloaded = rstate.tenants[app].loaded
        staged_mb = 0.0
        if rloaded is None or rloaded.size_mb < variant.size_mb:
            # Receiver staged load: the donor's variant, or the largest
            # smaller one the receiver can fund without destabilizing
            # its own residents.  demand=True — the moved requests
            # waited out a real transfer, their admissions are honestly
            # demand-cold, not prefetch-warm.
            plan, v = None, variant
            while v is not None:
                if rloaded is None or v.size_mb > rloaded.size_mb:
                    cand = A.ResidencyPlan(
                        (A.staged_load_action(rstate, app, v),))
                    if rstate.simulate(cand) is None:
                        plan = cand
                        break
                v = rstate.tenants[app].zoo.next_smaller(v)
            if plan is None:
                return False
            if recv.loader.execute(plan, now, demand=True) is None:
                return False  # stale between simulate and execute
            staged_mb = v.size_mb
            recv.engine._event(now, "handoff", app, staged_mb)
        # Donor drain: unwind any in-flight load the donor still has for
        # the tenant through the normal cancel lifecycle, then one
        # Unload through the manager's transactional mirror path.
        if app in donor.loader.inflight:
            donor.loader.cancel(app, now)
        if donor.loader.peek_use(app) is not None:
            donor.loader.take_use(app, False)
        if dstate.tenants[app].loaded is not None:
            donor.manager._apply_actions((A.Unload(app),), now=now)
        donor.engine._event(now, "handoff", app, -variant.size_mb)
        # Re-queue the stranded requests to the new home.  Direct to the
        # receiving batcher (rids survive — assign is idempotent); the
        # receiver's predictor never saw these arrivals, exactly like a
        # real fleet where history doesn't travel with a hand-off.
        moved = donor.engine.batcher.queues.pop(app, [])
        for req in moved:
            recv.engine.batcher.submit(req)
            recv.engine._event(now, "submit", app, 0.0)
        # The receiver's local clock catches up to the hand-off instant:
        # the moved requests were not on this server before ``now``.
        recv.engine._cluster_now = max(recv.engine._cluster_now, now)
        self.handoffs += 1
        return True

    # -- aggregation ----------------------------------------------------
    def audit_trails(self) -> Tuple[Tuple[AuditEvent, ...], ...]:
        """Per-server normalized audit trails (the bit-determinism
        surface: two identical runs produce equal tuples)."""
        return tuple(tuple(srv.engine.audit_trail)
                     for srv in self.servers)

    def check_event_invariant(self) -> None:
        for srv in self.servers:
            srv.engine.check_event_invariant()

    def stats(self) -> ServingStats:
        """Fleet-level :class:`ServingStats`: core counters summed over
        servers, warm/latency aggregates over the merged results, plus
        the ``cluster`` block (per-server warm ratios, routed/spilled/
        handed-off counts)."""
        results = [r for srv in self.servers for r in srv.engine.results]
        tens = [t for srv in self.servers
                for t in srv.manager.state.tenants.values()]
        total_req = sum(t.requests for t in tens)
        kw: dict = {
            "requests": len(results),
            "kv_downgrades": sum(s.engine.kv_downgrades
                                 for s in self.servers),
            "kv_rejections": sum(s.engine.kv_rejections
                                 for s in self.servers),
            "weight_failures": sum(s.engine.weight_failures
                                   for s in self.servers),
            "kv_overrelease_mb": sum(s.manager.state.kv_overrelease_mb
                                     for s in self.servers),
            "prediction_hit_rate": (
                sum(t.requests - t.unexpected for t in tens) / total_req
                if total_req else 0.0),
            "per_tenant": {},
            "warm_ratio": 0.0,
            "prefetch_hits": sum(s.loader.prefetch_hits
                                 for s in self.servers),
            "prefetch_wasted": sum(s.loader.prefetch_wasted
                                   for s in self.servers),
            "prefetch_shrunk": sum(s.loader.prefetch_shrunk
                                   for s in self.servers),
            "demand_loads": sum(s.loader.demand_loads
                                for s in self.servers),
            "loads_committed": sum(s.loader.loads_committed
                                   for s in self.servers),
            "load_overlap_ms": sum(s.loader.load_overlap_ms
                                   for s in self.servers),
            "fits_scheduled": sum(s.loader.fits_scheduled
                                  for s in self.servers),
        }
        per_server_requests = tuple(len(s.engine.results)
                                    for s in self.servers)
        per_server_warm = tuple(
            (sum(1 for r in s.engine.results if r.warm)
             / len(s.engine.results)) if s.engine.results else 0.0
            for s in self.servers)
        kw["cluster"] = {
            "servers": self.n_servers,
            "router": getattr(self.router, "name", "?"),
            "routed": self.routed,
            "spilled": self.spilled,
            "handoffs": self.handoffs,
            "per_server_requests": per_server_requests,
            "per_server_warm_ratio": per_server_warm,
        }
        if not results:
            return ServingStats(**kw)
        kw["warm_ratio"] = sum(r.warm for r in results) / len(results)
        span_ms = (max(r.done_ms for r in results)
                   - min(r.arrival_ms for r in results))
        kw["requests_per_sec"] = (len(results) / (span_ms / 1e3)
                                  if span_ms > 0 else 0.0)
        for app in sorted({r.app for r in results}):
            rs = [r for r in results if r.app == app]
            kw["per_tenant"][app] = {
                "requests": len(rs),
                "warm_ratio": sum(r.warm for r in rs) / len(rs),
                "fail_ratio": sum(r.failed for r in rs) / len(rs),
            }
        return ServingStats(**kw)
