"""Declarative cluster config: N server trees + one router spec.

Mirrors the ``repro.serving.api`` pattern — frozen dataclasses, a
``to_dict``/``from_dict`` round trip, validation at declaration time —
so a whole fleet is one JSON-able document::

    cfg = ClusterConfig(
        servers=(base, base, base),       # three identical edge boxes
        router=RouterSpec(name="warm-aware", handoff_queue=6))
    cluster = EdgeCluster.build(cfg)

The cluster tier is built on the *deterministic* serving stack: every
server must use the sim executor (one shared virtual clock; wall-clock
executors cannot interleave reproducibly), carry a background loader
(routing decisions read staging state), and use batch-scalar batching
(the continuous engine owns its own loop).  Tenant name sets must match
across servers — the router's unit of placement is the tenant, and a
request must be servable anywhere it can be routed.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Sequence, Tuple

from repro.serving.api import ServingConfig

__all__ = ["ClusterConfig", "RouterSpec"]


@dataclass(frozen=True)
class RouterSpec:
    """Which routing policy, and its knobs.

    ``name`` resolves through the ``@register_router`` registry
    (``round-robin`` / ``least-loaded`` / ``warm-aware`` built in).
    ``spill_penalty`` is the warm-aware router's queue-depth weight:
    how much resident-variant accuracy a server must offer to justify
    each already-queued request in front of the new one.  ``handoff_queue``
    arms cross-server tenant hand-off: when a tenant's queue on its
    routed server reaches this depth while a strictly lighter server
    exists, the cluster drains the tenant to the lighter server as one
    transactional plan pair.  ``0`` (default) disables hand-off.
    """

    name: str = "warm-aware"
    spill_penalty: float = 5.0
    handoff_queue: int = 0

    def __post_init__(self) -> None:
        # Lazy import: routers.py imports this module for the spec type.
        from repro.cluster.routers import available_routers
        if self.name not in available_routers():
            raise ValueError(
                f"unknown router {self.name!r}; registered routers: "
                f"{', '.join(available_routers())}")
        if self.spill_penalty < 0.0:
            raise ValueError(
                f"spill_penalty must be >= 0, got {self.spill_penalty}")
        if self.handoff_queue < 0:
            raise ValueError(
                f"handoff_queue must be >= 0, got {self.handoff_queue}")


@dataclass(frozen=True)
class ClusterConfig:
    """N :class:`~repro.serving.api.ServingConfig` trees + a router."""

    servers: Tuple[ServingConfig, ...]
    router: RouterSpec = field(default_factory=RouterSpec)

    def __post_init__(self) -> None:
        object.__setattr__(self, "servers", tuple(self.servers))
        if not self.servers:
            raise ValueError("ClusterConfig needs at least one server")
        for i, sc in enumerate(self.servers):
            if sc.executor != "sim":
                raise ValueError(
                    f"server {i}: cluster serving requires "
                    f"executor='sim' (one shared virtual clock)")
            if not sc.loader.prefetch:
                raise ValueError(
                    f"server {i}: cluster serving requires "
                    f"LoaderSpec(prefetch=True) — routing reads "
                    f"staging state")
            if sc.batching.continuous:
                raise ValueError(
                    f"server {i}: continuous batching drives its own "
                    f"loop and cannot share the cluster clock")
        names = {tuple(sorted(t.name for t in sc.tenants))
                 for sc in self.servers}
        if len(names) != 1:
            raise ValueError(
                "every server must register the same tenant set; got "
                f"{sorted(names)}")

    @property
    def tenant_names(self) -> Tuple[str, ...]:
        return tuple(sorted(t.name for t in self.servers[0].tenants))

    @classmethod
    def uniform(cls, n: int, base: ServingConfig,
                router: "RouterSpec | None" = None) -> "ClusterConfig":
        """N identical servers from one base config."""
        if n < 1:
            raise ValueError(f"need at least one server, got {n}")
        return cls(servers=(base,) * n,
                   router=router if router is not None else RouterSpec())

    # -- serialization round trip ---------------------------------------
    def to_dict(self) -> dict:
        return {"servers": [s.to_dict() for s in self.servers],
                "router": dataclasses.asdict(self.router)}

    @classmethod
    def from_dict(cls, d: dict) -> "ClusterConfig":
        servers: Sequence = d["servers"]
        router = d.get("router", RouterSpec())
        return cls(
            servers=tuple(s if isinstance(s, ServingConfig)
                          else ServingConfig.from_dict(s)
                          for s in servers),
            router=(router if isinstance(router, RouterSpec)
                    else RouterSpec(**router)))
